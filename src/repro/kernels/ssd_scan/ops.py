"""Jitted public wrapper for the SSD kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, a_log, b, c, h0, chunk: int = 128):
    """Mamba2 SSD scan.  x: (B,T,H,P); dt: (B,T,H); a_log: (H,);
    b,c: (B,T,N); h0: (B,H,P,N).  Returns (y, h_final)."""
    return ssd_scan_pallas(x, dt, a_log, b, c, h0, chunk=chunk,
                           interpret=not _on_tpu())
