"""Pure-jnp oracle for the SSD kernel: direct (non-chunked) recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, a_log, b, c, h0):
    """x: (B,T,H,P); dt: (B,T,H); a_log: (H,); b,c: (B,T,N);
    h0: (B,H,P,N).  Step-by-step recurrence (the ground truth)."""

    def step(h, inp):
        xt, dtt, bt, ct = inp                  # (B,H,P),(B,H),(B,N),(B,N)
        a = jnp.exp(-jnp.exp(a_log)[None, :] * dtt)      # (B,H)
        dtx = xt * dtt[..., None]
        h = h * a[:, :, None, None] + jnp.einsum("bhp,bn->bhpn", dtx, bt)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    seq = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
           dt.transpose(1, 0, 2).astype(jnp.float32),
           b.transpose(1, 0, 2).astype(jnp.float32),
           c.transpose(1, 0, 2).astype(jnp.float32))
    h_final, y = jax.lax.scan(step, h0.astype(jnp.float32), seq)
    return y.transpose(1, 0, 2, 3).astype(x.dtype), h_final
