"""Mamba2 SSD chunked-scan kernel.

    h_t = a_t h_{t-1} + dt_t B_t x_t;   y_t = C_t . h_t

TPU mapping: grid (B, H, T/chunk).  The (P, N) inter-chunk state carries in
VMEM scratch across the sequential innermost axis.  Each chunk does the SSD
matmul form on the MXU:

    y_intra = ((C B^T) o L) (dt*x)      L_ij = exp(acum_i - acum_j), i >= j
    y_inter = exp(acum) * (C h_in)
    h_out   = h_in * exp(acum_Q) + sum_j exp(acum_Q - acum_j) (dt*x)_j B_j^T

so the sequential dependency is only chunk-granular; intra-chunk work is
(Q,Q)/(Q,N)/(Q,P) matmuls — chunk Q defaults to 128 to align with the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(alog_ref, x_ref, dt_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
            state_ref, *, chunk: int):
    t_idx = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(t_idx == 0)
    def _init():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    a = -jnp.exp(alog_ref[0].astype(jnp.float32))        # scalar A < 0
    x = x_ref[0, :, 0].astype(jnp.float32)               # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)             # (Q,)
    bmat = b_ref[0].astype(jnp.float32)                  # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)                  # (Q, N)

    loga = a * dt                                        # (Q,)
    acum = jnp.cumsum(loga)                              # (Q,) inclusive
    dtx = x * dt[:, None]                                # (Q, P)

    # intra-chunk
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    decay = acum[:, None] - acum[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 0)
    qj = jax.lax.broadcasted_iota(jnp.int32, cb.shape, 1)
    gate = jnp.where(qi >= qj, jnp.exp(decay), 0.0)
    y = jax.lax.dot_general(cb * gate, dtx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)

    # inter-chunk: contribution of incoming state
    h_in = state_ref[...]                                 # (P, N)
    y = y + jnp.exp(acum)[:, None] * jax.lax.dot_general(
        cmat, h_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)               # (Q, P)
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    # outgoing state
    tail = acum[-1]
    sdecay = jnp.exp(tail - acum)                         # (Q,)
    h_new = h_in * jnp.exp(tail) + jax.lax.dot_general(
        dtx * sdecay[:, None], bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (P, N)
    state_ref[...] = h_new

    @pl.when(t_idx == n_t - 1)
    def _finish():
        hout_ref[0, 0] = state_ref[...].astype(hout_ref.dtype)


def ssd_scan_pallas(x, dt, a_log, b, c, h0, *, chunk: int = 128,
                    interpret: bool = True):
    """x: (B, T, H, P); dt: (B, T, H) post-softplus; a_log: (H,);
    b, c: (B, T, N); h0: (B, H, P, N) f32.

    Returns (y (B,T,H,P), h_final (B,H,P,N))."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, (t, chunk)
    grid = (bsz, h, t // chunk)
    kernel = functools.partial(_kernel, chunk=chunk)
    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ti: (hi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ti: (bi, ti, hi)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ti: (bi, ti, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ti: (bi, ti, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ti: (bi, hi, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((bsz, t, h, p), x.dtype),
                   jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(a_log, x, dt, b, c, h0)
    return y, h_final
