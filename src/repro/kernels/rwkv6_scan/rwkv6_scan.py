"""WKV6 recurrence kernel (RWKV-6 time mix with data-dependent decay).

    y_t = r_t^T (S_{t-1} + u k_t v_t^T);   S_t = diag(w_t) S_{t-1} + k_t v_t^T

TPU mapping: grid (B, H, T/block_t).  The (D, D) state matrix lives in VMEM
scratch and carries across the sequential innermost grid axis; each grid
step streams a (block_t, D) tile of r/k/v/w into VMEM and runs the
recurrence with a fori_loop of rank-1 updates (VPU work — the recurrence is
inherently sequential in t, the kernel's win is keeping S in VMEM instead of
bouncing it through HBM every step, which is what a naive lax.scan does on
long sequences).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
            state_ref, *, block_t: int):
    t_idx = pl.program_id(2)
    n_t = pl.num_programs(2)

    @pl.when(t_idx == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                     # (D,)

    def step(i, _):
        rt = r_ref[0, i, 0].astype(jnp.float32)          # (D,)
        kt = k_ref[0, i, 0].astype(jnp.float32)
        vt = v_ref[0, i, 0].astype(jnp.float32)
        wt = w_ref[0, i, 0].astype(jnp.float32)
        s = state_ref[...]                               # (D, D)
        kv = kt[:, None] * vt[None, :]                   # (D, D)
        y = jnp.sum((s + u[:, None] * kv) * rt[:, None], axis=0)
        y_ref[0, i, 0] = y.astype(y_ref.dtype)
        state_ref[...] = s * wt[:, None] + kv
        return ()

    jax.lax.fori_loop(0, block_t, step, ())

    @pl.when(t_idx == n_t - 1)
    def _finish():
        sout_ref[0, 0] = state_ref[...].astype(sout_ref.dtype)


def rwkv6_scan_pallas(r, k, v, w, u, s0, *, block_t: int = 256,
                      interpret: bool = True):
    """r,k,v,w: (B, T, H, D); u: (H, D); s0: (B, H, D, D) f32.

    Returns (y (B, T, H, D), s_final (B, H, D, D))."""
    b, t, h, d = r.shape
    block_t = min(block_t, t)
    assert t % block_t == 0, (t, block_t)
    grid = (b, h, t // block_t)
    kernel = functools.partial(_kernel, block_t=block_t)
    seq_spec = pl.BlockSpec((1, block_t, 1, d),
                            lambda bi, hi, ti: (bi, ti, hi, 0))
    y, s_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, d), lambda bi, hi, ti: (hi, 0)),
                  pl.BlockSpec((1, 1, d, d), lambda bi, hi, ti: (bi, hi, 0, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, 1, d, d),
                                lambda bi, hi, ti: (bi, hi, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((b, t, h, d), r.dtype),
                   jax.ShapeDtypeStruct((b, h, d, d), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_final
