"""Jitted public wrapper for the WKV6 kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rwkv6_scan.rwkv6_scan import rwkv6_scan_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("block_t",))
def rwkv6_scan(r, k, v, w, u, s0, block_t: int = 256):
    """WKV6 recurrence.  r,k,v,w: (B,T,H,D); u: (H,D); s0: (B,H,D,D).
    Returns (y, s_final)."""
    return rwkv6_scan_pallas(r, k, v, w, u, s0, block_t=block_t,
                             interpret=not _on_tpu())
