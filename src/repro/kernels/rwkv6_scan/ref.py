"""Pure-jnp oracle for the WKV6 kernel (lax.scan over time)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """r,k,v,w: (B,T,H,D); u: (H,D); s0: (B,H,D,D). -> (y, s_final)."""

    def step(state, inp):
        rt, kt, vt, wt = inp                               # (B,H,D)
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt,
                       state + u[None, :, :, None] * kv)
        state = state * wt[..., None] + kv
        return state, y

    seq = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32)
                for a in (r, k, v, w))
    s_final, y = jax.lax.scan(step, s0.astype(jnp.float32), seq)
    return y.transpose(1, 0, 2, 3).astype(r.dtype), s_final
