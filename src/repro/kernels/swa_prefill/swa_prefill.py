"""Sliding-window prefill flash attention with window block-skipping.

The pure-jnp blocked attention computes every (q_block, kv_block) pair and
masks — O(S^2) work even when the window W << S.  This kernel's grid is
(B, KV, S/block_q, W/block_k + 1): for each q block only the kv blocks that
can intersect its window are visited, so prefill work is O(S * W) — an
8x reduction for h2o-danube's prefill_32k (W=4096, S=32768).

TPU mapping:
* the kv BlockSpec index_map computes the ABSOLUTE kv block
  `qi + wi - n_w + 1` (clamped at 0) — the harness streams exactly the
  window-diagonal band HBM->VMEM;
* the q tile and the online-softmax state (m, l, acc scratch) persist
  across the innermost (wi) axis, finalized on the last window block;
* clamped duplicate blocks are killed in-kernel by the `expected >= 0`
  test plus the causal/window position mask (f32 accumulation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_k: int, n_w: int, window: int,
            scale: float):
    qi = pl.program_id(2)
    wi = pl.program_id(3)

    @pl.when(wi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    expected = qi + wi - (n_w - 1)          # absolute kv block (pre-clamp)

    @pl.when(expected >= 0)
    def _work():
        q = q_ref[0, :, 0].astype(jnp.float32) * scale   # (bq*G? no: bq, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (bk, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = expected * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                              s.shape, 1)
        rel = q_pos - k_pos
        mask = (rel >= 0) & (rel < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(wi == n_w - 1)
    def _finish():
        o_ref[0, :, 0] = (acc_ref[...]
                          / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def swa_prefill_pallas(q, k, v, *, window: int, block_q: int = 256,
                       block_k: int = 256, interpret: bool = True):
    """Causal sliding-window attention, one kv head group at a time.

    q: (B, S, H, D) with H == KV heads here (call per-group or with GQA
    groups folded into batch by the ops wrapper); k, v: (B, S, H, D).
    Returns (B, S, H, D)."""
    b, s, h, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert block_q == block_k, "kernel requires equal q/kv block sizes"
    assert s % block_q == 0
    # kv blocks that can intersect a q block's window (incl. the diagonal)
    n_w = (window + block_q - 2) // block_k + 1
    n_w = min(n_w, s // block_k)
    grid = (b, h, s // block_q, n_w)
    kernel = functools.partial(_kernel, block_q=block_q, block_k=block_k,
                               n_w=n_w, window=window, scale=d ** -0.5)

    def kv_index(bi, hi, qi, wi):
        return (bi, jnp.maximum(qi + wi - (n_w - 1), 0), hi, 0)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda bi, hi, qi, wi: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d), kv_index),
            pl.BlockSpec((1, block_k, 1, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda bi, hi, qi, wi: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
