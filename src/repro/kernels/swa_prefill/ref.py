"""Pure-jnp oracle: causal sliding-window attention (dense masked)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def swa_prefill_ref(q, k, v, *, window: int):
    """q, k, v: (B, S, H, D), same head count.  Returns (B, S, H, D)."""
    b, s, h, d = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    rel = qi - ki
    mask = (rel >= 0) & (rel < window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
