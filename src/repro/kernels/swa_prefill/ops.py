"""Jitted public wrapper: GQA sliding-window prefill attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.swa_prefill.swa_prefill import swa_prefill_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("window", "block"))
def swa_prefill_attention(q, k, v, window: int, block: int = 256):
    """Causal SWA prefill.  q: (B, S, H, D); k, v: (B, S, KV, D) with
    H % KV == 0 (GQA groups are folded into the head axis by repeating
    K/V — the kernel sees equal head counts).  Returns (B, S, H, D)."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    return swa_prefill_pallas(q, k, v, window=window, block_q=block,
                              block_k=block, interpret=not _on_tpu())
