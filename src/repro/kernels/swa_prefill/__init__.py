from repro.kernels.swa_prefill.ops import swa_prefill_attention
