"""Pallas TPU kernels for the serving hot spots.

Each kernel ships three files: the pl.pallas_call implementation with
explicit BlockSpec VMEM tiling, ``ops.py`` (the jitted public wrapper, with
``interpret=True`` on non-TPU backends), and ``ref.py`` (the pure-jnp
oracle used by the shape/dtype sweep tests).
"""
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.swa_prefill.ops import swa_prefill_attention
