"""Flash-decode GQA attention kernel (one query token vs a long KV cache).

This is the decode_32k / long_500k hot spot: q (B, KV, G, D) against
k/v (B, S, KV, D) with a per-batch valid length.  TPU mapping:

* grid (B, KV, S/block_s) — the innermost axis iterates sequentially on a
  TPU core, so the online-softmax running state (m, l, acc) lives in VMEM
  scratch and carries across KV-cache blocks;
* BlockSpecs stream one (block_s, D) tile of K and V per grid step
  HBM->VMEM (the kernel is memory-bound: arithmetic intensity ~ G, so the
  goal is pure streaming at HBM bandwidth with no (S,) materialization);
* block_s defaults to 512 and D is the head dim (128-multiple for MXU/VPU
  alignment where the model allows).

The q tile (G, D) stays resident; scores are (G, block_s) f32 in registers/
VMEM; the final normalization writes (G, D) once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, block_s: int, scale: float):
    s_idx = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (block_s, D)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (block_s, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    length = len_ref[0]
    offs = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32,
                                                      s.shape, 1)
    s = jnp.where(offs < length, s, NEG_INF)

    m_prev = m_ref[...]                                   # (G, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                # (G, bs)
    alpha = jnp.exp(m_prev - m_new)                       # (G, 1)
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == n_s - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, lengths, *, block_s: int = 512,
                            interpret: bool = True):
    """q: (B, KV, G, D); k, v: (B, S, KV, D); lengths: (B,) int32.

    Returns (B, KV, G, D)."""
    b, kvh, g, d = q.shape
    s = k.shape[1]
    block_s = min(block_s, s)
    assert s % block_s == 0, (s, block_s)
    n_s = s // block_s
    scale = d ** -0.5
    grid = (b, kvh, n_s)
    kernel = functools.partial(_kernel, block_s=block_s, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, si: (bi,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, hi, si: (bi, si, hi, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, hi, si: (bi, si, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
