"""Jitted public wrapper for the flash-decode kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.decode_attention import \
    decode_attention_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("block_s",))
def decode_attention(q, k, v, lengths, block_s: int = 512):
    """Flash-decode GQA attention.  q: (B, KV, G, D); k/v: (B, S, KV, D);
    lengths: (B,) int32 valid cache lengths.  Returns (B, KV, G, D)."""
    return decode_attention_pallas(q, k, v, lengths, block_s=block_s,
                                   interpret=not _on_tpu())
