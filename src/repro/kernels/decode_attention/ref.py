"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import jax.numpy as jnp
import jax


def decode_attention_ref(q, k, v, lengths):
    """q: (B, KV, G, D); k, v: (B, S, KV, D); lengths: (B,)."""
    b, kvh, g, d = q.shape
    s = k.shape[1]
    qf = q.astype(jnp.float32) * (d ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, kf)
    valid = jnp.arange(s)[None, :] < lengths[:, None]       # (B, S)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vf)
    return out.astype(q.dtype)
