"""RWKV-6 "Finch" — attention-free time mix with data-dependent decay.

Time-mix (WKV6) recurrence per head (state S in R^{dk x dv}):

    y_t = r_t^T (S_{t-1} + u  k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

with per-channel decays ``w_t`` produced from the input via a LoRA
(data-dependent decay — the Finch contribution), plus token-shift ddlerp
interpolation for the r/k/v/w/g streams.  Training/prefill runs a lax.scan
over time; ``repro.kernels.rwkv6_scan`` is the Pallas TPU kernel for the
recurrence with this module as oracle.  Decode carries O(1) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Array, dense_init, linear

LORA_R = 64
DECAY_LORA_R = 128


def init_rwkv6_tmix(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    h = cfg.rwkv_num_heads
    hd = d // h
    ks = jax.random.split(key, 12)
    return {
        "mu_x": jnp.zeros((d,), dtype),
        "mu": jnp.zeros((5, d), dtype),                       # r,k,v,w,g
        "lora_a": dense_init(ks[0], (d, 5 * LORA_R), dtype),
        "lora_b": dense_init(ks[1], (5, LORA_R, d), dtype, fan_in=LORA_R),
        "w_r": dense_init(ks[2], (d, d), dtype),
        "w_k": dense_init(ks[3], (d, d), dtype),
        "w_v": dense_init(ks[4], (d, d), dtype),
        "w_g": dense_init(ks[5], (d, d), dtype),
        "w_o": dense_init(ks[6], (d, d), dtype),
        "decay_a": dense_init(ks[7], (d, DECAY_LORA_R), dtype),
        "decay_b": dense_init(ks[8], (DECAY_LORA_R, d), dtype,
                              fan_in=DECAY_LORA_R),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "bonus_u": dense_init(ks[9], (h, hd), jnp.float32, fan_in=hd),
        "ln_scale": jnp.ones((d,), jnp.float32),
        "ln_bias": jnp.zeros((d,), jnp.float32),
    }


def init_rwkv6_cmix(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.zeros((d,), dtype),
        "mu_r": jnp.zeros((d,), dtype),
        "w_k": dense_init(ks[0], (d, cfg.d_ff), dtype),
        "w_v": dense_init(ks[1], (cfg.d_ff, d), dtype, fan_in=cfg.d_ff),
        "w_r": dense_init(ks[2], (d, d), dtype),
    }


def _token_shift(x: Array, prev: Array | None):
    """prev token's x; x: (B,S,d); prev: (B,d) carried state or None."""
    b, s, d = x.shape
    if prev is None:
        prev = jnp.zeros((b, d), x.dtype)
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted, x[:, -1, :]


def wkv6_scan(r: Array, k: Array, v: Array, w: Array, u: Array,
              s0: Array | None = None):
    """WKV6 recurrence.  r,k,v: (B,S,H,D); w: (B,S,H,D) decay in (0,1);
    u: (H,D) bonus.  Returns (y (B,S,H,D), s_final (B,H,D,D))."""
    b, s, h, d = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp                                   # (B,H,D) each
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        y = jnp.einsum("bhi,bhij->bhj", rt,
                       state + u[None, :, :, None] * kv)
        state = state * wt[..., None] + kv
        return state, y

    seq = tuple(a.transpose(1, 0, 2, 3).astype(jnp.float32)
                for a in (r, k, v, w))
    s_final, y = jax.lax.scan(step, s0.astype(jnp.float32), seq)
    return y.transpose(1, 0, 2, 3).astype(r.dtype), s_final


def wkv6_chunked(r: Array, k: Array, v: Array, w: Array, u: Array,
                 s0: Array | None = None, chunk: int = 32):
    """Chunked-parallel WKV6 (beyond-paper training path).

    The naive lax.scan carries the (H, D, D) state through HBM every
    timestep (T=4096 sequential steps dominate the rwkv6 train roofline);
    the chunked form factorizes the within-chunk decay products

        s_{t,j} = (r_t * e^{L_{t-1}}) . (k_j * e^{-L_j}),  j < t

    so intra-chunk work is two masked matmuls and the state is carried
    once per chunk.  Per-step log-decays are clamped to >= -2 (w >= 0.135)
    to bound e^{-L_j} within f32 for chunk <= 32 — lossless for realistic
    decays (tests assert equivalence with the scan reference).
    """
    b, t, h, d = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)
    pad = (-t) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    nc = (t + pad) // chunk
    q = chunk
    rs = r.reshape(b, nc, q, h, d).astype(jnp.float32)
    ks = k.reshape(b, nc, q, h, d).astype(jnp.float32)
    vs = v.reshape(b, nc, q, h, d).astype(jnp.float32)
    ws = w.reshape(b, nc, q, h, d).astype(jnp.float32)

    lw = jnp.maximum(jnp.log(jnp.maximum(ws, 1e-12)), -2.0)  # (B,nc,q,H,D)
    lcum = jnp.cumsum(lw, axis=2)                            # inclusive L_t
    lprev = lcum - lw                                        # L_{t-1}
    r_t = rs * jnp.exp(lprev)                                # r~ (B,nc,q,H,D)
    k_t = ks * jnp.exp(-lcum)                                # k~
    # intra: strict-causal (t > j) masked matmul + u-diagonal
    scores = jnp.einsum("bcthd,bcjhd->bchtj", r_t, k_t)
    qi = jnp.arange(q)
    strict = (qi[:, None] > qi[None, :])
    scores = jnp.where(strict[None, None, None], scores, 0.0)
    diag = jnp.einsum("bcthd,hd,bcthd->bcth", rs, u.astype(jnp.float32), ks)
    y = jnp.einsum("bchtj,bcjhd->bcthd", scores, vs)
    y = y + diag[..., None] * vs

    # inter-chunk: carry the state once per chunk
    ltot = lcum[:, :, -1]                                     # (B,nc,H,D)
    kw = ks * jnp.exp(ltot[:, :, None] - lcum)                # (B,nc,q,H,D)

    def step(s, inp):
        rt_, kw_, vs_, ltot_ = inp
        # rt_ already includes the e^{L_{t-1}} factor
        yi = jnp.einsum("bthd,bhde->bthe", rt_, s)
        s_new = s * jnp.exp(ltot_)[..., None] + jnp.einsum(
            "bthd,bthe->bhde", kw_, vs_)
        return s_new, yi

    seq = (r_t.transpose(1, 0, 2, 3, 4), kw.transpose(1, 0, 2, 3, 4),
           vs.transpose(1, 0, 2, 3, 4), ltot.transpose(1, 0, 2, 3))
    s_final, y_inter = jax.lax.scan(step, s0.astype(jnp.float32), seq)
    y = y + y_inter.transpose(1, 0, 2, 3, 4)
    y = y.reshape(b, nc * q, h, d)[:, :t]
    return y.astype(r.dtype), s_final


def rwkv6_tmix_fwd(params, x: Array, cfg: ModelConfig,
                   state: dict | None = None):
    """Time mix.  x: (B,S,d).  state: {"shift": (B,d), "wkv": (B,H,D,D)}."""
    b, s, d = x.shape
    h = cfg.rwkv_num_heads
    hd = d // h
    prev = state["shift"] if state else None
    xprev, shift_out = _token_shift(x, prev)
    sx = xprev - x
    xxx = x + sx * params["mu_x"][None, None, :]
    lora = jnp.tanh(linear(xxx, params["lora_a"]))
    lora = lora.reshape(b, s, 5, LORA_R)
    mix = params["mu"][None, None] + jnp.einsum(
        "bsfr,frd->bsfd", lora.astype(jnp.float32),
        params["lora_b"].astype(jnp.float32)).astype(x.dtype)
    xr, xk, xv, xw, xg = [x + sx * mix[:, :, i] for i in range(5)]

    r = linear(xr, params["w_r"]).reshape(b, s, h, hd)
    k = linear(xk, params["w_k"]).reshape(b, s, h, hd)
    v = linear(xv, params["w_v"]).reshape(b, s, h, hd)
    g = jax.nn.silu(linear(xg, params["w_g"]))
    dlora = linear(jnp.tanh(linear(xw, params["decay_a"])), params["decay_b"])
    w = jnp.exp(-jnp.exp(params["decay_base"][None, None]
                         + dlora.astype(jnp.float32)))        # (B,S,d) in (0,1)
    w = w.reshape(b, s, h, hd)

    wkv0 = state["wkv"] if state else None
    if cfg.rwkv_chunked and s > 1:
        y, wkv = wkv6_chunked(r, k, v, w, params["bonus_u"], wkv0)
    else:
        y, wkv = wkv6_scan(r, k, v, w, params["bonus_u"], wkv0)
    y = y.reshape(b, s, d)
    # per-head group norm
    yh = y.astype(jnp.float32).reshape(b, s, h, hd)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = (yh.reshape(b, s, d) * params["ln_scale"] + params["ln_bias"]).astype(x.dtype)
    out = linear(y * g, params["w_o"])
    return out, {"shift": shift_out, "wkv": wkv}


def rwkv6_cmix_fwd(params, x: Array, cfg: ModelConfig,
                   state: dict | None = None):
    """Channel mix.  state: {"shift": (B,d)}."""
    prev = state["shift"] if state else None
    xprev, shift_out = _token_shift(x, prev)
    sx = xprev - x
    xk = x + sx * params["mu_k"][None, None]
    xr = x + sx * params["mu_r"][None, None]
    k = jnp.square(jax.nn.relu(linear(xk, params["w_k"])))
    kv = linear(k, params["w_v"])
    out = jax.nn.sigmoid(linear(xr, params["w_r"])) * kv
    return out, {"shift": shift_out}


def init_rwkv6_state(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    h = cfg.rwkv_num_heads
    hd = d // h
    return {
        "tmix": {"shift": jnp.zeros((batch, d), dtype),
                 "wkv": jnp.zeros((batch, h, hd, hd), jnp.float32)},
        "cmix": {"shift": jnp.zeros((batch, d), dtype)},
    }
