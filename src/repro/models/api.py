"""Public model API: build_model(cfg) -> Model with init/forward/prefill/decode.

All functions are pure; params and caches are pytrees.  ``Model`` is a thin
namespace so the functions close over the config (hashable, frozen) and an
optional mesh for the expert-parallel MoE path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba2 as m2
from repro.models import rwkv6 as rk
from repro.models import transformer as tfm
from repro.models.common import embed_init, dense_init, linear, rms_norm, to_dtype

MAX_LEARNED_POS = 32768


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _stacked_init(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_params(key, cfg: ModelConfig):
    dtype = to_dtype(cfg.param_dtype)
    ks = iter(jax.random.split(key, 16))
    p: dict = {
        "embed": embed_init(next(ks), (cfg.padded_vocab, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = dense_init(next(ks), (cfg.d_model, cfg.padded_vocab), dtype)
    if cfg.rope_kind == "learned":
        p["pos_emb"] = embed_init(next(ks), (MAX_LEARNED_POS, cfg.d_model), dtype)
    groups = []
    for kind, n in tfm.layer_groups(cfg):
        groups.append(_stacked_init(
            next(ks), n, lambda k, kind=kind: tfm.init_block(k, cfg, kind, dtype)))
    p["groups"] = tuple(groups)
    if cfg.shared_attn_every:
        p["shared_attn"] = tfm.init_shared_attn(next(ks), cfg, dtype)
    if cfg.is_encoder_decoder:
        enc_groups = _stacked_init(
            next(ks), cfg.encoder_layers,
            lambda k: tfm.init_block(k, dataclasses.replace(
                cfg, is_encoder_decoder=False), "attn+mlp", dtype))
        p["encoder"] = {
            "groups": (enc_groups,),
            "pos_emb": embed_init(next(ks), (cfg.encoder_seq_len, cfg.d_model),
                                  dtype),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
    if cfg.mtp_depth:
        last_kind = cfg.blocks[-1]
        p["mtp"] = {
            "proj": dense_init(next(ks), (2 * cfg.d_model, cfg.d_model), dtype),
            "norm_h": jnp.zeros((cfg.d_model,), dtype),
            "norm_e": jnp.zeros((cfg.d_model,), dtype),
            "block": tfm.init_block(next(ks), cfg, last_kind, dtype),
        }
    return p


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens, positions=None):
    x = params["embed"][tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.rope_kind == "learned" and positions is not None:
        x = x + params["pos_emb"][positions]
    return x


def _head(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        return linear(x, params["embed"].T)
    return linear(x, params["head"])


def _dp_axes(mesh) -> tuple:
    if mesh is None:
        return ("data",)
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _constrain(x, mesh, spec):
    if mesh is None:
        return x
    fixed = jax.sharding.PartitionSpec(
        *[(tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                 if a in mesh.axis_names) or None) if ax is not None else None
          for ax in spec])
    fixed = jax.sharding.PartitionSpec(
        *[ax[0] if isinstance(ax, tuple) and len(ax) == 1 else ax
          for ax in fixed])
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, fixed))


# ---------------------------------------------------------------------------
# Group execution (scan-over-layers)
# ---------------------------------------------------------------------------

def _layer_slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _run_groups_fwd(params, x, ctx, cfg: ModelConfig, mesh,
                    groups=None, enc_mode=False):
    """Train-mode stack: no caches. Returns (x, aux)."""
    gspec = tfm.layer_groups(cfg) if not enc_mode else [
        ("attn+mlp", cfg.encoder_layers)]
    gparams = params["groups"] if groups is None else groups
    shared = params.get("shared_attn") if not enc_mode else None
    every = cfg.shared_attn_every
    aux = jnp.float32(0.0)
    layer0 = 0
    for (kind, n), gp in zip(gspec, gparams):
        def body(carry, xs):
            xc, auxc = carry
            pl, idx = xs
            if shared is not None and every:
                xc = jax.lax.cond(
                    idx % every == 0,
                    lambda v: tfm.shared_attn_fwd(shared, v, ctx, cfg),
                    lambda v: v, xc)
            xc, a = tfm.block_fwd(pl, xc, ctx, kind, cfg, mesh)
            return (xc, auxc + a), None

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        idxs = jnp.arange(layer0, layer0 + n)
        if cfg.scan_layers and n > 1:
            (x, aux), _ = jax.lax.scan(body, (x, aux), (gp, idxs))
        else:
            for i in range(n):
                (x, aux), _ = body((x, aux), (_layer_slice(gp, i), idxs[i]))
        layer0 += n
    return x, aux


def _run_groups_prefill(params, x, ctx, cfg: ModelConfig, mesh, cache_size):
    gspec = tfm.layer_groups(cfg)
    shared = params.get("shared_attn")
    every = cfg.shared_attn_every
    aux = jnp.float32(0.0)
    layer0 = 0
    group_caches = []
    shared_kv = _init_shared_cache(cfg, x.shape[0], cache_size,
                                   to_dtype(cfg.dtype)) if shared else None
    for (kind, n), gp in zip(gspec, params["groups"]):
        def body(carry, xs):
            xc, auxc, skv = carry
            pl, idx = xs
            if shared is not None and every:
                def apply(v_skv):
                    v, skv_in = v_skv
                    app = idx // every
                    v2, kv = tfm.shared_attn_prefill(shared, v, ctx, cfg,
                                                     cache_size)
                    skv_out = jax.tree.map(
                        lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                            buf, new.astype(buf.dtype), app, 0),
                        skv_in, kv)
                    return v2, skv_out
                xc, skv = jax.lax.cond(idx % every == 0, apply,
                                       lambda v_skv: v_skv, (xc, skv))
            xc, a, cache = tfm.block_prefill(pl, xc, ctx, kind, cfg, mesh,
                                             cache_size)
            return (xc, auxc + a, skv), cache

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        idxs = jnp.arange(layer0, layer0 + n)
        if cfg.scan_layers and n > 1:
            (x, aux, shared_kv), caches = jax.lax.scan(
                body, (x, aux, shared_kv), (gp, idxs))
        else:
            caches_list = []
            for i in range(n):
                (x, aux, shared_kv), c = body((x, aux, shared_kv),
                                              (_layer_slice(gp, i), idxs[i]))
                caches_list.append(c)
            caches = jax.tree.map(lambda *a: jnp.stack(a), *caches_list)
        group_caches.append(caches)
        layer0 += n
    return x, aux, tuple(group_caches), shared_kv


def _run_groups_decode(params, x, cache, index, ctx, cfg: ModelConfig,
                       mesh=None):
    gspec = tfm.layer_groups(cfg)
    shared = params.get("shared_attn")
    every = cfg.shared_attn_every
    layer0 = 0
    new_group_caches = []
    shared_kv = cache.get("shared")
    for (kind, n), gp, gc in zip(gspec, params["groups"], cache["groups"]):
        def body(carry, xs):
            xc, skv = carry
            pl, cl, idx = xs
            if shared is not None and every:
                def apply(v_skv):
                    v, skv_in = v_skv
                    app = idx // every
                    kv = jax.tree.map(lambda a: a[app], skv_in)
                    v2, kv2 = tfm.shared_attn_decode(shared, v, kv, index,
                                                     ctx, cfg)
                    skv_out = jax.tree.map(
                        lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                            buf, new.astype(buf.dtype), app, 0),
                        skv_in, kv2)
                    return v2, skv_out
                xc, skv = jax.lax.cond(idx % every == 0, apply,
                                       lambda v_skv: v_skv, (xc, skv))
            xc, new_cl = tfm.block_decode(pl, xc, cl, index, ctx, kind, cfg,
                                          mesh)
            return (xc, skv), new_cl

        idxs = jnp.arange(layer0, layer0 + n)
        if cfg.scan_layers and n > 1:
            (x, shared_kv), new_gc = jax.lax.scan(body, (x, shared_kv),
                                                  (gp, gc, idxs))
        else:
            ncs = []
            for i in range(n):
                (x, shared_kv), nc = body(
                    (x, shared_kv),
                    (_layer_slice(gp, i), _layer_slice(gc, i), idxs[i]))
                ncs.append(nc)
            new_gc = jax.tree.map(lambda *a: jnp.stack(a), *ncs)
        new_group_caches.append(new_gc)
        layer0 += n
    new_cache = dict(cache)
    new_cache["groups"] = tuple(new_group_caches)
    if shared_kv is not None:
        new_cache["shared"] = shared_kv
    return x, new_cache


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                      dtype):
    mixer, ffn = kind.split("+")
    c: dict = {}
    if mixer == "attn":
        c["kv"] = attn_mod.init_attention_cache(cfg, batch, cache_len, dtype)
    elif mixer == "swa":
        c["kv"] = attn_mod.init_attention_cache(cfg, batch, cache_len, dtype,
                                                window=cfg.window_size)
    elif mixer == "mla":
        c["kv"] = attn_mod.init_mla_cache(cfg, batch, cache_len, dtype)
    elif mixer == "mamba2":
        c["ssm"] = m2.init_mamba2_state(cfg, batch, dtype)
    elif mixer == "rwkv6":
        st = rk.init_rwkv6_state(cfg, batch, dtype)
        c["tmix"] = st["tmix"]
    if ffn == "rwkv_cm":
        st = rk.init_rwkv6_state(cfg, batch, dtype)
        c["cmix"] = st["cmix"]
    if cfg.is_encoder_decoder:
        c["cross"] = {
            "k": jnp.zeros((batch, cfg.encoder_seq_len, cfg.num_kv_heads,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((batch, cfg.encoder_seq_len, cfg.num_kv_heads,
                            cfg.head_dim), dtype),
        }
    return c


def _init_shared_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    n_apps = (cfg.num_layers + cfg.shared_attn_every - 1) // cfg.shared_attn_every
    w = cfg.shared_attn_window or cache_len
    single = attn_mod.init_attention_cache(cfg, batch, cache_len, dtype,
                                           window=w)
    return jax.tree.map(lambda a: jnp.zeros((n_apps,) + a.shape, a.dtype),
                        single)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    dtype = to_dtype(cfg.dtype)
    groups = []
    for kind, n in tfm.layer_groups(cfg):
        single = _init_block_cache(cfg, kind, batch, cache_len, dtype)
        groups.append(jax.tree.map(
            lambda a: jnp.zeros((n,) + a.shape, a.dtype), single))
    cache = {"groups": tuple(groups), "index": jnp.zeros((), jnp.int32)}
    if cfg.shared_attn_every:
        cache["shared"] = _init_shared_cache(cfg, batch, cache_len, dtype)
    return cache


# ---------------------------------------------------------------------------
# Top-level steps
# ---------------------------------------------------------------------------

def _positions_for(cfg: ModelConfig, batch: dict, b: int, s: int):
    if cfg.rope_kind == "mrope":
        return batch["mrope_positions"]
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))


def _encoder_fwd(params, cfg: ModelConfig, enc_embeds, mesh):
    enc = params["encoder"]
    b, s, _ = enc_embeds.shape
    x = enc_embeds + enc["pos_emb"][None, :s]
    ctx = {"positions": jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s)),
           "causal": False, "enc_out": None, "mesh": mesh,
           "data_axes": _dp_axes(mesh), "model_axis": "model"}
    ecfg = dataclasses.replace(cfg, is_encoder_decoder=False,
                               rope_kind="none", shared_attn_every=0)
    x, _ = _run_groups_fwd({"groups": enc["groups"]}, x, ctx, ecfg, mesh,
                           groups=enc["groups"], enc_mode=True)
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def _assemble_inputs(params, cfg: ModelConfig, batch, mesh):
    """Returns (x, ctx, b, s_total)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    if cfg.num_patch_tokens and "prefix_embeds" in batch:
        s_total = tokens.shape[1] + batch["prefix_embeds"].shape[1]
    else:
        s_total = tokens.shape[1]
    positions = _positions_for(cfg, batch, b, s_total)
    tok_positions = positions if cfg.rope_kind != "mrope" else None
    if cfg.num_patch_tokens and "prefix_embeds" in batch:
        pe = batch["prefix_embeds"].astype(to_dtype(cfg.dtype))
        te = _embed(params, cfg, tokens,
                    None if tok_positions is None else
                    tok_positions[:, pe.shape[1]:])
        x = jnp.concatenate([pe, te], axis=1)
    else:
        x = _embed(params, cfg, tokens, tok_positions)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _encoder_fwd(params, cfg,
                               batch["enc_embeds"].astype(to_dtype(cfg.dtype)),
                               mesh)
    ctx = {"positions": positions, "enc_out": enc_out, "causal": True,
           "mesh": mesh, "data_axes": _dp_axes(mesh), "model_axis": "model"}
    return x, ctx, b, s_total


def forward(params, batch: dict, cfg: ModelConfig, mesh=None):
    """Full-sequence forward (training).  Returns (logits, aux_loss)."""
    x, ctx, b, s = _assemble_inputs(params, cfg, batch, mesh)
    x = _constrain(x, mesh, jax.sharding.PartitionSpec(("pod", "data"), None, None))
    x, aux = _run_groups_fwd(params, x, ctx, cfg, mesh)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head(params, cfg, x)
    logits = _constrain(logits, mesh,
                        jax.sharding.PartitionSpec(("pod", "data"), None, "model"))
    return logits, aux


def forward_hidden(params, batch: dict, cfg: ModelConfig, mesh=None):
    """Like forward but returns pre-final-norm hidden states (for MTP)."""
    x, ctx, _, _ = _assemble_inputs(params, cfg, batch, mesh)
    x, aux = _run_groups_fwd(params, x, ctx, cfg, mesh)
    return x, aux


def mtp_logits(params, hidden, tokens, cfg: ModelConfig, mesh=None):
    """DeepSeek-V3 multi-token prediction head (depth 1): from hidden state
    h_t and the embedding of token t+1, predict token t+2."""
    p = params["mtp"]
    h = rms_norm(hidden[:, :-1], p["norm_h"], cfg.norm_eps)
    e = rms_norm(_embed(params, cfg, tokens[:, 1:]), p["norm_e"], cfg.norm_eps)
    z = linear(jnp.concatenate([h, e], axis=-1), p["proj"])
    b, s, _ = z.shape
    ctx = {"positions": jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s)),
           "enc_out": None, "causal": True, "mesh": mesh,
           "data_axes": _dp_axes(mesh), "model_axis": "model"}
    z, aux = tfm.block_fwd(p["block"], z, ctx, cfg.blocks[-1], cfg, mesh)
    z = rms_norm(z, params["final_norm"], cfg.norm_eps)
    return _head(params, cfg, z), aux


def prefill(params, batch: dict, cfg: ModelConfig, mesh=None,
            cache_len: Optional[int] = None):
    """Process the whole prompt; returns (last_logits, cache)."""
    x, ctx, b, s = _assemble_inputs(params, cfg, batch, mesh)
    x = _constrain(x, mesh, jax.sharding.PartitionSpec(("pod", "data"), None, None))
    cache_len = cache_len or s
    x, aux, group_caches, shared_kv = _run_groups_prefill(
        params, x, ctx, cfg, mesh, cache_len)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = _head(params, cfg, x)
    cache = {"groups": group_caches,
             "index": jnp.asarray(s, jnp.int32)}
    if shared_kv is not None:
        cache["shared"] = shared_kv
    return logits[:, 0], cache


def decode_step(params, cache: dict, token, cfg: ModelConfig, mesh=None,
                mrope_positions=None):
    """One serve step: one new token per sequence against the cache.

    token: (B, 1) int32.  Returns (logits (B, V), new_cache)."""
    index = cache["index"]
    b = token.shape[0]
    if cfg.rope_kind == "mrope":
        positions = (mrope_positions if mrope_positions is not None
                     else jnp.broadcast_to(index, (3, b, 1)).astype(jnp.int32))
    else:
        positions = jnp.broadcast_to(index, (b, 1)).astype(jnp.int32)
    tok_positions = positions if cfg.rope_kind != "mrope" else None
    x = _embed(params, cfg, token, tok_positions)
    ctx = {"positions": positions, "enc_out": None, "causal": True,
           "mesh": mesh, "data_axes": _dp_axes(mesh), "model_axis": "model"}
    x, new_cache = _run_groups_decode(params, x, cache, index, ctx, cfg, mesh)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head(params, cfg, x)
    new_cache["index"] = index + 1
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# Model namespace + input specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    mesh: Any = None

    def init(self, key):
        return init_params(key, self.cfg)

    def forward(self, params, batch):
        return forward(params, batch, self.cfg, self.mesh)

    def forward_hidden(self, params, batch):
        return forward_hidden(params, batch, self.cfg, self.mesh)

    def mtp_logits(self, params, hidden, tokens):
        return mtp_logits(params, hidden, tokens, self.cfg, self.mesh)

    def prefill(self, params, batch, cache_len=None):
        return prefill(params, batch, self.cfg, self.mesh, cache_len)

    def decode_step(self, params, cache, token, mrope_positions=None):
        return decode_step(params, cache, token, self.cfg, self.mesh,
                           mrope_positions)

    def init_cache(self, batch: int, cache_len: int):
        return init_cache(self.cfg, batch, cache_len)


def build_model(cfg: ModelConfig, mesh=None) -> Model:
    return Model(cfg=cfg, mesh=mesh)


def input_specs(cfg: ModelConfig, shape: InputShape | str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a given shape.

    The modality frontends are stubs per the assignment carve-out: audio
    supplies (B, encoder_seq_len, d) frame embeddings, VLM supplies
    (B, num_patch_tokens, d) patch embeddings.
    """
    if isinstance(shape, str):
        shape = INPUT_SHAPES[shape]
    b, s = shape.global_batch, shape.seq_len
    f32 = to_dtype(cfg.dtype)
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    specs: dict = {}
    if shape.kind in ("train", "prefill"):
        s_text = s
        if cfg.num_patch_tokens:
            s_text = s - cfg.num_patch_tokens
            specs["prefix_embeds"] = sds((b, cfg.num_patch_tokens,
                                          cfg.d_model), f32)
            specs["mrope_positions"] = sds((3, b, s), i32)
        specs["tokens"] = sds((b, s_text), i32)
        if shape.kind == "train":
            specs["labels"] = sds((b, s_text), i32)
        if cfg.is_encoder_decoder:
            specs["enc_embeds"] = sds((b, cfg.encoder_seq_len, cfg.d_model),
                                      f32)
    else:  # decode
        specs["token"] = sds((b, 1), i32)
        if cfg.rope_kind == "mrope":
            specs["mrope_positions"] = sds((3, b, 1), i32)
    return specs
