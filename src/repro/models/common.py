"""Shared numeric helpers for the model zoo (pure functional, pytree params)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
Params = Any


def to_dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def dense_init(key, shape, dtype, fan_in: int | None = None) -> Array:
    """Truncated-normal init scaled by 1/sqrt(fan_in)."""
    if fan_in is None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype) -> Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def rms_norm(x: Array, scale: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def linear(x: Array, w: Array) -> Array:
    """x @ w with f32 accumulation on the MXU."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


def softcap(logits: Array, cap: float) -> Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# RoPE (standard, and Qwen2-VL's multimodal 3-D M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions_3d: Array, theta: float,
                sections: tuple[int, int, int]) -> Array:
    """Qwen2-VL M-RoPE. x: (B,S,H,D); positions_3d: (3,B,S) (t,h,w ids).

    The d/2 frequency slots are partitioned into three sections which take
    their rotation angle from the temporal / height / width position id
    respectively [arXiv:2409.12191].
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(d, theta)                      # (half,)
    parts, start = [], 0
    for i, sec in enumerate(sections):
        f = freqs[start:start + sec]
        parts.append(positions_3d[i][..., None].astype(jnp.float32) * f)
        start += sec
    angles = jnp.concatenate(parts, axis=-1)          # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
