"""Stack assembly: blocks, scan-over-layers, prefill/decode plumbing.

A model is a sequence of homogeneous *groups* of blocks (e.g. DeepSeek-V3 is
3x "mla+mlp" then 58x "mla+moe"); each group is init'd with stacked params
(leading L dim) and executed with ``lax.scan`` so the HLO stays compact for
61-layer models.  Zamba2's single SHARED attention block is closed over by
the scan body and applied every ``shared_attn_every`` layers via
``lax.cond``, with its per-application KV cache carried through the scan.
"""
from __future__ import annotations

from itertools import groupby

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import rwkv6 as rk
from repro.models.common import linear, rms_norm
from repro.models.mlp import init_mlp, mlp_fwd
from repro.models.moe import init_moe, moe_fwd, moe_fwd_ep

EP_TOKEN_THRESHOLD = 4096  # below this, the single-shard MoE path is used


def layer_groups(cfg: ModelConfig) -> list[tuple[str, int]]:
    return [(kind, len(list(g))) for kind, g in groupby(cfg.blocks)]


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------

def _ffn_dim(cfg: ModelConfig, kind: str) -> int:
    return cfg.d_ff


def init_block(key, cfg: ModelConfig, kind: str, dtype):
    mixer, ffn = kind.split("+")
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: dict = {"norm1": jnp.zeros((d,), dtype)}
    if mixer in ("attn", "swa"):
        p["attn"] = attn.init_attention(ks[0], cfg, dtype)
    elif mixer == "mla":
        p["mla"] = attn.init_mla(ks[0], cfg, dtype)
    elif mixer == "mamba2":
        p["mamba"] = m2.init_mamba2(ks[0], cfg, dtype)
    elif mixer == "rwkv6":
        p["tmix"] = rk.init_rwkv6_tmix(ks[0], cfg, dtype)
    if ffn != "none":
        p["norm2"] = jnp.zeros((d,), dtype)
    if ffn == "mlp":
        p["mlp"] = init_mlp(ks[1], d, _ffn_dim(cfg, kind), cfg.mlp_kind, dtype)
    elif ffn == "moe":
        p["moe"] = init_moe(ks[1], cfg, dtype)
    elif ffn == "rwkv_cm":
        p["cmix"] = rk.init_rwkv6_cmix(ks[1], cfg, dtype)
    if cfg.is_encoder_decoder:
        p["norm_cross"] = jnp.zeros((d,), dtype)
        p["cross"] = attn.init_attention(ks[2], cfg, dtype)
    return p


def init_shared_attn(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    d = cfg.d_model
    return {
        "norm1": jnp.zeros((d,), dtype),
        "attn": attn.init_attention(ks[0], cfg, dtype),
        "norm2": jnp.zeros((d,), dtype),
        "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_kind, dtype),
    }


# ---------------------------------------------------------------------------
# Block forward (train — no caches) / prefill (returns caches) / decode
# ---------------------------------------------------------------------------

def _mixer_fwd(p, x, ctx, mixer, cfg, state=None):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if mixer in ("attn", "swa"):
        w = cfg.window_size if mixer == "swa" else 0
        return attn.attention_fwd(p["attn"], h, ctx["positions"], cfg,
                                  window=w, causal=ctx.get("causal", True),
                                  mesh=ctx.get("mesh")), None
    if mixer == "mla":
        return attn.mla_fwd(p["mla"], h, ctx["positions"], cfg), None
    if mixer == "mamba2":
        y, st = m2.mamba2_fwd(p["mamba"], h, cfg, state)
        return y, st
    if mixer == "rwkv6":
        y, st = rk.rwkv6_tmix_fwd(p["tmix"], h, cfg, state)
        return y, st
    raise ValueError(mixer)


def _ffn_fwd(p, x, ctx, ffn, cfg, mesh, state=None):
    if ffn == "none":
        return jnp.zeros_like(x), jnp.float32(0.0), None
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if ffn == "mlp":
        return mlp_fwd(p["mlp"], h, cfg.mlp_kind), jnp.float32(0.0), None
    if ffn == "moe":
        if mesh is not None:
            y, aux = moe_fwd_ep(p["moe"], h, cfg, mesh,
                                ctx["data_axes"], ctx["model_axis"])
        else:
            y, aux = moe_fwd(p["moe"], h, cfg)
        return y, aux, None
    if ffn == "rwkv_cm":
        y, st = rk.rwkv6_cmix_fwd(p["cmix"], h, cfg, state)
        return y, jnp.float32(0.0), st
    raise ValueError(ffn)


def _cross_fwd(p, x, ctx, cfg):
    h = rms_norm(x, p["norm_cross"], cfg.norm_eps)
    return attn.attention_fwd(p["cross"], h, ctx["positions"], cfg,
                              causal=False, kv_x=ctx["enc_out"])


def block_fwd(p, x, ctx, kind, cfg: ModelConfig, mesh):
    mixer, ffn = kind.split("+")
    y, _ = _mixer_fwd(p, x, ctx, mixer, cfg)
    x = x + y
    if cfg.is_encoder_decoder and ctx.get("enc_out") is not None:
        x = x + _cross_fwd(p, x, ctx, cfg)
    y, aux, _ = _ffn_fwd(p, x, ctx, ffn, cfg, mesh)
    x = x + y
    return x, aux


# -- prefill: same math, but also build the decode cache ---------------------

def _write_kv_cache(k, v, positions, cache_size, window):
    """Arrange full-sequence K/V (B,S,KV,D) into a decode cache.

    Full attention: cache[:, :S] = kv (cache_size >= S).
    SWA: ring buffer of size window — slot p%W holds position p (last W)."""
    b, s, kvh, d = k.shape
    if window > 0:
        w = min(window, cache_size)
        take = min(s, w)
        ks_, vs_ = k[:, -take:], v[:, -take:]
        pos = positions[0, -take:] % w
        ck = jnp.zeros((b, w, kvh, d), k.dtype).at[:, pos].set(ks_)
        cv = jnp.zeros((b, w, kvh, d), v.dtype).at[:, pos].set(vs_)
        return {"k": ck, "v": cv}
    pad = cache_size - s
    ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": ck, "v": cv}


def _attn_prefill(p, h, ctx, cfg, window, cache_size):
    """Attention fwd that also returns the populated decode cache."""
    b, s, _ = h.shape
    hh = cfg.num_heads
    kvh, d = cfg.num_kv_heads, cfg.head_dim
    q = linear(h, p["wq"]).reshape(b, s, hh, d)
    k = linear(h, p["wk"]).reshape(b, s, kvh, d)
    v = linear(h, p["wv"]).reshape(b, s, kvh, d)
    positions = ctx["positions"]
    if cfg.rope_kind in ("standard", "mrope"):
        q, k = attn._rope_qk(q, k, positions, cfg)
    qp = positions if cfg.rope_kind != "mrope" else positions[0]
    mesh = ctx.get("mesh")
    bp_axes = (attn._bp_spec(mesh, b)
               if (mesh is not None and cfg.attn_batch_parallel) else None)
    if bp_axes:
        q = attn._bp_constrain(q, mesh, bp_axes)
        k = attn._bp_constrain(k, mesh, bp_axes)
        v = attn._bp_constrain(v, mesh, bp_axes)
    # Pallas swa_prefill kernel route (serving prefill): the kernel is
    # causal-SWA, so full attention is window >= S; usable when nothing
    # needs the pure-jnp path's extras (softcap, batch-parallel shards,
    # non-divisible block shapes)
    if (cfg.use_pallas_prefill and cfg.logit_softcap == 0
            and bp_axes is None and (s <= 256 or s % 256 == 0)):
        from repro.kernels.swa_prefill.ops import swa_prefill_attention
        out = swa_prefill_attention(q, k, v,
                                    window=window if window > 0 else s,
                                    block=min(256, s))
    else:
        out = attn.blocked_attention(q, k, v, qp, qp, causal=True,
                                     window=window, scale=d ** -0.5,
                                     cap=cfg.logit_softcap)
    if bp_axes:
        out = attn._bp_constrain(out, mesh, bp_axes)
    y = linear(out.reshape(b, s, hh * d), p["wo"])
    cache = _write_kv_cache(k, v, qp, cache_size, window)
    return y, cache


def _mla_prefill(p, h, ctx, cfg, cache_size):
    b, s, _ = h.shape
    q_nope, q_rope, c_kv, k_rope = attn._mla_qkv(p, h, ctx["positions"], cfg)
    hh, nope, rope_d = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    vd = cfg.v_head_dim
    k_nope = linear(c_kv, p["w_uk"]).reshape(b, s, hh, nope)
    v = linear(c_kv, p["w_uv"]).reshape(b, s, hh, vd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, hh, rope_d))], axis=-1)
    out = attn.blocked_attention(q, k, v, ctx["positions"], ctx["positions"],
                                 causal=True, window=0,
                                 scale=(nope + rope_d) ** -0.5)
    y = linear(out.reshape(b, s, hh * vd), p["wo"])
    pad = cache_size - s
    cache = {"c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
             "k_rope": jnp.pad(k_rope[:, :, 0], ((0, 0), (0, pad), (0, 0)))}
    return y, cache


def block_prefill(p, x, ctx, kind, cfg: ModelConfig, mesh, cache_size):
    mixer, ffn = kind.split("+")
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    cache: dict = {}
    if mixer in ("attn", "swa"):
        w = cfg.window_size if mixer == "swa" else 0
        y, cache["kv"] = _attn_prefill(p["attn"], h, ctx, cfg, w, cache_size)
    elif mixer == "mla":
        y, cache["kv"] = _mla_prefill(p["mla"], h, ctx, cfg, cache_size)
    elif mixer == "mamba2":
        y, cache["ssm"] = m2.mamba2_fwd(p["mamba"], h, cfg, None)
    elif mixer == "rwkv6":
        y, cache["tmix"] = rk.rwkv6_tmix_fwd(p["tmix"], h, cfg, None)
    x = x + y
    if cfg.is_encoder_decoder and ctx.get("enc_out") is not None:
        hc = rms_norm(x, p["norm_cross"], cfg.norm_eps)
        enc = ctx["enc_out"]
        b, se = enc.shape[:2]
        kvh, d = cfg.num_kv_heads, cfg.head_dim
        ck = linear(enc, p["cross"]["wk"]).reshape(b, se, kvh, d)
        cv = linear(enc, p["cross"]["wv"]).reshape(b, se, kvh, d)
        cache["cross"] = {"k": ck, "v": cv}
        y = attn.attention_fwd(p["cross"], hc, ctx["positions"], cfg,
                               causal=False, kv_x=enc)
        x = x + y
    aux = jnp.float32(0.0)
    if ffn != "none":
        y, aux, st = _ffn_fwd(p, x, ctx, ffn, cfg, mesh)
        if st is not None:
            cache["cmix"] = st
        x = x + y
    return x, aux, cache


# -- decode -------------------------------------------------------------------

def _cross_decode(p, x, cache, ctx, cfg):
    """Cross-attention at decode using precomputed encoder K/V."""
    b = x.shape[0]
    hh, kvh, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    h = rms_norm(x, p["norm_cross"], cfg.norm_eps)
    q = linear(h, p["cross"]["wq"]).reshape(b, 1, hh, d)
    g = hh // kvh
    qf = (q.reshape(b, kvh, g, d) * (d ** -0.5)).astype(jnp.float32)
    ck, cv = cache["k"], cache["v"]
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, ck.astype(jnp.float32))
    pr = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", pr, cv.astype(jnp.float32))
    out = out.reshape(b, 1, hh * d).astype(x.dtype)
    return linear(out, p["cross"]["wo"])


def block_decode(p, x, cache, index, ctx, kind, cfg: ModelConfig, mesh=None):
    mixer, ffn = kind.split("+")
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    new_cache = dict(cache)
    if mixer in ("attn", "swa"):
        w = cfg.window_size if mixer == "swa" else 0
        y, new_cache["kv"] = attn.attention_decode(
            p["attn"], h, cache["kv"], index, ctx["positions"], cfg, window=w)
    elif mixer == "mla":
        y, new_cache["kv"] = attn.mla_decode(
            p["mla"], h, cache["kv"], index, ctx["positions"], cfg)
    elif mixer == "mamba2":
        y, new_cache["ssm"] = m2.mamba2_decode(p["mamba"], h, cfg, cache["ssm"])
    elif mixer == "rwkv6":
        y, new_cache["tmix"] = rk.rwkv6_tmix_fwd(p["tmix"], h, cfg,
                                                 cache["tmix"])
    x = x + y
    if cfg.is_encoder_decoder and "cross" in cache:
        x = x + _cross_decode(p, x, cache["cross"], ctx, cfg)
    if ffn != "none":
        hf = rms_norm(x, p["norm2"], cfg.norm_eps)
        if ffn == "mlp":
            y = mlp_fwd(p["mlp"], hf, cfg.mlp_kind)
        elif ffn == "moe":
            if mesh is not None:
                y, _ = moe_fwd_ep(p["moe"], hf, cfg, mesh,
                                  ctx["data_axes"], ctx["model_axis"])
            else:
                y, _ = moe_fwd(p["moe"], hf, cfg)
        elif ffn == "rwkv_cm":
            y, new_cache["cmix"] = rk.rwkv6_cmix_fwd(p["cmix"], hf, cfg,
                                                     cache["cmix"])
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# Shared attention block (zamba2)
# ---------------------------------------------------------------------------

def shared_attn_fwd(p, x, ctx, cfg: ModelConfig):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    w = cfg.shared_attn_window
    y = attn.attention_fwd(p["attn"], h, ctx["positions"], cfg, window=w)
    x = x + y
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return x + mlp_fwd(p["mlp"], h, cfg.mlp_kind)


def shared_attn_prefill(p, x, ctx, cfg: ModelConfig, cache_size):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    w = cfg.shared_attn_window
    y, kv = _attn_prefill(p["attn"], h, ctx, cfg, w, cache_size)
    x = x + y
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return x + mlp_fwd(p["mlp"], h, cfg.mlp_kind), kv


def shared_attn_decode(p, x, kv, index, ctx, cfg: ModelConfig):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    w = cfg.shared_attn_window
    y, kv = attn.attention_decode(p["attn"], h, kv, index, ctx["positions"],
                                  cfg, window=w)
    x = x + y
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    return x + mlp_fwd(p["mlp"], h, cfg.mlp_kind), kv
