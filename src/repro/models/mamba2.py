"""Mamba2 (SSD) mixer — chunked scan for train/prefill, recurrent decode.

The chunked SSD formulation (intra-chunk masked matmuls on the MXU +
inter-chunk state carry via lax.scan) follows the Mamba2 paper's minimal
reference; ``repro.kernels.ssd_scan`` provides the Pallas TPU kernel for the
same computation and uses this module's math as its oracle.

Projections are split (w_zx / w_bc / w_dt) instead of one fused in_proj so
each piece gets a clean tensor-parallel sharding: the d_inner outputs shard
over "model" (80 SSD heads / 16 = 5 per chip for zamba2) while the shared
B/C (n_state=64, head-groups g=1) stay replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Array, dense_init, linear, rms_norm


def init_mamba2(key, cfg: ModelConfig, dtype):
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state_dim
    h = cfg.ssm_num_heads
    ks = jax.random.split(key, 6)
    return {
        "w_zx": dense_init(ks[0], (d, 2 * di), dtype),
        "w_bc": dense_init(ks[1], (d, 2 * n), dtype),
        "w_dt": dense_init(ks[2], (d, h), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.zeros((h,), jnp.float32),          # A = -exp(a_log)
        "d_skip": jnp.ones((h,), jnp.float32),
        "conv_x": dense_init(ks[3], (cfg.ssm_conv_width, di), dtype,
                             fan_in=cfg.ssm_conv_width),
        "conv_bc": dense_init(ks[4], (cfg.ssm_conv_width, 2 * n), dtype,
                              fan_in=cfg.ssm_conv_width),
        "norm": jnp.zeros((di,), dtype),
        "w_out": dense_init(ks[5], (di, d), dtype, fan_in=di),
    }


def _causal_conv(x: Array, w: Array, state: Array | None = None):
    """Depthwise causal conv along time.  x: (B, S, C); w: (W, C).

    Returns (y, new_state) where state is the trailing (W-1) inputs."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
            for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else state
    return jax.nn.silu(y), new_state


def ssd_chunked(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
                chunk: int = 128, h0: Array | None = None):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); b, c: (B, S, N);
    a_log: (H,).  Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    q = chunk

    xc = x.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bc = b.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, q, n).astype(jnp.float32)

    loga = -jnp.exp(a_log)[None, None, None, :] * dtc      # (B,nc,q,H) <= 0
    acum = jnp.cumsum(loga, axis=2)                         # inclusive
    dtx = xc * dtc[..., None]                               # (B,nc,q,H,P)

    # intra-chunk: S_ij = (C_i . B_j) * exp(acum_i - acum_j) for i >= j
    # (h_t = a_t h_{t-1} + dt_t B_t x_t: own-step input is NOT decayed)
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)              # (B,nc,q,q)
    decay = acum[:, :, :, None, :] - acum[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    gate = jnp.where(mask[None, None, :, :, None], jnp.exp(decay), 0.0)
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, gate, dtx)

    # per-chunk outgoing state (before adding incoming):
    # h_chunk = sum_j exp(acum_Q - acum_j) * dtx_j  (x)  B_j
    tail = acum[:, :, -1:, :]                               # (B,nc,1,H)
    sdecay = jnp.exp(tail - acum)                           # (B,nc,q,H)
    h_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bc, sdecay, dtx)
    chunk_gain = jnp.exp(tail[:, :, 0, :])                  # (B,nc,H)

    # inter-chunk recurrence over chunk index
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(hprev, inp):
        hc, gain = inp                                      # (B,H,P,N),(B,H)
        hout = hprev * gain[:, :, None, None] + hc
        return hout, hprev

    (h_final, h_in) = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (h_chunk.transpose(1, 0, 2, 3, 4), chunk_gain.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                    # (B,nc,H,P,N)

    # inter contribution: y_i += exp(acum_i) * C_i . h_in
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", cc, jnp.exp(acum), h_in)
    y = (y_diag + y_inter).reshape(bsz, nc * q, h, p)[:, :s]
    return y.astype(x.dtype), h_final


def mamba2_fwd(params, x: Array, cfg: ModelConfig, state: dict | None = None):
    """Full-sequence forward.  x: (B, S, d_model).  Returns (y, new_state)."""
    b, s, _ = x.shape
    di, n, h, p = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_num_heads, cfg.ssm_head_dim
    zx = linear(x, params["w_zx"])
    z, xin = zx[..., :di], zx[..., di:]
    bcin = linear(x, params["w_bc"])
    dt = jax.nn.softplus(linear(x, params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"])
    conv_x_state = state["conv_x"] if state else None
    conv_bc_state = state["conv_bc"] if state else None
    xc, conv_x_state = _causal_conv(xin, params["conv_x"], conv_x_state)
    bcc, conv_bc_state = _causal_conv(bcin, params["conv_bc"], conv_bc_state)
    bmat, cmat = bcc[..., :n], bcc[..., n:]
    xh = xc.reshape(b, s, h, p)
    h0 = state["h"] if state else None
    y, h_final = ssd_chunked(xh, dt, params["a_log"], bmat, cmat, h0=h0)
    y = y + xh.astype(jnp.float32).astype(y.dtype) * params["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = linear(y, params["w_out"])
    new_state = {"conv_x": conv_x_state, "conv_bc": conv_bc_state,
                 "h": h_final}
    return out, new_state


def mamba2_decode(params, x: Array, cfg: ModelConfig, state: dict):
    """Single-token recurrent step.  x: (B, 1, d_model)."""
    b = x.shape[0]
    di, n, h, p = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_num_heads, cfg.ssm_head_dim
    zx = linear(x, params["w_zx"])
    z, xin = zx[..., :di], zx[..., di:]
    bcin = linear(x, params["w_bc"])
    dt = jax.nn.softplus(linear(x, params["w_dt"]).astype(jnp.float32)
                         + params["dt_bias"])[:, 0]          # (B,H)
    xc, conv_x_state = _causal_conv(xin, params["conv_x"], state["conv_x"])
    bcc, conv_bc_state = _causal_conv(bcin, params["conv_bc"], state["conv_bc"])
    bmat, cmat = bcc[:, 0, :n], bcc[:, 0, n:]                # (B,N)
    xh = xc.reshape(b, h, p).astype(jnp.float32)
    a = jnp.exp(-jnp.exp(params["a_log"])[None, :] * dt)     # (B,H)
    dtx = xh * dt[..., None]
    hnew = (state["h"] * a[:, :, None, None]
            + jnp.einsum("bhp,bn->bhpn", dtx, bmat.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bn->bhp", hnew, cmat.astype(jnp.float32))
    y = y + xh * params["d_skip"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = linear(y, params["w_out"])
    return out, {"conv_x": conv_x_state, "conv_bc": conv_bc_state, "h": hnew}


def init_mamba2_state(cfg: ModelConfig, batch: int, dtype):
    di, n, h, p = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_num_heads, cfg.ssm_head_dim
    w = cfg.ssm_conv_width
    return {
        "conv_x": jnp.zeros((batch, w - 1, di), dtype),
        "conv_bc": jnp.zeros((batch, w - 1, 2 * n), dtype),
        "h": jnp.zeros((batch, h, p, n), jnp.float32),
    }
