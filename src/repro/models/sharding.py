"""Parameter/cache PartitionSpec rules (logical-axis style, path-regex based).

Megatron-style tensor parallelism over "model" + ZeRO-3/FSDP over
("pod","data") for the large matrices.  Rules are matched against the
parameter path (first match wins) and the spec is right-aligned against the
array rank (stacked-layer leading dims get None).
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


FSDP = ("pod", "data")


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=False):
    """Version-portable ``shard_map``.

    JAX >= 0.6 exposes ``jax.shard_map`` (replication checking controlled
    by ``check_vma``); the pinned 0.4.x line only has
    ``jax.experimental.shard_map.shard_map``, where the same switch is
    spelled ``check_rep``.  Resolve whichever exists and translate the
    kwarg so call sites can use the modern spelling everywhere.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=check_vma)

# (path regex, spec over trailing dims)
PARAM_RULES: list[tuple[str, P]] = [
    # embeddings / heads
    (r"embed$", P("model", FSDP)),
    (r"pos_emb$", P(None, "model")),
    (r"head$", P(FSDP, "model")),
    # attention
    (r"attn/w[qkv]$", P(FSDP, "model")),
    (r"attn/wo$", P("model", FSDP)),
    (r"cross/w[qkv]$", P(FSDP, "model")),
    (r"cross/wo$", P("model", FSDP)),
    # MLA
    (r"mla/w_dq$", P(FSDP, None)),
    (r"mla/w_uq$", P(FSDP, "model")),
    (r"mla/w_dkv$", P(FSDP, None)),
    (r"mla/w_uk$", P(FSDP, "model")),
    (r"mla/w_uv$", P(FSDP, "model")),
    (r"mla/wo$", P("model", FSDP)),
    # dense MLP
    (r"mlp/w_(gate|up)$", P(FSDP, "model")),
    (r"mlp/w_down$", P("model", FSDP)),
    # MoE (experts over model, FSDP over d_model dim)
    (r"moe/w[gu]$", P("model", FSDP, None)),
    (r"moe/wd$", P("model", None, FSDP)),
    (r"moe/router$", P()),
    (r"moe/router_bias$", P()),
    (r"moe/shared/w_(gate|up)$", P(FSDP, "model")),
    (r"moe/shared/w_down$", P("model", FSDP)),
    # mamba2
    (r"mamba/w_zx$", P(FSDP, "model")),
    (r"mamba/w_bc$", P(FSDP, None)),
    (r"mamba/w_dt$", P(FSDP, "model")),
    (r"mamba/conv_x$", P(None, "model")),
    (r"mamba/conv_bc$", P()),
    (r"mamba/norm$", P("model")),
    (r"mamba/w_out$", P("model", FSDP)),
    # rwkv6
    (r"tmix/w_[rkvg]$", P(FSDP, "model")),
    (r"tmix/w_o$", P("model", FSDP)),
    (r"tmix/decay_b$", P(None, "model")),
    (r"tmix/decay_base$", P("model")),
    (r"tmix/bonus_u$", P("model", None)),
    (r"tmix/(ln_scale|ln_bias)$", P("model")),
    (r"cmix/w_k$", P(FSDP, "model")),
    (r"cmix/w_v$", P("model", FSDP)),
    (r"cmix/w_r$", P(FSDP, None)),
    # everything else (norm scales, mus, biases, loras): replicated
    (r".*", P()),
]

CACHE_RULES: list[tuple[str, P]] = [
    # KV caches: batch over data axes, heads over model
    (r"kv/[kv]$", P(FSDP, None, "model", None)),
    (r"cross/[kv]$", P(FSDP, None, "model", None)),
    (r"shared.*/[kv]$", P(FSDP, None, "model", None)),
    # MLA latent cache: batch over data only (latent dim small)
    (r"kv/c_kv$", P(FSDP, None, None)),
    (r"kv/k_rope$", P(FSDP, None, None)),
    # SSM / RWKV states: batch over data, heads/channels over model
    (r"ssm/conv_x$", P(FSDP, None, "model")),
    (r"ssm/conv_bc$", P(FSDP, None, None)),
    (r"ssm/h$", P(FSDP, "model", None, None)),
    (r"tmix/shift$", P(FSDP, "model")),
    (r"tmix/wkv$", P(FSDP, "model", None, None)),
    (r"cmix/shift$", P(FSDP, "model")),
    (r"index$", P()),
    (r".*", P()),
]

BATCH_RULES: list[tuple[str, P]] = [
    (r"(tokens|labels|token)$", P(FSDP, None)),
    (r"prefix_embeds$", P(FSDP, None, None)),
    (r"enc_embeds$", P(FSDP, None, None)),
    (r"mrope_positions$", P(None, FSDP, None)),
    (r".*", P()),
]


def _match(path: str, rules) -> P:
    for pat, spec in rules:
        if re.search(pat, path):
            return spec
    return P()


def _fit_spec(spec: P, ndim: int, shape, mesh) -> P:
    """Right-align spec to ndim; drop axes that don't divide the dim."""
    entries = list(spec)
    if len(entries) > ndim:
        entries = entries[-ndim:]
    entries = [None] * (ndim - len(entries)) + entries
    fixed = []
    for dim, ax in zip(shape, entries):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        axes = tuple(a for a in axes if a in mesh.shape)
        n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if n <= 1 or dim % n != 0:
            # try a prefix of the axes tuple that divides
            while axes and (dim % int(np.prod([mesh.shape[a] for a in axes]))):
                axes = axes[:-1]
            if not axes:
                fixed.append(None)
                continue
        fixed.append(axes if len(axes) > 1 else axes[0])
    return P(*fixed)


def _specs_for(tree: Any, rules, mesh) -> Any:
    from repro.utils.tree import tree_map_with_path

    def fn(path, leaf):
        spec = _match(path, rules)
        return _fit_spec(spec, leaf.ndim, leaf.shape, mesh)

    return tree_map_with_path(fn, tree)


def param_specs(params_shape: Any, mesh, fsdp: bool = True) -> Any:
    """PartitionSpec pytree for a params (shape) pytree.

    fsdp=False (serving): drop the ("pod","data") ZeRO-3 axes from all
    non-expert params so decode steps do not all-gather weights every token
    (EXPERIMENTS.md §Perf, rwkv6 decode iteration).  MoE expert weights keep
    their two-axis sharding — the partial-sum EP path consumes them
    in place (moe_partial_ep)."""
    from repro.utils.tree import tree_map_with_path

    def fn(path, leaf):
        spec = _match(path, PARAM_RULES)
        if not fsdp and not re.search(r"moe/w[gud]$", path):
            spec = P(*[tuple(a for a in (ax if isinstance(ax, tuple)
                                         else (ax,)) if a not in FSDP) or None
                       if ax is not None else None for ax in spec])
            spec = P(*[ax[0] if isinstance(ax, tuple) and len(ax) == 1
                       else (None if isinstance(ax, tuple) and not ax else ax)
                       for ax in spec])
        return _fit_spec(spec, leaf.ndim, leaf.shape, mesh)

    return tree_map_with_path(fn, params_shape)


# decode-tuned cache rules: the cache SEQUENCE dim shards over "model", so
# each rank reads 1/n_model of the cache and the softmax reduces via a tiny
# all-reduce (EXPERIMENTS.md §Perf kimi decode iteration 2).  The in-place
# cache write (dynamic-update-slice at a traced index) stays local — GSPMD
# partitions DUS on a sharded dim without gathering (verified in the perf
# log).  Head-dim sharding is dropped (kv heads rarely divide 16).
CACHE_RULES_SEQSHARD: list[tuple[str, P]] = [
    (r"kv/[kv]$", P(FSDP, "model", None, None)),
    (r"cross/[kv]$", P(FSDP, "model", None, None)),
    (r"shared.*/[kv]$", P(FSDP, "model", None, None)),
    (r"kv/c_kv$", P(FSDP, "model", None)),
    (r"kv/k_rope$", P(FSDP, "model", None)),
] + CACHE_RULES[5:]


def cache_specs(cache_shape: Any, mesh, seq_shard: bool = False) -> Any:
    rules = CACHE_RULES_SEQSHARD if seq_shard else CACHE_RULES
    return _specs_for(cache_shape, rules, mesh)


def batch_specs(batch_shape: Any, mesh) -> Any:
    return _specs_for(batch_shape, BATCH_RULES, mesh)


def shardings(spec_tree: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
