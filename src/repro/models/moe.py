"""Mixture-of-Experts layer with expert-parallel shard_map execution.

Two execution paths share one sort-based capacity dispatch core:

* ``moe_fwd`` — single-device / GSPMD path (smoke tests, tiny token counts).
* ``moe_fwd_ep`` — production path under ``jax.shard_map``: tokens sharded
  over the ("pod", "data") axes, experts sharded over "model", expert weights
  additionally FSDP-sharded over ("pod", "data") on the d_model dim and
  all-gathered inside the shard (ZeRO-3 style).  Each model rank dispatches
  its data shard's tokens to its local experts (no token all-to-all needed in
  the replicated-activation scheme); outputs are combined with a psum over
  "model".  See DESIGN.md §5.

Dispatch is sort-based (argsort by expert id + capacity clamp) instead of the
GShard one-hot einsum, so the dispatch tensor is O(T·k) indices rather than
O(T·E·C) one-hots — the difference between 587 MB and 4 GB per device at the
prefill_32k shape.

Routing supports softmax top-k (classic) and the DeepSeek-V3 sigmoid scoring
with a bias-balanced, aux-loss-free flavor (bias buffer held in params but
updated outside the gradient), plus the standard load-balance aux loss.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import Array, dense_init
from repro.models.mlp import init_mlp, mlp_fwd
from repro.models.sharding import shard_map_compat


def init_moe(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "router_bias": jnp.zeros((e,), jnp.float32),   # aux-free balance buffer
        "wg": dense_init(ks[1], (e, d, f), dtype),
        "wu": dense_init(ks[2], (e, d, f), dtype),
        "wd": dense_init(ks[3], (e, f, d), dtype, fan_in=f),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * cfg.num_shared_experts,
                               "swiglu", dtype)
    return p


def route_topk(logits: Array, bias: Array, k: int, kind: str):
    """Returns (weights (T,k), ids (T,k), probs (T,E)) for aux loss."""
    if kind == "sigmoid":  # DeepSeek-V3: sigmoid scores, bias only for topk
        scores = jax.nn.sigmoid(logits.astype(jnp.float32))
        _, ids = jax.lax.top_k(scores + bias[None, :], k)
        w = jnp.take_along_axis(scores, ids, axis=-1)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        w, ids = jax.lax.top_k(probs, k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, ids, probs


def load_balance_aux(probs: Array, ids: Array, num_experts: int) -> Array:
    """GShard/Switch aux loss: E * sum_i f_i * P_i (local-batch estimate)."""
    t = probs.shape[0]
    f = jnp.zeros((num_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = f / jnp.maximum(t * ids.shape[1], 1)
    p = probs.mean(axis=0)
    return num_experts * jnp.sum(f * p)


def capacity_for(tokens: int, k: int, num_experts: int, cf: float) -> int:
    """Static per-shard expert capacity.  Small token counts (decode) get a
    zero-drop floor; large counts get the classic cf-scaled capacity."""
    c = int(math.ceil(tokens * k * cf / num_experts))
    c = max(c, min(tokens * k, 8))
    c = min(c, tokens * k)
    return int(math.ceil(c / 4) * 4) if c > 8 else c


def _expert_ffn(wg: Array, wu: Array, wd: Array, xb: Array) -> Array:
    """Batched expert SwiGLU: xb (E, C, d) -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", xb, wg,
                   preferred_element_type=jnp.float32).astype(xb.dtype)
    u = jnp.einsum("ecd,edf->ecf", xb, wu,
                   preferred_element_type=jnp.float32).astype(xb.dtype)
    a = jax.nn.silu(h) * u
    return jnp.einsum("ecf,efd->ecd", a, wd,
                      preferred_element_type=jnp.float32).astype(xb.dtype)


def _dispatch_compute_combine(x: Array, ids: Array, w: Array, wg, wu, wd,
                              capacity: int, e_lo: int, e_local: int) -> Array:
    """Sort-based capacity dispatch -> expert FFN -> weighted combine.

    x: (T, d); ids/w: (T, k) with GLOBAL expert ids; computes only experts in
    [e_lo, e_lo + e_local) (pass 0, E for the non-EP path).  Returns the
    partial output (T, d) (zero contribution for non-local / dropped pairs).
    """
    t, d = x.shape
    k = ids.shape[1]
    flat_ids = ids.reshape(-1)
    flat_w = w.reshape(-1).astype(jnp.float32)
    local = (flat_ids >= e_lo) & (flat_ids < e_lo + e_local)
    lids = jnp.clip(flat_ids - e_lo, 0, e_local - 1)

    order = jnp.argsort(jnp.where(local, lids, e_local), stable=True)
    sid = lids[order]
    s_local = local[order]
    s_w = flat_w[order]
    s_tok = order // k

    counts = jnp.zeros((e_local,), jnp.int32).at[lids].add(local.astype(jnp.int32))
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k, dtype=jnp.int32) - offsets[sid]
    keep = s_local & (pos < capacity)
    trash = e_local * capacity
    slot = jnp.where(keep, sid * capacity + pos, trash)

    buf = jnp.zeros((e_local * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(x[s_tok], mode="drop")
    xb = buf[:-1].reshape(e_local, capacity, d)

    yb = _expert_ffn(wg, wu, wd, xb).reshape(e_local * capacity, d)
    contrib = yb[jnp.minimum(slot, trash - 1)].astype(jnp.float32)
    contrib = contrib * (s_w * keep.astype(jnp.float32))[:, None]
    y = jnp.zeros((t, d), jnp.float32).at[s_tok].add(contrib)
    return y.astype(x.dtype)


def moe_fwd(params, x: Array, cfg: ModelConfig):
    """Single-shard MoE (reference / smoke / tiny-token path).

    x: (B, S, d).  Returns (y, aux_loss).
    """
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    w, ids, probs = route_topk(logits, params["router_bias"],
                               cfg.num_experts_per_tok, cfg.moe_router_kind)
    aux = load_balance_aux(probs, ids, cfg.num_experts)
    cap = capacity_for(b * s, cfg.num_experts_per_tok, cfg.num_experts,
                       cfg.moe_capacity_factor)
    y = _dispatch_compute_combine(xt, ids, w, params["wg"], params["wu"],
                                  params["wd"], cap, 0, cfg.num_experts)
    if "shared" in params:
        y = y + mlp_fwd(params["shared"], xt, "swiglu")
    return y.reshape(b, s, d), aux


PARTIAL_EP_MAX_TOKENS = 4096


def moe_fwd_ep(params, x: Array, cfg: ModelConfig, mesh: jax.sharding.Mesh,
               data_axes: tuple, model_axis: str):
    """Expert-parallel MoE under shard_map.  x: (B, S, d) with B sharded over
    ``data_axes``.  Returns (y, aux_loss)."""
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    n_data = math.prod(mesh.shape[a] for a in data_axes)
    n_model = mesh.shape[model_axis]
    e_local = cfg.num_experts // n_model
    if (cfg.moe_partial_ep and b * s <= PARTIAL_EP_MAX_TOKENS
            and d % n_data == 0):
        return _moe_fwd_partial_ep(params, x, cfg, mesh, data_axes,
                                   model_axis)
    t_local = (b * s) // n_data
    cap = capacity_for(t_local, cfg.num_experts_per_tok, cfg.num_experts,
                       cfg.moe_capacity_factor)

    def shard_fn(xt, router, router_bias, wg, wu, wd):
        # xt: (T_local, d); wg/wu/wd: (E_local, d/n_data, f) -> FSDP gather
        wg = jax.lax.all_gather(wg, data_axes, axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, data_axes, axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, data_axes, axis=2, tiled=True)
        logits = xt.astype(jnp.float32) @ router
        w, ids, probs = route_topk(logits, router_bias,
                                   cfg.num_experts_per_tok, cfg.moe_router_kind)
        aux = load_balance_aux(probs, ids, cfg.num_experts)
        aux = jax.lax.pmean(aux, data_axes)
        e_lo = jax.lax.axis_index(model_axis) * e_local
        y = _dispatch_compute_combine(xt, ids, w, wg, wu, wd, cap,
                                      e_lo, e_local)
        y = jax.lax.psum(y, model_axis)
        return y, aux

    xt = x.reshape(b * s, d)
    dspec = P(data_axes, None)
    y, aux = shard_map_compat(
        shard_fn, mesh=mesh,
        in_specs=(dspec, P(), P(), P(model_axis, data_axes, None),
                  P(model_axis, data_axes, None), P(model_axis, None, data_axes)),
        out_specs=(dspec, P()),
        check_vma=False,
    )(xt, params["router"], params["router_bias"],
      params["wg"], params["wu"], params["wd"])
    if "shared" in params:
        y = y + mlp_fwd(params["shared"], xt, "swiglu")
    return y.reshape(b, s, d), aux


def _moe_fwd_partial_ep(params, x: Array, cfg: ModelConfig, mesh,
                        data_axes: tuple, model_axis: str):
    """Serving-path MoE: d-sliced partial-sum expert compute.

    The FSDP gather in the training path moves the FULL expert weight set
    over ICI every step — fatal at decode (kimi-k2: ~6 GB/layer gathered to
    serve 8 local tokens; see EXPERIMENTS.md §Perf).  Here every chip keeps
    its resident (E/n_model, d/n_data, f) weight slice and computes partial
    matmuls over its d-slice; the tiny token activations move instead:

        all-gather tokens over data  (T x d, ~2 MB at decode_32k)
        partial h/u = x_slice @ w_slice ; psum over data
        y_slice = a @ wd_slice        ; psum over model + gather d over data

    Collective volume per layer drops from O(E d f / n_data) to O(T d + E_l
    C f) — weights never move.
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    t = b * s
    n_data = math.prod(mesh.shape[a] for a in data_axes)
    n_model = mesh.shape[model_axis]
    e_local = cfg.num_experts // n_model
    d_shard = d // n_data
    t_local = t // n_data
    cap = capacity_for(t, cfg.num_experts_per_tok, cfg.num_experts,
                       cfg.moe_capacity_factor)

    def shard_fn(xt_local, router, router_bias, wg, wu, wd):
        # xt_local: (T_local, d); w*: (E_local, d_shard, f) resident slices
        xt = jax.lax.all_gather(xt_local, data_axes, axis=0, tiled=True)
        logits = xt.astype(jnp.float32) @ router
        w, ids, probs = route_topk(logits, router_bias,
                                   cfg.num_experts_per_tok,
                                   cfg.moe_router_kind)
        aux = load_balance_aux(probs, ids, cfg.num_experts)
        e_lo = jax.lax.axis_index(model_axis) * e_local
        # data-rank index (possibly over a ("pod","data") tuple)
        didx = jnp.int32(0)
        stride = 1
        for a in reversed(data_axes):
            didx = didx + jax.lax.axis_index(a) * stride
            stride *= mesh.shape[a]

        # dispatch in full-d, then slice this rank's d range
        k = ids.shape[1]
        flat_ids = ids.reshape(-1)
        flat_w = w.reshape(-1).astype(jnp.float32)
        local = (flat_ids >= e_lo) & (flat_ids < e_lo + e_local)
        lids = jnp.clip(flat_ids - e_lo, 0, e_local - 1)
        order = jnp.argsort(jnp.where(local, lids, e_local), stable=True)
        sid = lids[order]
        s_local = local[order]
        s_w = flat_w[order]
        s_tok = order // k
        counts = jnp.zeros((e_local,), jnp.int32).at[lids].add(
            local.astype(jnp.int32))
        offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(t * k, dtype=jnp.int32) - offsets[sid]
        keep = s_local & (pos < cap)
        trash = e_local * cap
        slot = jnp.where(keep, sid * cap + pos, trash)
        x_sliced = jax.lax.dynamic_slice_in_dim(xt, didx * d_shard, d_shard,
                                                axis=1)
        buf = jnp.zeros((e_local * cap + 1, d_shard), xt.dtype)
        buf = buf.at[slot].set(x_sliced[s_tok], mode="drop")
        xb = buf[:-1].reshape(e_local, cap, d_shard)

        h = jnp.einsum("ecd,edf->ecf", xb, wg,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", xb, wu,
                       preferred_element_type=jnp.float32)
        h = jax.lax.psum(h, data_axes)
        u = jax.lax.psum(u, data_axes)
        a = (jax.nn.silu(h) * u).astype(xt.dtype)
        # wd stored (E_local, f, d) sharded over data on the LAST dim
        yb = jnp.einsum("ecf,efd->ecd", a, wd,
                        preferred_element_type=jnp.float32)  # (E_l,C,d_shard)
        yb = yb.reshape(e_local * cap, d_shard)
        contrib = yb[jnp.minimum(slot, trash - 1)]
        contrib = contrib * (s_w * keep.astype(jnp.float32))[:, None]
        y_slice = jnp.zeros((t, d_shard), jnp.float32).at[s_tok].add(contrib)
        y_slice = jax.lax.psum(y_slice, model_axis)
        y_full = jax.lax.all_gather(y_slice, data_axes, axis=1, tiled=True)
        y_mine = jax.lax.dynamic_slice_in_dim(y_full, didx * t_local,
                                              t_local, axis=0)
        return y_mine.astype(xt.dtype), aux

    xt = x.reshape(t, d)
    dspec = P(data_axes, None)
    y, aux = shard_map_compat(
        shard_fn, mesh=mesh,
        in_specs=(dspec, P(), P(), P(model_axis, data_axes, None),
                  P(model_axis, data_axes, None),
                  P(model_axis, None, data_axes)),
        out_specs=(dspec, P()),
        check_vma=False,
    )(xt, params["router"], params["router_bias"],
      params["wg"], params["wu"], params["wd"])
    if "shared" in params:
        y = y + mlp_fwd(params["shared"], xt, "swiglu")
    return y.reshape(b, s, d), aux
