"""Feed-forward blocks: SwiGLU / GeGLU / GELU."""
from __future__ import annotations

import jax

from repro.models.common import Array, dense_init, linear


def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[1], (d_model, d_ff), dtype),
         "w_down": dense_init(ks[2], (d_ff, d_model), dtype, fan_in=d_ff)}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[0], (d_model, d_ff), dtype)
    return p


def mlp_fwd(params, x: Array, kind: str) -> Array:
    up = linear(x, params["w_up"])
    if kind == "swiglu":
        act = jax.nn.silu(linear(x, params["w_gate"])) * up
    elif kind == "geglu":
        act = jax.nn.gelu(linear(x, params["w_gate"]), approximate=True) * up
    elif kind == "gelu":
        act = jax.nn.gelu(up, approximate=True)
    else:
        raise ValueError(kind)
    return linear(act, params["w_down"])
