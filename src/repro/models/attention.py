"""Attention mixers: GQA/MQA, sliding-window, and DeepSeek-style MLA.

Pure functional: ``init_*`` builds a param dict, ``*_fwd`` runs train/prefill,
``*_decode`` runs a single-token step against a KV cache.

Prefill/train uses a double-blocked (flash-style) online-softmax attention in
pure jnp (``blocked_attention``) so the 32k-token shapes never materialize a
full (S, S) score matrix.  Decode computes scores against the whole cache
directly — that is the hot spot the Pallas ``decode_attention`` kernel
implements for TPU (see src/repro/kernels/decode_attention).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (Array, apply_mrope, apply_rope, dense_init,
                                 linear, rms_norm, softcap)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Standard / GQA / SWA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype, *, num_heads=None,
                   num_kv_heads=None, head_dim=None):
    h = num_heads or cfg.num_heads
    kv = num_kv_heads or cfg.num_kv_heads
    d = head_dim or cfg.head_dim
    dm = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (dm, h * d), dtype),
        "wk": dense_init(ks[1], (dm, kv * d), dtype),
        "wv": dense_init(ks[2], (dm, kv * d), dtype),
        "wo": dense_init(ks[3], (h * d, dm), dtype, fan_in=h * d),
    }


def _rope_qk(q, k, positions, cfg: ModelConfig, k_positions=None):
    if cfg.rope_kind == "standard":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions if k_positions is None else k_positions,
                       cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        # positions here is (3, B, S)
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k


def blocked_attention(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
                      *, causal: bool, window: int, scale: float,
                      cap: float = 0.0, block_q: int = 512,
                      block_k: int = 1024) -> Array:
    """Flash-style online-softmax attention in pure jnp.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D); q_pos/k_pos: (B, Sq)/(B, Sk).
    Returns (B, Sq, H, D).  Never materializes (Sq, Sk).
    """
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    orig_sq = sq
    # pad sq/sk to block multiples
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-1)
        sq += pad_q
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=2**30)
        sk += pad_k
    nq, nk = sq // block_q, sk // block_k

    qb = q.reshape(b, nq, block_q, kvh, g, d).transpose(1, 0, 3, 4, 2, 5)
    # qb: (nq, B, KV, G, bq, D)
    qpb = q_pos.reshape(b, nq, block_q).transpose(1, 0, 2)  # (nq, B, bq)
    kb = k.reshape(b, nk, block_k, kvh, d).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(b, nk, block_k, kvh, dv).transpose(1, 0, 3, 2, 4)
    kpb = k_pos.reshape(b, nk, block_k).transpose(1, 0, 2)  # (nk, B, bk)

    def q_block(args):
        qi, qp = args  # (B,KV,G,bq,D), (B,bq)
        qi = qi.astype(jnp.float32) * scale

        def kv_step(carry, kv_args):
            m, l, acc = carry
            ki, vi, kp = kv_args  # (B,KV,bk,D) x2, (B,bk)
            s = jnp.einsum("bkgqd,bktd->bkgqt", qi, ki.astype(jnp.float32))
            if cap > 0:
                s = softcap(s, cap)
            mask = jnp.ones(s.shape[-2:], bool)[None, None, None]
            rel = qp[:, None, None, :, None] - kp[:, None, None, None, :]
            if causal:
                mask = mask & (rel >= 0)
            if window > 0:
                mask = mask & (rel < window)
            mask = mask & (kp < 2**30)[:, None, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,bktd->bkgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, block_q, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kb, vb, kpb))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(q_block, (qb, qpb))           # (nq,B,KV,G,bq,Dv)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, dv)
    return out[:, :orig_sq].astype(v.dtype)


def _bp_spec(mesh, batch: int):
    """Widest mesh-axes tuple that divides the batch (for batch-parallel
    attention: shard the batch over the model axis too — archs whose head
    counts don't divide the model axis otherwise run attention replicated
    n_model times; EXPERIMENTS.md §Perf smollm iteration)."""
    from jax.sharding import PartitionSpec as P
    names = list(mesh.axis_names)
    for axes in (tuple(names), tuple(a for a in names if a != "pod")):
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if axes and batch % n == 0:
            return axes
    return None


def _bp_constrain(x, mesh, axes):
    from jax.sharding import NamedSharding, PartitionSpec as P
    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def attention_fwd(params, x: Array, positions, cfg: ModelConfig, *,
                  window: int = 0, causal: bool = True,
                  kv_x: Optional[Array] = None, kv_positions=None,
                  num_heads=None, num_kv_heads=None, head_dim=None,
                  mesh=None) -> Array:
    """Train/prefill attention.  kv_x != None => cross-attention."""
    h = num_heads or cfg.num_heads
    kvh = num_kv_heads or cfg.num_kv_heads
    d = head_dim or cfg.head_dim
    b, s, _ = x.shape
    src = kv_x if kv_x is not None else x
    sk = src.shape[1]
    q = linear(x, params["wq"]).reshape(b, s, h, d)
    k = linear(src, params["wk"]).reshape(b, sk, kvh, d)
    v = linear(src, params["wv"]).reshape(b, sk, kvh, d)
    if kv_x is None and cfg.rope_kind in ("standard", "mrope"):
        q, k = _rope_qk(q, k, positions, cfg)
    qp = positions if cfg.rope_kind != "mrope" else positions[0]
    if kv_x is not None:
        kp = kv_positions
        if kp is None:
            kp = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32), (b, sk))
    else:
        kp = qp
    bp_axes = (_bp_spec(mesh, b)
               if (mesh is not None and cfg.attn_batch_parallel) else None)
    if bp_axes:
        q = _bp_constrain(q, mesh, bp_axes)
        k = _bp_constrain(k, mesh, bp_axes)
        v = _bp_constrain(v, mesh, bp_axes)
    out = blocked_attention(q, k, v, qp, kp, causal=causal and kv_x is None,
                            window=window, scale=d ** -0.5,
                            cap=cfg.logit_softcap)
    if bp_axes:
        out = _bp_constrain(out, mesh, bp_axes)
    return linear(out.reshape(b, s, h * d), params["wo"])


def attention_decode(params, x: Array, cache: dict, cache_index: Array,
                     positions, cfg: ModelConfig, *, window: int = 0,
                     num_heads=None, num_kv_heads=None, head_dim=None):
    """Single-token decode.  x: (B, 1, d_model).

    cache: {"k": (B, S, KV, D), "v": ...} — S is the window size for SWA
    (ring buffer) or max_seq for full attention.  Keys are cached post-RoPE.
    Returns (y, new_cache).
    """
    h = num_heads or cfg.num_heads
    kvh = num_kv_heads or cfg.num_kv_heads
    d = head_dim or cfg.head_dim
    b = x.shape[0]
    q = linear(x, params["wq"]).reshape(b, 1, h, d)
    k = linear(x, params["wk"]).reshape(b, 1, kvh, d)
    v = linear(x, params["wv"]).reshape(b, 1, kvh, d)
    if cfg.rope_kind in ("standard", "mrope"):
        q, k = _rope_qk(q, k, positions, cfg)

    s_cache = cache["k"].shape[1]
    slot = cache_index % s_cache if window > 0 else cache_index
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))

    # validity mask over cache slots
    j = jnp.arange(s_cache)
    if window > 0:
        # ring buffer: slot j holds position index - ((slot - j) mod S)
        age = (slot - j) % s_cache
        valid = age <= cache_index
    else:
        valid = j <= cache_index

    g = h // kvh
    if (cfg.use_pallas_decode and window == 0 and cfg.logit_softcap == 0
            and d % 8 == 0):
        # Pallas flash-decode kernel path (kernels/decode_attention):
        # contiguous cache [0..index] -> lengths mask
        from repro.kernels.decode_attention.ops import decode_attention
        lengths = jnp.broadcast_to(cache_index + 1, (b,)).astype(jnp.int32)
        qk = q.reshape(b, kvh, g, d)
        out = decode_attention(qk, ck, cv, lengths,
                               block_s=min(512, s_cache))
        out = out.reshape(b, 1, h * d).astype(x.dtype)
        y = linear(out, params["wo"])
        return y, {"k": ck, "v": cv}
    qf = (q.reshape(b, kvh, g, d) * (d ** -0.5)).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, ck.astype(jnp.float32))
    if cfg.logit_softcap > 0:
        scores = softcap(scores, cfg.logit_softcap)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, cv.astype(jnp.float32))
    out = out.reshape(b, 1, h * d).astype(x.dtype)
    y = linear(out, params["wo"])
    return y, {"k": ck, "v": cv}


def init_attention_cache(cfg: ModelConfig, batch: int, seq: int, dtype, *,
                         window: int = 0, num_kv_heads=None, head_dim=None):
    kvh = num_kv_heads or cfg.num_kv_heads
    d = head_dim or cfg.head_dim
    s = min(seq, window) if window > 0 else seq
    shape = (batch, s, kvh, d)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# MLA — DeepSeek multi-head latent attention
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype):
    dm, h = cfg.d_model, cfg.num_heads
    nope, rope_d = cfg.qk_nope_dim, cfg.qk_rope_dim
    vd = cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], (dm, cfg.q_lora_rank), dtype),
        "q_norm": jnp.zeros((cfg.q_lora_rank,), dtype),
        "w_uq": dense_init(ks[1], (cfg.q_lora_rank, h * (nope + rope_d)), dtype),
        "w_dkv": dense_init(ks[2], (dm, cfg.kv_lora_rank + rope_d), dtype),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[3], (cfg.kv_lora_rank, h * nope), dtype),
        "w_uv": dense_init(ks[4], (cfg.kv_lora_rank, h * vd), dtype),
        "wo": dense_init(ks[5], (h * vd, dm), dtype, fan_in=h * vd),
    }


def _mla_qkv(params, x, positions, cfg: ModelConfig):
    """Shared projection logic. Returns q_nope, q_rope, c_kv, k_rope."""
    b, s, _ = x.shape
    h, nope, rope_d = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    cq = rms_norm(linear(x, params["w_dq"]), params["q_norm"], cfg.norm_eps)
    q = linear(cq, params["w_uq"]).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = linear(x, params["w_dkv"])
    c_kv = rms_norm(ckv[..., :cfg.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = ckv[..., cfg.kv_lora_rank:][:, :, None, :]      # (B,S,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def mla_fwd(params, x: Array, positions, cfg: ModelConfig) -> Array:
    """Train/prefill MLA: materialize per-head K/V from the latent."""
    b, s, _ = x.shape
    h, nope, rope_d = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    vd = cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, positions, cfg)
    k_nope = linear(c_kv, params["w_uk"]).reshape(b, s, h, nope)
    v = linear(c_kv, params["w_uv"]).reshape(b, s, h, vd)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope_d))],
                        axis=-1)
    pos = positions
    out = blocked_attention(q, k, v, pos, pos, causal=True, window=0,
                            scale=(nope + rope_d) ** -0.5)
    return linear(out.reshape(b, s, h * vd), params["wo"])


def mla_decode(params, x: Array, cache: dict, cache_index: Array, positions,
               cfg: ModelConfig):
    """Absorbed-matrix MLA decode: attend in the compressed latent space.

    cache: {"c_kv": (B, S, kv_lora), "k_rope": (B, S, rope_d)} — ~10x smaller
    than a materialized GQA cache; the per-head K/V never exist at decode.
    """
    b = x.shape[0]
    h, nope, rope_d = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    vd, r = cfg.v_head_dim, cfg.kv_lora_rank
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, x, positions, cfg)
    c_kv_cache = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, cache_index, 0))
    k_rope_cache = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope[:, :, 0].astype(cache["k_rope"].dtype),
        (0, cache_index, 0))
    # absorb W_uk into q: q_eff (B,H,r)
    w_uk = params["w_uk"].reshape(r, h, nope)
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = (nope + rope_d) ** -0.5
    s_lat = jnp.einsum("bhr,bsr->bhs", q_eff,
                       c_kv_cache.astype(jnp.float32)) * scale
    s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                        k_rope_cache.astype(jnp.float32)) * scale
    scores = s_lat + s_rope
    valid = jnp.arange(scores.shape[-1]) <= cache_index
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, c_kv_cache.astype(jnp.float32))
    w_uv = params["w_uv"].reshape(r, h, vd)
    out = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, h * vd).astype(x.dtype)
    y = linear(out, params["wo"])
    return y, {"c_kv": c_kv_cache, "k_rope": k_rope_cache}


def init_mla_cache(cfg: ModelConfig, batch: int, seq: int, dtype):
    return {"c_kv": jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, seq, cfg.qk_rope_dim), dtype)}
