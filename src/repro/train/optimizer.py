"""AdamW in pure JAX (no optax dependency).

Moment dtype is configurable: f32 for small models, bf16 for the MoE giants
where full-f32 optimizer state cannot fit a single pod (see DESIGN.md §5 and
EXPERIMENTS.md §Dry-run memory notes).  Router bias buffers (aux-loss-free
MoE balancing) are excluded from AdamW and updated by the balance rule.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"   # "bfloat16" for the giants
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def lr_at(step, oc: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - oc.warmup_steps)
                    / jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = oc.min_lr_frac + (1 - oc.min_lr_frac) * cos
    return oc.lr * warm * frac


def adamw_init(params, oc: OptConfig):
    dt = jnp.bfloat16 if oc.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(params, grads, opt_state, oc: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if oc.grad_clip > 0 else 1.0
    lr = lr_at(step, oc)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_new = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_new = b2 * nu.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = mu_new / bc1
        vhat = nu_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + oc.eps)
        decay = oc.weight_decay if p.ndim >= 2 else 0.0
        p_new = p.astype(jnp.float32) * (1 - lr * decay) - lr * delta
        return (p_new.astype(p.dtype), mu_new.astype(mu.dtype),
                nu_new.astype(nu.dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(opt_state["mu"])
    flat_nu = tdef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return (new_p, {"mu": new_mu, "nu": new_nu, "step": step},
            {"grad_norm": gnorm, "lr": lr})
