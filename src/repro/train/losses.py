"""Cross-entropy (+ MoE aux + DeepSeek MTP) losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

IGNORE = -100


def softmax_xent(logits, labels, vocab_size: int):
    """Mean CE over non-ignored labels.  logits: (B,S,Vpad), labels: (B,S)."""
    mask = (labels != IGNORE) & (labels < vocab_size)
    safe = jnp.where(mask, labels, 0)
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
    ce = (lse - gold) * mask
    return ce.sum() / jnp.maximum(mask.sum(), 1)


def next_token_labels(tokens):
    """Shift-left labels with the final position ignored."""
    return jnp.concatenate(
        [tokens[:, 1:], jnp.full((tokens.shape[0], 1), IGNORE, tokens.dtype)],
        axis=1)


def train_loss(model, params, batch, cfg: ModelConfig, mtp_weight: float = 0.1):
    """Total loss = CE + aux_coef * moe_aux (+ mtp_weight * MTP CE)."""
    labels = batch.get("labels")
    if labels is None:
        labels = next_token_labels(batch["tokens"])
    if cfg.mtp_depth:
        hidden, aux = model.forward_hidden(params, batch)
        from repro.models.common import rms_norm
        from repro.models.api import _head
        h = rms_norm(hidden, params["final_norm"], cfg.norm_eps)
        logits = _head(params, cfg, h)
    else:
        logits, aux = model.forward(params, batch)
    if cfg.num_patch_tokens:
        # logits cover [patches, text]; only text positions carry labels
        logits = logits[:, -batch["tokens"].shape[1]:]
    ce = softmax_xent(logits, labels, cfg.vocab_size)
    total = ce + cfg.moe_aux_loss_coef * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp_depth:
        # depth-1 MTP: logits2[t] predicts token t+2
        logits2, aux2 = model.mtp_logits(params, hidden, batch["tokens"])
        lab2 = labels[:, 1:]
        mtp_ce = softmax_xent(logits2, lab2, cfg.vocab_size)
        total = total + mtp_weight * mtp_ce + cfg.moe_aux_loss_coef * aux2
        metrics["mtp_ce"] = mtp_ce
    return total, metrics
