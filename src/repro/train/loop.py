"""Training step/loop with pjit shardings."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable

import jax

from repro.models.api import Model
from repro.train.losses import train_loss
from repro.train.optimizer import OptConfig, adamw_init, adamw_update


@dataclass
class TrainState:
    params: Any
    opt: Any

    def as_dict(self):
        return {"params": self.params, "opt": self.opt}


def init_state(model: Model, rng, oc: OptConfig) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=adamw_init(params, oc))


def make_train_step(model: Model, oc: OptConfig) -> Callable:
    cfg = model.cfg

    def step(state: dict, batch: dict):
        def loss_fn(params):
            return train_loss(model, params, batch, cfg)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        new_params, new_opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], oc)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def train_loop(model: Model, batches, oc: OptConfig, rng=None,
               log_every: int = 10, callback=None):
    """Simple host loop for the examples; returns final state + history."""
    rng = rng if rng is not None else jax.random.key(0)
    state = init_state(model, rng, oc).as_dict()
    step_fn = jax.jit(make_train_step(model, oc), donate_argnums=(0,))
    history = []
    t0 = time.perf_counter()
    for i, batch in enumerate(batches):
        state, metrics = step_fn(state, batch)
        if i % log_every == 0 or callback:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(m)
    return state, history
