from repro.train.optimizer import adamw_init, adamw_update, OptConfig
from repro.train.loop import make_train_step, TrainState
