"""4G/5G bandwidth traces (paper Fig. 1, van der Hooft et al. [34]).

The dataset (HTTP/2 adaptive streaming over Belgian 4G, 1 Hz samples) is not
shipped offline, so ``synth_4g_trace`` generates traces statistically matched
to the paper's description: bandwidth varying between ~0.5 MB/s and ~7 MB/s
within a 10-minute window, with mobility-induced regime shifts (log-OU
process + occasional deep fades).  ``synth_5g_trace`` is the same generator
re-parameterized to an mmWave-ish envelope (higher ceiling, rarer but deeper
blockage fades) for the mixed-network scenario replays.  A loader for the
real CSV format is provided for when the dataset is available.

Lookups are vectorized: ``BandwidthTrace.at_many`` maps a whole arrival
array to bandwidths in one numpy pass — the million-request workload
generators never call the scalar ``at`` in a loop.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class BandwidthTrace:
    t: np.ndarray        # seconds, 1 Hz
    mbps: np.ndarray     # MB/s (megaBYTES, as in the paper's figure)

    def at(self, now: float) -> float:
        i = min(int(now), len(self.mbps) - 1)
        return float(self.mbps[max(i, 0)])

    def at_many(self, times: np.ndarray) -> np.ndarray:
        """Vectorized ``at``: bandwidth sample for every entry of ``times``
        (same truncate-and-clamp indexing as the scalar path)."""
        idx = np.clip(np.asarray(times, np.float64).astype(np.int64),
                      0, len(self.mbps) - 1)
        return self.mbps[idx]

    @property
    def duration(self) -> float:
        return float(self.t[-1])


def synth_4g_trace(duration_s: int = 600, seed: int = 0,
                   lo: float = 0.5, hi: float = 7.0,
                   fade_depth: tuple = (0.15, 0.3)) -> BandwidthTrace:
    """Log-space Ornstein–Uhlenbeck bandwidth with regime shifts and fades.

    Regime-shift and fade counts scale with the duration, so hour-long
    scenario traces keep the paper's per-10-minute mobility statistics
    (short traces draw the same RNG stream as before).
    """
    rng = np.random.default_rng(seed)
    n = int(duration_s)
    x = np.zeros(n)
    mu = np.log(2.5)
    x[0] = mu
    theta, sigma = 0.05, 0.25
    # regime shifts every ~60-120 s (user mobility)
    n_regimes = max(20, n // 90 + 1)
    shift_times = np.cumsum(rng.integers(45, 150, size=n_regimes))
    shifts = {int(t): rng.uniform(np.log(lo * 1.6), np.log(hi * 0.8))
              for t in shift_times if t < n}
    for i in range(1, n):
        if i in shifts:
            mu = shifts[i]
        x[i] = x[i - 1] + theta * (mu - x[i - 1]) + sigma * rng.normal()
    bw = np.exp(x)
    # deep fades (handover/obstruction): a few seconds near the floor
    if n > 20:
        n_fades = int(rng.integers(2, 5)) if n <= 1200 else n // 250
        for _ in range(n_fades):
            s = rng.integers(0, n - 15)
            bw[s:s + rng.integers(4, 12)] *= rng.uniform(*fade_depth)
    bw = np.clip(bw, lo, hi)
    return BandwidthTrace(t=np.arange(n, dtype=np.float64), mbps=bw)


def synth_5g_trace(duration_s: int = 600, seed: int = 0,
                   lo: float = 1.5, hi: float = 40.0) -> BandwidthTrace:
    """5G-class synthetic trace: an order of magnitude more bandwidth than
    the 4G envelope but with mmWave-style blockage — fades are rarer yet
    proportionally deeper, so the *dynamic-SLO* effect (budgets collapsing
    when the link dips) survives even on the faster network."""
    return synth_4g_trace(duration_s, seed=seed, lo=lo, hi=hi,
                          fade_depth=(0.05, 0.15))


def load_csv_trace(path: str, col: int = 1, scale_to_mbytes: float = 1e-6
                   ) -> BandwidthTrace:
    """Load a real 4G log (one sample/line, bytes/s by default)."""
    raw = np.loadtxt(path, delimiter=",", usecols=[col])
    mbps = raw * scale_to_mbytes
    return BandwidthTrace(t=np.arange(len(mbps), dtype=np.float64), mbps=mbps)
