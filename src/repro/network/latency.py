"""Communication latency model (paper Fig. 1 bottom): the time to ship a
payload of size_kb over the instantaneous bandwidth, plus a small RTT."""
from __future__ import annotations

import numpy as np

from repro.network.traces import BandwidthTrace


def comm_latency(size_kb: float, trace: BandwidthTrace, now: float,
                 rtt_s: float = 0.02) -> float:
    bw_mbps = trace.at(now)                  # MB/s
    return rtt_s + (size_kb / 1024.0) / max(bw_mbps, 1e-6)


def comm_latency_many(size_kb: np.ndarray, trace: BandwidthTrace,
                      times: np.ndarray, rtt_s: float = 0.02) -> np.ndarray:
    """Vectorized ``comm_latency``: one numpy pass over a whole arrival
    array (element-for-element identical to the scalar model)."""
    bw = np.maximum(trace.at_many(times), 1e-6)
    return rtt_s + (np.asarray(size_kb, np.float64) / 1024.0) / bw
