"""Communication latency model (paper Fig. 1 bottom): the time to ship a
payload of size_kb over the instantaneous bandwidth, plus a small RTT."""
from __future__ import annotations

from repro.network.traces import BandwidthTrace


def comm_latency(size_kb: float, trace: BandwidthTrace, now: float,
                 rtt_s: float = 0.02) -> float:
    bw_mbps = trace.at(now)                  # MB/s
    return rtt_s + (size_kb / 1024.0) / max(bw_mbps, 1e-6)
