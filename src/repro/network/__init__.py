from repro.network.traces import BandwidthTrace, synth_4g_trace
from repro.network.latency import comm_latency
