"""DeepSeek-V3 671B — MoE with MLA, 1 shared + 256 routed experts (top-8), MTP.

[arXiv:2412.19437] 61L, d_model=7168, 128 heads, MoE expert d_ff=2048,
vocab=129280.  First 3 layers are dense (d_ff=18432 per the paper); the
assigned d_ff=2048 is the routed-expert inner dim.  MLA dims per the paper:
q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128.
Aux-loss-free sigmoid routing; multi-token prediction depth 1.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,                      # dense layers (first_k_dense)
    vocab_size=129280,
    blocks=("mla+mlp",) * 3 + ("mla+moe",) * 58,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=256,
    num_experts_per_tok=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_k_dense=3,
    moe_router_kind="sigmoid",
    mtp_depth=1,
    rope_theta=10000.0,
    tie_embeddings=False,
    source="arXiv:2412.19437",
)
