"""Qwen2-VL 2B — VLM text decoder with M-RoPE and dynamic resolution.

[arXiv:2409.12191] 28L, d_model=1536, 12 heads GQA kv=2, d_ff=8960,
vocab=151936.  The ViT vision encoder + projector is a STUB per the
assignment carve-out: ``input_specs()`` supplies precomputed patch
embeddings (B, num_patch_tokens, d_model); the decoder applies 3-D M-RoPE
(temporal/height/width sections 16/24/24 over the 64-dim rope half).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    blocks=("attn+mlp",) * 28,
    rope_kind="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1000000.0,
    num_patch_tokens=256,
    tie_embeddings=True,
    source="arXiv:2409.12191",
)
