from repro.configs.base import ModelConfig, InputShape, INPUT_SHAPES
from repro.configs.registry import get_config, list_archs, ARCH_IDS
