"""Model / run configuration dataclasses.

Every assigned architecture instantiates :class:`ModelConfig`.  The config is a
frozen dataclass so it can be closed over by jitted functions and hashed for
the serving engine's executable table.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Tuple


# Block kinds: each layer is "<mixer>+<ffn>".
#   mixers: attn | swa | mla | mamba2 | rwkv6
#   ffns:   mlp  | moe | rwkv_cm | none
MIXERS = ("attn", "swa", "mla", "mamba2", "rwkv6")
FFNS = ("mlp", "moe", "rwkv_cm", "none")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- layer stack ------------------------------------------------------
    # per-layer block kind; if empty, derived as ("attn+mlp",) * num_layers
    blocks: Tuple[str, ...] = ()

    # --- attention --------------------------------------------------------
    window_size: int = 0               # >0 => sliding-window attention for "swa"
    rope_theta: float = 10000.0
    rope_kind: str = "standard"        # standard | mrope | none | learned
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    qk_norm: bool = False
    logit_softcap: float = 0.0

    # --- MLA (DeepSeek-style multi-head latent attention) ------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MLP ----------------------------------------------------------------
    mlp_kind: str = "swiglu"           # swiglu | geglu | gelu
    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                  # expert inner dim (d_ff used for dense layers)
    first_k_dense: int = 0             # leading dense layers (DeepSeek)
    moe_capacity_factor: float = 1.25
    moe_router_kind: str = "softmax"   # softmax | sigmoid (DeepSeek-V3)
    moe_aux_loss_coef: float = 0.001
    mtp_depth: int = 0                 # multi-token-prediction extra depth (DeepSeek)

    # --- SSM (Mamba2) -------------------------------------------------------
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    shared_attn_every: int = 0         # zamba2: shared attn block every k layers
    shared_attn_window: int = 0        # window for the shared attn block when serving

    # --- enc-dec (whisper) ---------------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq_len: int = 0           # frames after the (stubbed) conv frontend

    # --- vlm -----------------------------------------------------------------
    num_patch_tokens: int = 0          # prefix patch embeddings from stub encoder

    # --- performance knobs (see EXPERIMENTS.md §Perf) -------------------------
    attn_batch_parallel: bool = False  # shard attention batch over model axis
                                       # (archs whose heads don't divide 16)
    moe_partial_ep: bool = False       # serving: d-sliced partial-sum expert
                                       # compute, no FSDP weight gather
    use_pallas_decode: bool = False    # decode attention via the Pallas
                                       # flash-decode kernel (TPU; interpret
                                       # mode on CPU)
    use_pallas_prefill: bool = False   # prefill attention via the Pallas
                                       # swa_prefill kernel (full causal ==
                                       # window >= S; serving path only)
    rwkv_chunked: bool = False         # chunked-parallel WKV6 for training
                                       # (vs per-step lax.scan)
    # --- numerics ------------------------------------------------------------
    scale_embed: bool = False          # gemma: multiply embeddings by sqrt(d)
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    dtype: str = "bfloat16"            # activation/compute dtype
    param_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    # citation for the config (paper/model card)
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if not self.blocks:
            object.__setattr__(self, "blocks", self.default_blocks())
        assert len(self.blocks) == self.num_layers, (
            f"{self.name}: blocks length {len(self.blocks)} != L={self.num_layers}")
        for b in self.blocks:
            mixer, ffn = b.split("+")
            assert mixer in MIXERS and ffn in FFNS, f"bad block kind {b}"

    def default_blocks(self) -> Tuple[str, ...]:
        return ("attn+mlp",) * self.num_layers

    # --- derived ------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded so it shards over 16-way model parallelism."""
        return int(math.ceil(self.vocab_size / 128) * 128)

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_num_heads(self) -> int:
        return self.d_model // 64

    @property
    def uses_moe(self) -> bool:
        return any(b.endswith("+moe") for b in self.blocks)

    @property
    def mixer_kinds(self) -> Tuple[str, ...]:
        return tuple(b.split("+")[0] for b in self.blocks)

    def param_count(self) -> int:
        """Analytic parameter count (approximate: embeddings + blocks)."""
        d = self.d_model
        n = self.padded_vocab * d  # embedding
        if not self.tie_embeddings:
            n += self.padded_vocab * d
        for b in self.blocks:
            mixer, ffn = b.split("+")
            if mixer in ("attn", "swa"):
                n += d * self.num_heads * self.head_dim * 2  # q, o
                n += d * self.num_kv_heads * self.head_dim * 2  # k, v
            elif mixer == "mla":
                n += d * self.q_lora_rank
                n += self.q_lora_rank * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                n += d * (self.kv_lora_rank + self.qk_rope_dim)
                n += self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                n += self.num_heads * self.v_head_dim * d
            elif mixer == "mamba2":
                di = self.d_inner
                n += d * (2 * di + 2 * self.ssm_state_dim + self.ssm_num_heads)
                n += di * d
            elif mixer == "rwkv6":
                n += 6 * d * d  # r,k,v,g,o,w(lora) rough
            if ffn == "mlp":
                mult = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
                n += mult * d * self.d_ff
            elif ffn == "moe":
                mult = 3
                n += self.num_experts * mult * d * self.moe_d_ff
                n += self.num_shared_experts * mult * d * self.moe_d_ff
                n += d * self.num_experts  # router
            elif ffn == "rwkv_cm":
                n += 2 * d * self.d_ff + d * d
        if self.shared_attn_every:
            n += 4 * d * self.num_heads * self.head_dim
        if self.is_encoder_decoder:
            # encoder blocks + cross attention in decoder
            enc = self.encoder_layers * (4 * d * self.num_heads * self.head_dim
                                         + 2 * d * self.d_ff)
            cross = self.num_layers * 4 * d * self.num_heads * self.head_dim
            n += enc + cross
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: only routed top-k + shared)."""
        if not self.uses_moe:
            return self.param_count()
        d = self.d_model
        n = self.param_count()
        moe_layers = sum(1 for b in self.blocks if b.endswith("+moe"))
        all_exp = self.num_experts * 3 * d * self.moe_d_ff
        act_exp = self.num_experts_per_tok * 3 * d * self.moe_d_ff
        n -= moe_layers * (all_exp - act_exp)
        return n

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=256, <=4 experts."""
        d = min(self.d_model, 256)
        heads = max(2, min(self.num_heads, 4))
        head_dim = max(16, min(self.head_dim, 64))
        kv = max(1, min(self.num_kv_heads, heads))
        while heads % kv:
            kv -= 1
        nl = min(self.num_layers, 2)
        blocks = self.blocks[:1] + self.blocks[-1:] if nl == 2 else self.blocks[:nl]
        changes = dict(
            name=self.name + "-reduced",
            num_layers=nl,
            blocks=blocks,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq_len=min(self.encoder_seq_len, 32) if self.encoder_seq_len else 0,
            num_patch_tokens=min(self.num_patch_tokens, 8) if self.num_patch_tokens else 0,
            window_size=min(self.window_size, 16) if self.window_size else 0,
            shared_attn_every=1 if self.shared_attn_every else 0,
            shared_attn_window=min(self.shared_attn_window, 16) if self.shared_attn_window else 0,
            ssm_state_dim=min(self.ssm_state_dim, 16) if self.ssm_state_dim else 0,
            ssm_head_dim=32 if self.ssm_state_dim else self.ssm_head_dim,
            dtype="float32",
            param_dtype="float32",
            remat=False,
            scan_layers=True,
        )
        if self.uses_moe:
            changes.update(
                num_experts=min(self.num_experts, 4),
                num_experts_per_tok=min(self.num_experts_per_tok, 2),
                moe_d_ff=min(self.moe_d_ff, 128),
                first_k_dense=min(self.first_k_dense, 1),
                mtp_depth=min(self.mtp_depth, 1),
                # no-drop capacity so prefill+decode == forward exactly in
                # the smoke/equivalence tests
                moe_capacity_factor=float(min(self.num_experts, 4)),
            )
        if self.rope_kind == "mrope":
            half = head_dim // 2
            a = half // 4
            b = (half - a) // 2
            changes["mrope_sections"] = (a, b, half - a - b)
        if self.q_lora_rank:
            changes.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
                           qk_rope_dim=16, v_head_dim=32)
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
