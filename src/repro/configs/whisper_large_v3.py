"""Whisper large-v3 — encoder-decoder audio transformer backbone.

[arXiv:2212.04356] 32 encoder + 32 decoder layers, d_model=1280, 20 heads
(kv=20), d_ff=5120, vocab=51866.  The mel-spectrogram + conv frontend is a
STUB per the assignment carve-out: ``input_specs()`` supplies precomputed
frame embeddings of shape (B, 1500, d_model).  Learned positional
embeddings (frontend conv positionality is stubbed away with the conv).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    arch_type="audio",
    num_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,                # padded internally to 51968 for sharding
    blocks=("attn+mlp",) * 32,
    mlp_kind="gelu",
    rope_kind="learned",
    is_encoder_decoder=True,
    encoder_layers=32,
    encoder_seq_len=1500,
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
