"""Architecture registry: ``--arch <id>`` resolution.

Every assigned architecture (plus the paper's own serving model, a small
ResNet-class stand-in served as ``smollm-135m`` in the Sponge experiments) is
selectable by id.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

# arch id -> module name
_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "gemma-2b": "gemma_2b",
    "zamba2-2.7b": "zamba2_2p7b",
    "smollm-135m": "smollm_135m",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "smollm-360m": "smollm_360m",
}

ARCH_IDS = tuple(_MODULES)

# Per-model accuracy score in (0, 1] — the quality axis of the
# (m, n, c, b) degradation solver (``repro.core.degradation``).
# Normalized open-eval composite per architecture class: monotone in
# capability (roughly log active params), so the registry's natural
# ladder smollm-135m -> smollm-360m -> gemma-2b -> zamba2/rwkv6 ->
# deepseek/kimi is also the accuracy order.  The absolute numbers only
# matter relatively: the solver ranks rungs by them and the
# accuracy-weighted-goodput metric sums them.
MODEL_ACCURACY = {
    "smollm-135m": 0.58,
    "smollm-360m": 0.64,
    "rwkv6-1.6b": 0.69,
    "h2o-danube-1.8b": 0.70,
    "gemma-2b": 0.72,
    "qwen2-vl-2b": 0.73,
    "zamba2-2.7b": 0.74,
    "whisper-large-v3": 0.76,
    "deepseek-v3-671b": 0.90,
    "kimi-k2-1t-a32b": 0.92,
}
assert set(MODEL_ACCURACY) == set(_MODULES)


def model_accuracy(arch_id: str) -> float:
    """The registry accuracy score for ``arch_id`` (``-reduced``
    variants score as their parent — a smoke-sized config is not a
    different model)."""
    if arch_id.endswith("-reduced"):
        arch_id = arch_id[: -len("-reduced")]
    if arch_id not in MODEL_ACCURACY:
        raise KeyError(f"unknown arch {arch_id!r}; known: "
                       f"{sorted(MODEL_ACCURACY)}")
    return MODEL_ACCURACY[arch_id]


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    if arch_id.endswith("-reduced"):
        arch_id, reduced = arch_id[: -len("-reduced")], True
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def list_archs() -> list[str]:
    return list(ARCH_IDS)
