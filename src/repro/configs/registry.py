"""Architecture registry: ``--arch <id>`` resolution.

Every assigned architecture (plus the paper's own serving model, a small
ResNet-class stand-in served as ``smollm-135m`` in the Sponge experiments) is
selectable by id.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

# arch id -> module name
_MODULES = {
    "deepseek-v3-671b": "deepseek_v3_671b",
    "whisper-large-v3": "whisper_large_v3",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "gemma-2b": "gemma_2b",
    "zamba2-2.7b": "zamba2_2p7b",
    "smollm-135m": "smollm_135m",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "smollm-360m": "smollm_360m",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    if arch_id.endswith("-reduced"):
        arch_id, reduced = arch_id[: -len("-reduced")], True
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    cfg: ModelConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg


def list_archs() -> list[str]:
    return list(ARCH_IDS)
