"""Gemma 2B — dense decoder with GeGLU, head_dim=256, MQA (kv=1).

[arXiv:2403.08295] 18L, d_model=2048, 8 heads, kv=1 (multi-query),
d_ff=16384, vocab=256000, tied embeddings, GeGLU MLP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    arch_type="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    blocks=("attn+mlp",) * 18,
    mlp_kind="geglu",
    scale_embed=True,
    tie_embeddings=True,
    source="arXiv:2403.08295",
)
