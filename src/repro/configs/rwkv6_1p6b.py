"""RWKV-6 "Finch" 1.6B — attention-free RNN with data-dependent decay.

[arXiv:2404.05892] 24L, d_model=2048 (32 heads of 64), d_ff=7168 (channel
mix), vocab=65536.  Decode state is O(1) per layer (token-shift vectors +
a 32x64x64 WKV state), so all decode shapes including long_500k run.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    blocks=("rwkv6+rwkv_cm",) * 24,
    rope_kind="none",
    tie_embeddings=False,
    source="arXiv:2404.05892",
)
