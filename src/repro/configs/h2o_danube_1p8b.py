"""H2O-Danube 1.8B — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818] 24L, d_model=2560, 32 heads GQA kv=8 (head_dim 80),
d_ff=6912, vocab=32000, sliding window 4096.  The SWA window bounds the
KV cache, so the long_500k decode shape runs for this arch.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    arch_type="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    blocks=("swa+mlp",) * 24,
    window_size=4096,
    tie_embeddings=False,
    source="arXiv:2401.16818",
)
