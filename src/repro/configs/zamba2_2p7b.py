"""Zamba2 2.7B — hybrid: Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242] 54 Mamba2 layers, d_model=2560, ssm_state=64,
d_inner=2*d_model (80 SSD heads of dim 64).  A single SHARED
attention(+MLP d_ff=10240) block (32 heads, head_dim 80) is applied every
6 Mamba2 layers (9 applications, one weight set — the Zamba2 signature).
For serving, the shared attention uses a sliding window (4096) so the
long_500k decode shape stays sub-quadratic (see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    blocks=("mamba2+none",) * 54,
    ssm_state_dim=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,
    shared_attn_window=4096,
    tie_embeddings=True,
    source="arXiv:2411.15242",
)
