"""Kimi K2 — trillion-param MoE, 384 experts top-8 (paper-table variant).

[arXiv:2501.kimi2] 61L, d_model=7168, 64 heads with GQA kv=8 (as assigned),
MoE expert d_ff=2048, vocab=163840, 1 shared expert, first layer dense,
sigmoid (aux-loss-free) routing.  head_dim=128 (q width 8192 > d_model,
as in the K2 family).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18432,                      # dense first layer
    vocab_size=163840,
    blocks=("attn+mlp",) * 1 + ("attn+moe",) * 60,
    num_experts=384,
    num_experts_per_tok=8,
    num_shared_experts=1,
    moe_d_ff=2048,
    first_k_dense=1,
    moe_router_kind="sigmoid",
    rope_theta=50000.0,
    tie_embeddings=False,
    source="arXiv:2501.kimi2",
)
