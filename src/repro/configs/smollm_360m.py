"""SmolLM-360M — llama-architecture small dense model.

[hf:HuggingFaceTB/SmolLM-135M family] 32L, d_model=960, 15 heads GQA kv=5,
d_ff=2560, vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    arch_type="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    blocks=("attn+mlp",) * 32,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
