"""The online session API: submit / update_slo / cancel, any engine.

The paper's central claim is that SLOs are **dynamic at the request
level** — the wireless network keeps changing *after* a request is sent.
The historical serving surface was offline and closed-world:
``run(workload)`` fixed every deadline at arrival and only reported at
the end.  This module opens it up: a :class:`SpongeSession` is a live
handle on a serving engine through which a client (or a
network-telemetry feed) can

* ``submit(...)`` a request and receive a **handle**,
* ``update_slo(handle, ...)`` — renegotiate a *queued* request's
  deadline mid-flight (a network fade tightens the budget, a recovery
  relaxes it),
* ``cancel(handle)`` — withdraw a queued or not-yet-arrived request,
* ``step_until(t)`` — advance the engine's virtual clock incrementally,
* ``finish(horizon)`` — drain and collect the uniform ``RunReport``.

One protocol, four engines, identical scheduling semantics:

* :class:`ExactSession`     — the object-based ``ScenarioRunner`` (any
  backend: sim, token-sim, live Jax);
* :class:`FastSession`      — the struct-of-arrays ``FastSimRunner``;
* :class:`TokenFastSession` — the continuous-batching
  ``TokenFastSimRunner``;
* :class:`FleetSession`     — the joint horizontal + vertical
  ``FleetFastSimRunner`` (tightened budgets **re-route** through the
  configured arrival router; the exact pre-heaped fleet gang loop stays
  untouched as the decision-identity oracle).

The historical batch entry points are now thin replay drivers over a
session — ``FastSimRunner.run`` is literally ``submit_batch`` +
``finish`` — so there is exactly one event loop per engine and the
closed-world path is the no-renegotiation special case.  When no
mid-flight event occurs, every session processes the same events in the
same order with the same floats as the pre-session loops (the EDF
queues never hold a stale entry, the λ estimator never retracts), which
is what ``tests/test_session.py`` proves against the reference oracles
and the recorded-transcript fixtures.

Event-ordering contract: ``step_until(t)`` processes every pending
engine event with time ≤ t in the canonical order (arrivals, then
adaptation ticks, then fleet events, then completions/wake-ups at equal
times); ``update_slo`` / ``cancel`` apply *between* engine events at
the session's current clock and immediately re-trigger a dispatch pass
(a tightened head request must not wait for the next tick).  Cancelled
requests retract their arrival from the λ window
(``core.monitor.array_window_rate_cancel_aware`` /
``RateEstimator.retract``) so a cancel storm deflates the provisioning
signal immediately, and they are excluded from every served/violation
aggregate (reported via ``RunReport.n_cancelled``).
"""
from __future__ import annotations

import heapq
import itertools
from bisect import bisect_left, insort
from typing import Any, Dict, List, Optional, Protocol, Sequence, \
    runtime_checkable

import numpy as np

from repro.core.cost_model import Composition
from repro.core.monitor import (array_window_rate,
                                array_window_rate_cancel_aware,
                                tick_window_rate)
from repro.core.slo import Request
from repro.serving.api import RunReport, build_array_report
from repro.serving.fleet import normalize_fleet_events, route_request
from repro.serving.workload import RequestBatch

INF = float("inf")

# handle lifecycle states (column sessions keep one byte per request)
PENDING, QUEUED, DONE, CANCELLED = 0, 1, 2, 3


@runtime_checkable
class SpongeSession(Protocol):
    """The online serving session protocol (see the module docstring)."""

    now: float

    def submit(self, req: Optional[Request] = None, **fields) -> int: ...

    def submit_batch(self, batch: RequestBatch) -> Sequence[int]: ...

    def update_slo(self, handle: int, *, deadline: Optional[float] = None,
                   slo: Optional[float] = None,
                   net_latency: Optional[float] = None) -> bool: ...

    def cancel(self, handle: int) -> bool: ...

    def step_until(self, t: float) -> None: ...

    def finish(self, horizon: Optional[float] = None) -> RunReport: ...

    def record(self, handle: int) -> dict: ...


def _check_step_target(t: float) -> None:
    """``step_until`` needs a finite target: the adaptation-tick train
    is unbounded, so an infinite target would loop forever."""
    if not t < INF or t != t:
        raise ValueError(f"step_until needs a finite time (got {t}); "
                         "use finish(horizon) to drain a run")


def _new_deadline(send: float, cur_slo: float, deadline, slo,
                  net_latency) -> float:
    """Resolve a renegotiated absolute deadline.

    Priority: an explicit ``deadline`` wins; otherwise the deadline is
    rebuilt from the (possibly updated) end-to-end ``slo`` minus the
    anticipated response-path ``net_latency`` — the paper's dynamic-SLO
    quantity: when the client's link fades after submission, the
    response will take longer, so the server must finish earlier.
    """
    if deadline is not None:
        return float(deadline)
    s = cur_slo if slo is None else float(slo)
    return send + s - (0.0 if net_latency is None else float(net_latency))


# --------------------------------------------------------------------------
# transcripts: record once, replay anywhere
# --------------------------------------------------------------------------
class SessionTranscript:
    """A recorded stream of session ops, replayable on any engine.

    Ops reference workload *rows* (indices into the ``RequestBatch`` the
    transcript was recorded against), never engine handles — replay maps
    rows to whatever handles the target session allocates:

    * ``("submit", t, row)``            — submit row at its arrival t;
    * ``("update", t, row, deadline)``  — renegotiate to ``deadline``;
    * ``("cancel", t, row)``            — cancel.
    """

    def __init__(self, ops: Optional[List[tuple]] = None):
        self.ops: List[tuple] = list(ops or [])

    @classmethod
    def from_batch(cls, batch: RequestBatch,
                   events: Sequence[tuple] = ()) -> "SessionTranscript":
        """Record a transcript: one submit per row at its arrival time,
        merged time-stably with a renegotiation event stream (items
        shaped like the ``session_events`` scenario meta:
        ``(t, "update", row, new_deadline)`` / ``(t, "cancel", row)``)."""
        ops = [("submit", float(t), i)
               for i, t in enumerate(batch.arrival)]
        for ev in events:
            if ev[1] == "update":
                ops.append(("update", float(ev[0]), int(ev[2]),
                            float(ev[3])))
            else:
                ops.append(("cancel", float(ev[0]), int(ev[2])))
        ops.sort(key=lambda op: op[1])       # stable: submits precede
        return cls(ops)


def _row_request(batch: RequestBatch, i: int) -> Request:
    """Materialize one workload row as a ``Request``."""
    return Request(deadline=float(batch.deadline[i]),
                   arrival=float(batch.arrival[i]),
                   comm_latency=float(batch.comm_latency[i]),
                   slo=float(batch.slo[i]),
                   size_kb=float(batch.size_kb[i]),
                   prompt_tokens=int(batch.prompt_tokens[i]),
                   decode_tokens=int(batch.decode_tokens[i]),
                   tbt_slo=float(batch.tbt_slo[i]))


def replay_transcript(session: SpongeSession, transcript: SessionTranscript,
                      batch: RequestBatch,
                      horizon: Optional[float] = None) -> RunReport:
    """Drive ``session`` op by op — the true online path: each submit is
    pushed just before the clock reaches its arrival (so arrival events
    keep their tie precedence over same-time ticks), each renegotiation
    applies after the engine has advanced to its timestamp."""
    handles: Dict[int, int] = {}
    for op in transcript.ops:
        kind, t = op[0], op[1]
        if kind == "submit":
            handles[op[2]] = session.submit(_row_request(batch, op[2]))
            session.step_until(t)
        elif kind == "update":
            session.step_until(t)
            session.update_slo(handles[op[2]], deadline=op[3])
        else:
            session.step_until(t)
            session.cancel(handles[op[2]])
    return session.finish(horizon)


def drive_session_events(session: SpongeSession, handles: Sequence[int],
                         events: Sequence[tuple]) -> Dict[str, int]:
    """Apply a scenario's mid-flight event stream (``session_events``
    meta: time-sorted ``(t, "update", row, new_deadline)`` /
    ``(t, "cancel", row)`` tuples) to an already-submitted session.
    Returns applied/no-op counts (an event whose request already
    dispatched is a no-op, exactly like a real telemetry feed racing
    the scheduler)."""
    applied = {"update": 0, "cancel": 0, "noop": 0}
    for ev in events:
        t, kind, i = float(ev[0]), ev[1], int(ev[2])
        session.step_until(t)
        if kind == "update":
            ok = session.update_slo(handles[i], deadline=float(ev[3]))
        else:
            ok = session.cancel(handles[i])
        applied[kind if ok else "noop"] += 1
    return applied


# --------------------------------------------------------------------------
# the object-based session (ScenarioRunner: any backend)
# --------------------------------------------------------------------------
class ExactSession:
    """Online session over the object-based ``ScenarioRunner``.

    Wraps a runner (policy + backend already composed); arrivals live on
    a pending heap keyed ``(arrival, submission order)`` and are fed to
    the runner's streamed loop with the same tie precedence the batch
    path used (arrivals, then ticks, then dynamic events).  Dispatch,
    pool mutation and reporting stay on the runner — the session only
    owns the event cursor and the renegotiation surface.
    """

    def __init__(self, runner):
        self.runner = runner
        self.now = 0.0
        self.events_processed = 0
        self._pending: List[tuple] = []      # (arrival, seq, req, payload)
        self._pseq = itertools.count()
        self._events: List[tuple] = []       # dynamic: completions/wake-ups
        self._seq = itertools.count()
        self._next_tick = 0.0
        self._max_arrival = 0.0
        self._reqs: Dict[int, Request] = {}
        self._status: Dict[int, int] = {}    # PENDING / CANCELLED marks
        runner._wake = {}
        runner._slack_wake = {}
        runner.events_processed = 0

    # -- the client surface ------------------------------------------------
    def submit(self, req: Optional[Request] = None, *, payload: Any = None,
               send: Optional[float] = None, comm_latency: float = 0.0,
               slo: float = 1.0, size_kb: float = 200.0,
               deadline: Optional[float] = None, prompt_tokens: int = 1,
               decode_tokens: int = 0,
               tbt_slo: float = INF) -> int:
        """Submit one request (a ``Request`` or its fields); returns the
        handle every later ``update_slo`` / ``cancel`` uses."""
        if req is None:
            arrival = (send or 0.0) + comm_latency
            req = Request.make(arrival=arrival, comm_latency=comm_latency,
                               slo=slo, size_kb=size_kb,
                               prompt_tokens=prompt_tokens,
                               decode_tokens=decode_tokens, tbt_slo=tbt_slo)
            if deadline is not None:
                req.deadline = float(deadline)
        if req.arrival < self.now - 1e-12:
            raise ValueError(f"arrival {req.arrival} is in the session's "
                             f"past (now={self.now})")
        heapq.heappush(self._pending,
                       (req.arrival, next(self._pseq), req, payload))
        self._reqs[req.id] = req
        self._status[req.id] = PENDING
        self._max_arrival = max(self._max_arrival, req.arrival)
        return req.id

    def submit_batch(self, batch: RequestBatch) -> List[int]:
        """Submit a whole workload (arrival order); returns its handles."""
        return [self.submit(r) for r in batch.to_requests()]

    def update_slo(self, handle: int, *, deadline: Optional[float] = None,
                   slo: Optional[float] = None,
                   net_latency: Optional[float] = None) -> bool:
        """Renegotiate a pending or queued request's deadline; False once
        it has dispatched, finished, or been cancelled."""
        req = self._reqs.get(handle)
        if req is None:
            return False
        new_dl = _new_deadline(req.arrival - req.comm_latency, req.slo,
                               deadline, slo, net_latency)
        if slo is not None:
            req.slo = float(slo)
        st = self._status.get(handle, DONE)
        if st == PENDING:
            req.deadline = new_dl
            return True
        if st == CANCELLED:
            return False
        r = self.runner
        if not r.queue.update_deadline(handle, new_dl):
            return False
        # a tightened head must not wait for the next tick
        r._dispatch(self.now, self._events, self._seq)
        return True

    def cancel(self, handle: int) -> bool:
        """Withdraw a pending or queued request; double-cancel safe."""
        st = self._status.get(handle, DONE)
        if st == PENDING:
            self._status[handle] = CANCELLED
            # never arrived: counts as cancelled but there is no λ
            # observation to retract (same rule as the column sessions)
            self.runner.monitor.cancelled.append(self._reqs[handle])
            return True
        if st != QUEUED:
            return False
        req = self.runner.queue.cancel(handle)
        if req is None:
            return False
        self._status[handle] = CANCELLED
        self.runner.monitor.observe_cancel(req)
        # same mutation contract as the column sessions: re-trigger a
        # dispatch pass so the wake-event streams cannot drift
        self.runner._dispatch(self.now, self._events, self._seq)
        return True

    def record(self, handle: int) -> dict:
        """Per-request completion record."""
        req = self._reqs[handle]
        st = self._status.get(handle, DONE)
        status = {PENDING: "pending", QUEUED: "queued",
                  CANCELLED: "cancelled"}.get(st, "done")
        if st == QUEUED and handle not in self.runner.queue:
            status = "done" if req.finish is not None else "running"
        return {"handle": handle, "arrival": req.arrival,
                "deadline": req.deadline, "finish": req.finish,
                "first_token": req.first_token, "status": status,
                "violated": req.violated if req.finish is not None
                else None}

    # -- the clock ---------------------------------------------------------
    def step_until(self, t: float) -> None:
        """Advance virtual time, processing every event with time ≤ t."""
        _check_step_target(t)
        r = self.runner
        pend = self._pending
        events = self._events
        while True:
            ta = pend[0][0] if pend else INF
            tt = self._next_tick
            td = events[0][0] if events else INF
            if ta <= tt and ta <= td:
                et, kind = ta, 0
            elif tt <= td:
                et, kind = tt, 1
            else:
                et, kind = td, 2
            if et == INF or et > t:
                break
            self.events_processed += 1
            self.now = et
            r.now = et
            if kind == 0:
                _, _, req, payload = heapq.heappop(pend)
                if self._status.get(req.id) == CANCELLED:
                    self.events_processed -= 1
                    continue
                self._status[req.id] = QUEUED
                r.submit(req, payload)
            elif kind == 1:
                self._next_tick += r.tick
                if hasattr(r.policy, "on_tick"):
                    r.policy.on_tick(et, r)
                else:
                    r.drive(r.policy, et)
                r.core_samples.append((et, r.allocated_cores))
            else:
                heapq.heappop(events)
            r._dispatch(et, events, self._seq)
        self.now = max(self.now, t)

    def finish(self, horizon: Optional[float] = None) -> RunReport:
        """Drain to ``horizon`` (default: last arrival + 60 s) and
        aggregate the uniform report."""
        if horizon is None:
            horizon = self._max_arrival + 60.0 if self._reqs else 60.0
        self.step_until(horizon)
        self.runner.events_processed = self.events_processed
        return self.runner.results(horizon)


# --------------------------------------------------------------------------
# struct-of-arrays sessions
# --------------------------------------------------------------------------
class _ColumnSession:
    """Shared plumbing of the struct-of-arrays sessions: per-request
    columns as growable Python lists (converted to numpy once at report
    time), a byte per request for the handle lifecycle, the pending
    arrival heap, and the cancel-aware λ window.  Handles are row
    indices in submission order — exactly the indices the fast EDF
    queues carry."""

    # per-request columns: scalar reads/writes work on both backings;
    # the list backing additionally supports append (incremental submit)
    _COLUMNS = ("_send", "_arrival", "_cl", "_slo", "_dl", "_size",
                "_ptok", "_dtok", "_tbt", "_finish")

    def __init__(self, runner):
        self.runner = runner
        self.now = 0.0
        self.events_processed = 0
        self._n = 0
        self._send: List[float] = []
        self._arrival: List[float] = []
        self._cl: List[float] = []
        self._slo: List[float] = []
        self._dl: List[float] = []
        self._size: List[float] = []
        self._ptok: List[int] = []
        self._dtok: List[int] = []
        self._tbt: List[float] = []
        self._finish: List[float] = []
        # the batch-replay fast path keeps the columns as numpy arrays
        # (no per-request boxing at the million-request scale); the
        # first *incremental* submit converts them to lists once
        self._cols_are_arrays = False
        self._state = bytearray()
        self._pending: List[tuple] = []      # (arrival, handle)
        self._max_arrival = 0.0
        self._n_cancelled = 0
        # λ window: processed arrivals + retracted (cancelled) arrivals
        self._arr: List[float] = []
        self._w0 = 0
        self._cxl: List[float] = []
        self._cw0 = 0
        # batch-replay tick-granular λ: when the workload is one adopted
        # arrival-sorted column and nothing gets cancelled, the λ window
        # reads the column directly at tick time (tick_window_rate) and
        # the event loop skips the per-arrival append entirely
        self._tick_lam = False
        self._next_tick = 0.0

    def _ensure_lists(self) -> None:
        """Flip array-backed columns to appendable lists (one-time cost,
        only paid when batch submits are mixed with incremental ones)."""
        self._tick_off()
        if self._cols_are_arrays:
            for name in self._COLUMNS:
                setattr(self, name, getattr(self, name).tolist())
            self._cols_are_arrays = False

    def _tick_off(self) -> None:
        """Leave tick-granular λ mode: materialize the processed-arrival
        list the incremental estimator expects.  In batch-replay mode
        arrivals pop strictly in column order, so the processed set is
        exactly the first ``n - len(pending)`` rows; the window pointer
        ``_w0`` transfers unchanged."""
        if self._tick_lam:
            self._tick_lam = False
            k = self._n - len(self._pending)
            self._arr = np.asarray(self._arrival[:k], np.float64).tolist()

    # -- submission --------------------------------------------------------
    def submit(self, req: Optional[Request] = None, *,
               send: Optional[float] = None, comm_latency: float = 0.0,
               slo: float = 1.0, size_kb: float = 200.0,
               deadline: Optional[float] = None, prompt_tokens: int = 1,
               decode_tokens: int = 0, tbt_slo: float = INF,
               payload: Any = None) -> int:
        """Submit one request; returns its handle (the row index)."""
        if req is not None:
            send, comm_latency = req.arrival - req.comm_latency, \
                req.comm_latency
            slo, size_kb, deadline = req.slo, req.size_kb, req.deadline
            prompt_tokens, decode_tokens = req.prompt_tokens, \
                req.decode_tokens
            tbt_slo = req.tbt_slo
        send = float(send or 0.0)
        arrival = send + comm_latency
        if arrival < self.now - 1e-12:
            raise ValueError(f"arrival {arrival} is in the session's past "
                             f"(now={self.now})")
        dl = (send + slo) if deadline is None else float(deadline)
        self._ensure_lists()
        h = self._n
        self._n += 1
        self._send.append(send)
        self._arrival.append(arrival)
        self._cl.append(float(comm_latency))
        self._slo.append(float(slo))
        self._dl.append(dl)
        self._size.append(float(size_kb))
        self._ptok.append(int(prompt_tokens))
        self._dtok.append(int(decode_tokens))
        self._tbt.append(float(tbt_slo))
        self._finish.append(float("nan"))
        self._state.append(PENDING)
        heapq.heappush(self._pending, (arrival, h))
        self._max_arrival = max(self._max_arrival, arrival)
        self._on_submit()
        return h

    def submit_batch(self, batch: RequestBatch) -> range:
        """Submit a whole arrival-sorted workload in one vectorized
        append; returns the handle range."""
        n = len(batch)
        if n and np.any(np.diff(batch.arrival) < 0):
            raise ValueError("RequestBatch must be sorted by arrival")
        if n and float(batch.arrival[0]) < self.now - 1e-12:
            raise ValueError("batch starts in the session's past")
        h0 = self._n
        if h0 == 0 and not self._pending:
            # the batch-replay fast path: adopt the workload's columns
            # as (decoupled) numpy arrays — no per-request boxing
            self._send = np.array(batch.send, np.float64)
            self._arrival = np.array(batch.arrival, np.float64)
            self._cl = np.array(batch.comm_latency, np.float64)
            self._slo = np.array(batch.slo, np.float64)
            self._dl = np.array(batch.deadline, np.float64)
            self._size = np.array(batch.size_kb, np.float64)
            self._ptok = np.array(batch.prompt_tokens, np.int64)
            self._dtok = np.array(batch.decode_tokens, np.int64)
            self._tbt = np.array(batch.tbt_slo, np.float64)
            self._finish = np.full(n, np.nan)
            self._cols_are_arrays = True
            self._tick_lam = self._TICK_LAM
        else:
            self._ensure_lists()
            self._send.extend(batch.send.tolist())
            self._arrival.extend(batch.arrival.tolist())
            self._cl.extend(batch.comm_latency.tolist())
            self._slo.extend(batch.slo.tolist())
            self._dl.extend(batch.deadline.tolist())
            self._size.extend(batch.size_kb.tolist())
            self._ptok.extend(batch.prompt_tokens.tolist())
            self._dtok.extend(batch.decode_tokens.tolist())
            self._tbt.extend(batch.tbt_slo.tolist())
            self._finish.extend([float("nan")] * n)
        self._state.extend(bytes(n))
        pairs = list(zip(batch.arrival.tolist(), range(h0, h0 + n)))
        if self._pending:
            self._pending.extend(pairs)
            heapq.heapify(self._pending)
        else:
            self._pending = pairs            # sorted list is a valid heap
        self._n = h0 + n
        if n:
            self._max_arrival = max(self._max_arrival,
                                    float(batch.arrival[-1]))
        self._on_submit()
        return range(h0, h0 + n)

    def _on_submit(self) -> None:
        """Hook for subclasses (token sessions rebind queue columns)."""

    # -- renegotiation -----------------------------------------------------
    def update_slo(self, handle: int, *, deadline: Optional[float] = None,
                   slo: Optional[float] = None,
                   net_latency: Optional[float] = None) -> bool:
        """Renegotiate a pending or queued request's deadline; False once
        it has dispatched, finished, or been cancelled (or the handle is
        unknown)."""
        if not 0 <= handle < self._n:
            return False
        st = self._state[handle]
        if st >= DONE:
            return False
        new_dl = _new_deadline(self._send[handle], self._slo[handle],
                               deadline, slo, net_latency)
        if slo is not None:
            self._slo[handle] = float(slo)
        if st == PENDING:
            self._dl[handle] = new_dl
            return True
        if not self._requeue_update(handle, new_dl):
            return False
        self._dl[handle] = new_dl
        self._post_mutate()
        return True

    def _requeue_update(self, handle: int, new_dl: float) -> bool:
        return self.runner.queue.update_deadline(handle, new_dl)

    def cancel(self, handle: int) -> bool:
        """Withdraw a pending or queued request; double-cancel safe,
        unknown handles refused."""
        if not 0 <= handle < self._n:
            return False
        self._tick_off()     # cancels break the derived-count invariant
        st = self._state[handle]
        if st == PENDING:
            self._state[handle] = CANCELLED
            self._n_cancelled += 1
            return True
        if st != QUEUED or not self._requeue_cancel(handle):
            return False
        self._state[handle] = CANCELLED
        self._n_cancelled += 1
        insort(self._cxl, self._arrival[handle])   # retract from λ
        self._post_mutate()
        return True

    def _requeue_cancel(self, handle: int) -> bool:
        return self.runner.queue.cancel(handle)

    def _post_mutate(self) -> None:
        """Re-trigger dispatch after a mid-flight mutation."""
        self._dispatch(self.now)

    def _dispatch(self, t: float) -> None:  # pragma: no cover
        raise NotImplementedError

    def record(self, handle: int) -> dict:
        """Per-request completion record."""
        st = self._state[handle]
        fin = self._finish[handle]
        status = {PENDING: "pending", QUEUED: "queued",
                  CANCELLED: "cancelled"}.get(st, None)
        if status is None:
            status = "done" if fin == fin else "running"
        return {"handle": handle, "arrival": self._arrival[handle],
                "deadline": self._dl[handle],
                "finish": fin if fin == fin else None, "status": status,
                "violated": (fin > self._dl[handle] + 1e-9)
                if fin == fin else None}

    # -- λ -----------------------------------------------------------------
    # subclasses whose event loop mutates λ state mid-flight (the token
    # session retracts overrun-cancelled streams in-loop) opt out
    _TICK_LAM = True

    def _rate(self, now: float) -> float:
        r = self.runner
        if self._tick_lam:
            lam, self._w0 = tick_window_rate(
                self._arrival, self._w0, now, r.rate_window, r.prior_rps)
            return lam
        if self._cxl:
            lam, self._w0, self._cw0 = array_window_rate_cancel_aware(
                self._arr, len(self._arr), self._w0, now, r.rate_window,
                r.prior_rps, self._cxl, self._cw0)
        else:
            lam, self._w0 = array_window_rate(
                self._arr, len(self._arr), self._w0, now, r.rate_window,
                r.prior_rps)
        return lam

    # -- reporting ---------------------------------------------------------
    def _columns_batch(self) -> RequestBatch:
        return RequestBatch(
            send=np.asarray(self._send, np.float64),
            arrival=np.asarray(self._arrival, np.float64),
            comm_latency=np.asarray(self._cl, np.float64),
            slo=np.asarray(self._slo, np.float64),
            deadline=np.asarray(self._dl, np.float64),
            size_kb=np.asarray(self._size, np.float64),
            prompt_tokens=np.asarray(self._ptok, np.int64),
            decode_tokens=np.asarray(self._dtok, np.int64),
            tbt_slo=np.asarray(self._tbt, np.float64))

    def _default_horizon(self) -> float:
        return self._max_arrival + 60.0 if self._n else 60.0

    def finish(self, horizon: Optional[float] = None) -> RunReport:
        """Drain to ``horizon`` (default: last arrival + 60 s) and
        aggregate the uniform report."""
        if horizon is None:
            horizon = self._default_horizon()
        self.step_until(horizon)
        self.runner.events_processed = self.events_processed
        return self._report(horizon)

    def _report(self, horizon: float) -> RunReport:  # pragma: no cover
        raise NotImplementedError


class FastSession(_ColumnSession):
    """Online session over the struct-of-arrays :class:`FastSimRunner`.

    Owns the event cursor (pending arrivals, tick train, dynamic
    completions/wake-ups) and the dispatch pass; queue, slots and
    decision application stay on the runner.  ``FastSimRunner.run`` is a
    thin replay driver over this class.
    """

    def __init__(self, runner):
        super().__init__(runner)
        self._events: List[tuple] = []
        self._seq = itertools.count()
        self._busy_wake: Dict[int, float] = {}
        self._slack_wake: Dict[int, float] = {}

    def drive(self, policy, now: float) -> None:
        """One adaptation step (the runner drive path, session λ)."""
        due = policy.due(now) if hasattr(policy, "due") else True
        if not due:
            return
        lam = self._rate(now)
        r = self.runner
        wait0 = max(r.slots[0].busy_until - now, 0.0)
        d = policy.decide(now, r.queue, lam, initial_wait=wait0)
        r._apply(d, now)

    def step_until(self, t: float) -> None:
        """Advance virtual time, processing every event with time ≤ t."""
        _check_step_target(t)
        r = self.runner
        pend = self._pending
        events = self._events
        queue = r.queue
        dl = self._dl
        # tick-granular λ mode derives the window count from the arrival
        # column itself — no per-arrival Python append
        arr = None if self._tick_lam else self._arr
        state = self._state
        tick = r.tick
        policy = r.policy
        has_on_tick = hasattr(policy, "on_tick")
        pop = heapq.heappop
        n_events = 0
        while True:
            ta = pend[0][0] if pend else INF
            tt = self._next_tick
            td = events[0][0] if events else INF
            if ta <= tt and ta <= td:
                et, kind = ta, 0
            elif tt <= td:
                et, kind = tt, 1
            else:
                et, kind = td, 2
            if et == INF or et > t:
                break
            n_events += 1
            if kind == 0:
                _, h = pop(pend)
                if state[h] == CANCELLED:
                    n_events -= 1
                    continue
                state[h] = QUEUED
                queue.push(dl[h], h)
                if arr is not None:
                    arr.append(et)
            elif kind == 1:
                self._next_tick += tick
                self.now = et
                if has_on_tick:
                    policy.on_tick(et, self)
                else:
                    self.drive(policy, et)
                r.core_samples.append((et, r.allocated_cores))
            else:
                pop(events)
            self.now = et
            self._dispatch(et)
        self.events_processed += n_events
        self.now = max(self.now, t)

    # spongelint: inline-of repro.serving.api.ScenarioRunner._dispatch pin=bb8870a3cacd
    def _dispatch(self, t: float) -> None:
        """Slack-aware EDF dispatch over every slot (the FastSimRunner
        rules, verbatim: fill toward b, release a partial batch only
        under deadline pressure, precise deduplicated wake-ups)."""
        r = self.runner
        queue = r.queue
        if not queue._heap:
            return
        live = queue._live
        b_now = r.b
        lat = r._lat
        bucket_arr = r._bucket_arr
        margin = r.dispatch_margin
        tick = r.tick
        events = self._events
        seq = self._seq
        busy_wake = self._busy_wake
        slack_wake = self._slack_wake
        finish = self._finish
        state = self._state
        push = heapq.heappush
        for s in r.slots:
            if s.ready_at > t or s.busy_until > t:
                wake_t = (s.ready_at if s.ready_at > s.busy_until
                          else s.busy_until)
                if busy_wake.get(s.id) != wake_t:
                    busy_wake[s.id] = wake_t
                    push(events, (wake_t, next(seq), s.id))
                continue
            while queue._heap and s.busy_until <= t:
                if len(live) < b_now:
                    head_dl = queue._heap[0][0]
                    l_full = lat[(s.c, r._bucket(b_now))]
                    t_force = head_dl - l_full - margin
                    if t < t_force:
                        tw = min(t_force, t + tick)
                        if slack_wake.get(s.id) != tw:
                            slack_wake[s.id] = tw
                            push(events, (tw, next(seq), s.id))
                        break
                idxs = queue.pop_batch(b_now)
                m = len(idxs)
                bucket = int(bucket_arr[m])
                fin = t + lat[(s.c, bucket)]
                s.busy_until = fin
                r.bucket_log.append((t, s.c, bucket, m))
                for i in idxs:
                    finish[i] = fin
                    state[i] = DONE
                push(events, (fin, next(seq), s.id))

    def _report(self, horizon: float) -> RunReport:
        r = self.runner
        return build_array_report(
            r.policy, "sim-fast", self._columns_batch(),
            np.asarray(self._finish, np.float64), horizon,
            r.slots + r.dead, r.core_samples, r.bucket_log,
            n_cancelled=self._n_cancelled)


class TokenFastSession(_ColumnSession):
    """Online session over the continuous-batching
    :class:`TokenFastSimRunner`.

    Opts out of tick-granular λ (``_TICK_LAM = False``): speculative
    admission cancels overrun streams *inside* the step loop, which
    retracts arrivals from the λ window mid-flight — the derived-count
    shortcut would miss those retractions.

    Renegotiation applies to the *TTFT* deadline while a request waits
    for admission; once its prompt joins a decode step the stream is
    committed (``update_slo`` / ``cancel`` return False — exactly the
    point past which a real engine has spent the prefill).  Admission,
    step composition and the per-token accounting follow the batch
    loop's rules verbatim.

    Decode-length uncertainty (ISSUE 7): when the runner carries a
    non-point ``repro.core.uncertainty.UncertaintyConfig``, admission
    is *speculative* — every stream joins with a decode-token budget
    (``config.budget_tokens(slo)``: the per-SLO-class quantile estimate
    widened by the predictor's slack) and a stream that exhausts its
    budget before finishing is **cancelled at the step boundary**: its
    slot frees immediately, the cancel flows through the PR 5 machinery
    (λ retraction via the ``_cxl`` window + ``n_cancelled``) and the
    request is excluded from latency/violation aggregates (``finish``
    stays NaN).  Finished and overrun streams both feed the shared
    length predictor, closing the calibration → solver-slack loop.
    With no config (or a point mass) none of this code runs and the
    deterministic loop is bit-identical to before.
    """

    _TICK_LAM = False

    def __init__(self, runner):
        super().__init__(runner)
        self._first_tok: List[float] = []
        self._tbt_bad: List[bool] = []
        # the running decode streams + the step in flight
        self._run_idx: List[int] = []
        self._run_rem: List[int] = []
        self._run_tbt: List[float] = []
        self._step_end = INF
        self._step_start = 0.0
        self._step_admit: List[int] = []
        self._step_total_ptok = 0
        self._step_decoders = 0
        self._tokens_served = 0
        self._decode_tokens_served = 0
        self._tbt_viol_tokens = 0
        self._rebind = False
        # speculative admission (parallel to _run_idx when tracking):
        # per-stream token budgets + the length each was planned at
        unc = getattr(runner, "uncertainty", None)
        self._unc = unc
        self._track = unc is not None and not unc.is_point()
        self._spec = self._track and unc.speculative
        self._run_cap: List[int] = []
        self._run_pred: List[float] = []
        self._n_overrun = 0

    def _on_submit(self) -> None:
        n = self._n - len(self._first_tok)
        self._first_tok.extend([float("nan")] * n)
        self._tbt_bad.extend([False] * n)
        self._rebind = True

    def _bind(self) -> None:
        if self._rebind:
            self.runner.queue.bind(np.asarray(self._ptok, np.float64),
                                   np.asarray(self._tbt, np.float64))
            self._rebind = False

    def drive(self, policy, now: float, active_slots: int = 0,
              tbt_budget: float = INF, initial_wait: float = 0.0) -> None:
        """One adaptation step over the token-aware decide protocol."""
        due = policy.due(now) if hasattr(policy, "due") else True
        if not due:
            return
        self._bind()
        lam = self._rate(now)
        d = policy.decide(now, self.runner.queue, lam,
                          initial_wait=initial_wait,
                          active_slots=active_slots, tbt_budget=tbt_budget)
        self.runner._apply(d, now)

    def _post_mutate(self) -> None:
        """Admission happens at step boundaries only — nothing to do."""

    def _start_step(self, t0: float) -> float:
        """Admit waiting requests, compose the step, return its end
        (INF when there is no work to run).  Admission is EDF-ordered
        and chunk-bounded by the cost model's prefill-token allowance
        for the tightest running TBT — see ``TokenFastSimRunner``."""
        r = self.runner
        queue = r.queue
        cost = r.cost
        slot = r.slots[0]
        ptoks = self._ptok
        run_idx, run_tbt = self._run_idx, self._run_tbt
        free = r.b - len(run_idx)
        admit: List[int] = []
        total = 0
        if free > 0 and queue._heap:
            allowance = (cost.prefill_token_allowance(
                slot.c, len(run_idx), min(run_tbt))
                if run_tbt else INF)
            heap = queue._heap
            live = queue._live
            state = self._state
            while heap and len(admit) < free:
                dl0, i = heap[0]
                if live.get(i) != dl0:        # stale (renegotiated away)
                    heapq.heappop(heap)
                    continue
                if total + ptoks[i] > allowance:
                    break
                heapq.heappop(heap)
                del live[i]
                state[i] = DONE               # committed to the stream
                admit.append(i)
                total += ptoks[i]
            queue._fix_top()
        if not admit and not run_idx:
            return INF
        self._step_admit = admit
        self._step_total_ptok = total
        self._step_decoders = len(run_idx)
        l = cost.step_latency(slot.c, Composition(total,
                                                  self._step_decoders))
        l += r._pending_penalty
        r._pending_penalty = 0.0
        self._step_start = t0
        return t0 + l

    def step_until(self, t: float) -> None:
        """Advance virtual time, processing every event with time ≤ t."""
        _check_step_target(t)
        r = self.runner
        pend = self._pending
        queue = r.queue
        dl = self._dl
        dtoks = self._dtok
        tbts = self._tbt
        arr = self._arr
        state = self._state
        slot = r.slots[0]
        tick = r.tick
        policy = r.policy
        first_tok = self._first_tok
        finish = self._finish
        tbt_bad = self._tbt_bad
        pop = heapq.heappop
        n_events = 0
        while True:
            ta = pend[0][0] if pend else INF
            tt = self._next_tick
            se = self._step_end
            if ta <= tt and ta <= se:
                et, kind = ta, 0
            elif tt <= se:
                et, kind = tt, 1
            else:
                et, kind = se, 2
            if et == INF or et > t:
                break
            n_events += 1
            self.now = et
            if kind == 0:                        # arrival
                _, h = pop(pend)
                if state[h] == CANCELLED:
                    n_events -= 1
                    continue
                state[h] = QUEUED
                queue.push(dl[h], h)
                arr.append(et)
            elif kind == 1:                      # adaptation tick
                self._next_tick += tick
                run_tbt_min = (min(self._run_tbt) if self._run_tbt
                               else INF)
                iw = (max(self._step_end - et, 0.0)
                      if self._step_end < INF else 0.0)
                self.drive(policy, et, active_slots=len(self._run_idx),
                           tbt_budget=run_tbt_min, initial_wait=iw)
                r.core_samples.append((et, slot.c))
            else:                                # step boundary
                gap = et - self._step_start
                run_idx, run_rem, run_tbt = (self._run_idx, self._run_rem,
                                             self._run_tbt)
                run_cap, run_pred = self._run_cap, self._run_pred
                track, spec, unc = self._track, self._spec, self._unc
                nxt_idx: List[int] = []
                nxt_rem: List[int] = []
                nxt_tbt: List[float] = []
                nxt_cap: List[int] = []
                nxt_pred: List[float] = []
                for k in range(self._step_decoders):
                    i = run_idx[k]
                    self._tokens_served += 1
                    self._decode_tokens_served += 1
                    if gap > run_tbt[k] + 1e-12:
                        self._tbt_viol_tokens += 1
                        tbt_bad[i] = True
                    if run_rem[k] > 1:
                        if spec and run_cap[k] <= 1:
                            # cancel-on-overrun: the stream consumed its
                            # token budget without finishing — free the
                            # slot through the PR 5 cancel machinery
                            # (λ retraction + n_cancelled); finish stays
                            # NaN so aggregates exclude the request
                            state[i] = CANCELLED
                            self._n_cancelled += 1
                            self._n_overrun += 1
                            insort(self._cxl, float(self._arrival[i]))
                            unc.observe(run_pred[k], float(dtoks[i]),
                                        float(self._slo[i]))
                        else:
                            nxt_idx.append(i)
                            nxt_rem.append(run_rem[k] - 1)
                            nxt_tbt.append(run_tbt[k])
                            if track:
                                nxt_cap.append(run_cap[k] - 1)
                                nxt_pred.append(run_pred[k])
                    else:
                        finish[i] = et
                        if track:
                            unc.observe(run_pred[k], float(dtoks[i]),
                                        float(self._slo[i]))
                for i in self._step_admit:
                    first_tok[i] = et
                    self._tokens_served += 1
                    if dtoks[i] > 0:
                        nxt_idx.append(i)
                        nxt_rem.append(int(dtoks[i]))
                        nxt_tbt.append(float(tbts[i]))
                        if track:
                            s = float(self._slo[i])
                            nxt_pred.append(unc.planned_length(s))
                            nxt_cap.append(unc.budget_tokens(s)
                                           if spec else (1 << 60))
                    else:
                        finish[i] = et
                self._run_idx, self._run_rem, self._run_tbt = (
                    nxt_idx, nxt_rem, nxt_tbt)
                self._run_cap, self._run_pred = nxt_cap, nxt_pred
                self._step_admit = []
                self._step_decoders = 0
                self._step_end = self._start_step(et)
            if self._step_end == INF and (queue._heap or self._run_idx):
                self._step_end = self._start_step(et)
        self.events_processed += n_events
        self.now = max(self.now, t)

    def _report(self, horizon: float) -> RunReport:
        r = self.runner
        r.overrun_cancels = self._n_overrun   # telemetry for run stats
        return r._token_report(
            self._columns_batch(),
            np.asarray(self._first_tok, np.float64),
            np.asarray(self._finish, np.float64),
            np.asarray(self._tbt_bad, bool),
            self._tokens_served, self._decode_tokens_served,
            self._tbt_viol_tokens, horizon,
            n_cancelled=self._n_cancelled)


class FleetSession(_ColumnSession):
    """Online session over the struct-of-arrays
    :class:`~repro.serving.fleet.FleetFastSimRunner`.

    Mid-flight semantics on a fleet add one twist: **a tightened budget
    re-routes**.  The replica a request was originally routed to was
    chosen under the old deadline; when the budget tightens the request
    is pulled and re-offered to the configured router under its new
    deadline (cold-start aware, same tie-breaks as arrivals), while a
    relaxed budget re-keys in place.  Fleet disruptions
    (kill / restart events) flow through the same event cursor in the
    canonical tie order (arrivals, ticks, fleet events, completions).
    """

    def __init__(self, runner, fleet_events=()):
        super().__init__(runner)
        self._events: List[tuple] = []
        self._seq = itertools.count()
        self._busy_wake: Dict[int, float] = {}
        self._slack_wake: Dict[int, float] = {}
        self._fev = normalize_fleet_events(fleet_events)
        self._fi = 0

    # -- fleet-specific renegotiation --------------------------------------
    def _holding_replica(self, handle: int):
        for rep in self.runner.replicas:
            if handle in rep.queue._live:
                return rep
        return None

    def _requeue_update(self, handle: int, new_dl: float) -> bool:
        r = self.runner
        rep = self._holding_replica(handle)
        if rep is None:
            return False
        old = rep.queue._live[handle]
        track = r._track_dls
        if new_dl < old:
            # tightened: pull and re-offer through the arrival router
            rep.queue.cancel(handle)
            if track:
                del rep.dls[bisect_left(rep.dls, old)]
            j = route_request(r.router, r.replicas, new_dl, self.now,
                              cold_load=r._cold_load(self.now))
            tgt = r.replicas[j]
            tgt.queue.push(new_dl, handle)
            if track:
                insort(tgt.dls, new_dl)
        else:
            rep.queue.update_deadline(handle, new_dl)
            if track:
                del rep.dls[bisect_left(rep.dls, old)]
                insort(rep.dls, new_dl)
        return True

    def _requeue_cancel(self, handle: int) -> bool:
        rep = self._holding_replica(handle)
        if rep is None:
            return False
        old = rep.queue._live[handle]
        rep.queue.cancel(handle)
        if self.runner._track_dls:
            del rep.dls[bisect_left(rep.dls, old)]
        return True

    def _drive(self, t: float) -> None:
        """One adaptation step through the runner's single drive rule,
        with the session's cancel-aware λ."""
        r = self.runner
        pol = r.policy
        if hasattr(pol, "due") and not pol.due(t):
            return
        r._drive(t, lam=self._rate(t))

    def step_until(self, t: float) -> None:
        """Advance virtual time, processing every event with time ≤ t
        (arrivals, ticks, fleet events, completions — canonical order)."""
        _check_step_target(t)
        r = self.runner
        pend = self._pending
        events = self._events
        dl = self._dl
        arr = None if self._tick_lam else self._arr
        state = self._state
        fev = self._fev
        tick = r.tick
        track_dls = r._track_dls
        pop = heapq.heappop
        n_events = 0
        while True:
            ta = pend[0][0] if pend else INF
            tt = self._next_tick
            tf = fev[self._fi][0] if self._fi < len(fev) else INF
            td = events[0][0] if events else INF
            if ta <= tt and ta <= tf and ta <= td:
                et, kind = ta, 0
            elif tt <= tf and tt <= td:
                et, kind = tt, 1
            elif tf <= td:
                et, kind = tf, 2
            else:
                et, kind = td, 3
            if et == INF or et > t:
                break
            n_events += 1
            self.now = et
            if kind == 0:                        # arrival: route + enqueue
                _, h = pop(pend)
                if state[h] == CANCELLED:
                    n_events -= 1
                    continue
                state[h] = QUEUED
                j = route_request(r.router, r.replicas, dl[h], et,
                                  cold_load=r._cold_load(et))
                tgt = r.replicas[j]
                tgt.queue.push(dl[h], h)
                if track_dls:
                    insort(tgt.dls, dl[h])
                if arr is not None:
                    arr.append(et)
            elif kind == 1:                      # adaptation tick
                self._next_tick += tick
                self._drive(et)
                r.core_samples.append((et, r.allocated_cores))
            elif kind == 2:                      # fleet event
                _, ev_kind, ev_args = fev[self._fi]
                self._fi += 1
                r._fleet_event(ev_kind, ev_args, et)
            else:                                # completion / wake-up
                pop(events)
            self._dispatch(et)
        self.events_processed += n_events
        self.now = max(self.now, t)

    # spongelint: inline-of repro.serving.session.FastSession._dispatch pin=c5e1fc10d215
    def _dispatch(self, t: float) -> None:
        """Per-replica slack-aware EDF dispatch (FleetFastSimRunner
        rules, verbatim)."""
        r = self.runner
        b_now = r.b
        lat = r._lat
        bucket_arr = r._bucket_arr
        margin = r.dispatch_margin
        tick = r.tick
        track_dls = r._track_dls
        events = self._events
        seq = self._seq
        busy_wake = self._busy_wake
        slack_wake = self._slack_wake
        finish = self._finish
        state = self._state
        push = heapq.heappush
        for rep in r.replicas:
            q = rep.queue._heap
            if not q:
                continue
            if rep.ready_at > t or rep.busy_until > t:
                wake_t = (rep.ready_at if rep.ready_at > rep.busy_until
                          else rep.busy_until)
                if busy_wake.get(rep.id) != wake_t:
                    busy_wake[rep.id] = wake_t
                    push(events, (wake_t, next(seq), rep.id))
                continue
            live = rep.queue._live
            while q and rep.busy_until <= t:
                if len(live) < b_now:
                    head_dl = q[0][0]
                    l_full = lat[(rep.c, r._bucket(b_now))]
                    t_force = head_dl - l_full - margin
                    if t < t_force:
                        tw = min(t_force, t + tick)
                        if slack_wake.get(rep.id) != tw:
                            slack_wake[rep.id] = tw
                            push(events, (tw, next(seq), rep.id))
                        break
                idxs = rep.queue.pop_batch(b_now)
                m = len(idxs)
                if track_dls:
                    del rep.dls[:m]   # pop_batch took the m earliest
                bucket = int(bucket_arr[m])
                fin = t + lat[(rep.c, bucket)]
                rep.busy_until = fin
                r.bucket_log.append((t, rep.c, bucket, m))
                for i in idxs:
                    finish[i] = fin
                    state[i] = DONE
                push(events, (fin, next(seq), rep.id))

    def _report(self, horizon: float) -> RunReport:
        r = self.runner
        batch = self._columns_batch()
        finish = np.asarray(self._finish, np.float64)
        rep = build_array_report(
            r.policy, r.backend_name, batch, finish, horizon,
            r.replicas + r.dead, r.core_samples, r.bucket_log,
            n_cancelled=self._n_cancelled)
        return r._enrich_report(rep, finish, batch.deadline, horizon)
