"""The unified Sponge serving API: one control plane, pluggable everything.

The paper's control loop — IP solver + in-place vertical scaling + EDF
dynamic batching — used to be wired twice in this repo: once inside the
discrete-event ``ClusterSimulator`` and once inside the live
``ServingEngine``.  This module factors it into three protocols and one
facade so every policy, backend, and workload scenario is wired exactly
once:

* ``SchedulingPolicy`` — anything with ``decide(now, queue, lam,
  initial_wait) -> Decision`` (optionally ``due(now)``).  The Sponge
  scaler, the static baselines, the FA2-style horizontal autoscaler and
  the predictive scalers all speak this protocol; a ``Decision`` now
  carries a replica target ``n`` so horizontal actions are first-class.
* ``ExecutionBackend`` — a pool of vertically scalable server slots plus
  ``execute(batch, c, b, now) -> finish_time``.  ``SimBackend`` finishes
  batches on the calibrated ``PerfModel`` clock (the Fig. 4 path);
  ``JaxBackend`` runs the pre-jitted ``(c, b)`` executable table for real
  and can advance time either by the measured wall latency
  (``clock="measured"``) or by the model prediction (``clock="modeled"``,
  which makes live runs event-for-event reproducible against the
  simulator).  Both support multiple slots, so FA2-style horizontal
  baselines run on either substrate.
* ``ScenarioRunner`` — the single event loop: arrivals, adaptation ticks,
  slack-aware EDF dispatch, server-free events.  It feeds any workload
  script into any backend+policy pair and returns a uniform ``RunReport``
  (p50/p99, violation rate, core-seconds, decision + bucket logs).

``SpongeServer`` composes the three; ``make_sim_server`` /
``make_live_server`` build them config-driven (the live path resolves the
model through ``configs.registry``).  Adding a scenario is now: pick or
write one policy class, pick a backend, hand the runner a request script.

Legacy ``on_tick(now, sim)`` policies (e.g. ``MultiDimPolicy``) still
work: the runner exposes the old mutation facade (``pool``,
``add_server``, ``remove_servers``, ``set_batch``) and drives new-style
policies through the same path (``ScenarioRunner.drive``).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, runtime_checkable)

import numpy as np

from repro.core.monitor import Monitor
from repro.core.perf_model import PerfModel, yolov5s_like
from repro.core.queueing import EDFQueue
from repro.core.slo import Decision, Request
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.core.vertical import TimedExecutor, VerticalScaledInstance
from repro.serving.workload import WorkloadGenerator

_sid = itertools.count()


# --------------------------------------------------------------------------
# protocols
# --------------------------------------------------------------------------
@runtime_checkable
class SchedulingPolicy(Protocol):
    """One decision interface for every scaling policy."""
    name: str

    def decide(self, now: float, queue: EDFQueue, lam: float,
               initial_wait: float = 0.0) -> Decision: ...


@runtime_checkable
class ExecutionBackend(Protocol):
    """A pool of vertically scalable slots + a way to execute batches."""
    c_set: Tuple[int, ...]
    b_set: Tuple[int, ...]

    def apply(self, d: Decision, now: float) -> None: ...

    def execute(self, batch: List[Request], c: int, b: int,
                now: float) -> float: ...

    def core_seconds(self, horizon: float) -> float: ...


def round_up_c(c_set: Sequence[int], c: int) -> int:
    """Smallest available core count >= c (never round a feasible Decision
    down), falling back to max(c_set) when c exceeds every entry."""
    up = [cc for cc in c_set if cc >= c]
    return min(up) if up else max(c_set)


def resolve_decision(c_set: Sequence[int], d: Decision) -> Tuple[int, int]:
    """The ONE decision-application rule shared by every engine: ``c``
    rounds *up* to the nearest available entry (a feasible Decision must
    never be weakened — the PR 1 fix), ``b`` is floored at 1.  Both the
    object-based runner and the struct-of-arrays fast paths resolve
    through this helper so the rule cannot drift between engines."""
    return round_up_c(c_set, d.c), max(1, int(d.b))


# --------------------------------------------------------------------------
# server slots (shared by both backends)
# --------------------------------------------------------------------------
@dataclass
class Server:
    """One servable slot: a vertically scaled instance + availability."""
    instance: VerticalScaledInstance
    ready_at: float = 0.0
    busy_until: float = 0.0
    alive_since: float = 0.0
    dead_at: Optional[float] = None
    id: int = field(default_factory=lambda: next(_sid))

    def core_seconds(self, horizon: float) -> float:
        end = min(self.dead_at if self.dead_at is not None else horizon,
                  horizon)
        self.instance.account(max(end, self.alive_since))
        return self.instance.core_seconds


class _PooledBackend:
    """Slot-pool mechanics shared by SimBackend and JaxBackend: in-place
    vertical resize, horizontal scale to Decision.n (scale-ups may pay
    ``Decision.scale_up_delay`` before serving), core-second accounting."""

    name = "base"

    def __init__(self, perf: PerfModel, c_set: Sequence[int],
                 b_set: Sequence[int], c0: int = 1,
                 resize_penalty: float = 0.005):
        self.perf = perf
        self.c_set = tuple(sorted(c_set))
        self.b_set = tuple(sorted(b_set))
        self.resize_penalty = resize_penalty
        self.pool: List[Server] = []
        self.dead: List[Server] = []
        self.monitor: Optional[Monitor] = None   # bound by ScenarioRunner
        self.add_slot(c0, ready_at=0.0, now=0.0)

    # -- pool management ---------------------------------------------------
    def add_slot(self, c: int, ready_at: float = 0.0,
                 now: float = 0.0) -> Server:
        inst = VerticalScaledInstance(self.c_set, self.b_set, self.perf,
                                      c0=c, resize_penalty=self.resize_penalty)
        inst.account(now)
        srv = Server(instance=inst, ready_at=ready_at, alive_since=now)
        self.pool.append(srv)
        return srv

    def remove_slots(self, n: int, now: float) -> None:
        # remove youngest servers first, never the last one
        for _ in range(min(n, len(self.pool) - 1)):
            srv = self.pool.pop()
            srv.dead_at = max(now, srv.busy_until)
            self.dead.append(srv)

    @property
    def allocated_cores(self) -> int:
        return sum(s.instance.c for s in self.pool)

    def core_seconds(self, horizon: float) -> float:
        return (sum(s.core_seconds(horizon) for s in self.pool)
                + sum(s.core_seconds(horizon) for s in self.dead))

    # -- decision application (vertical + horizontal) ----------------------
    def apply(self, d: Decision, now: float) -> None:
        c, _ = resolve_decision(self.c_set, d)
        for srv in self.pool:
            penalty = srv.instance.resize(c, now)
            if penalty:
                srv.busy_until = max(srv.busy_until, now) + penalty
        n = max(1, getattr(d, "n", 1))
        cur = len(self.pool)
        if n > cur:
            for _ in range(n - cur):
                self.add_slot(c, ready_at=now + d.scale_up_delay, now=now)
        elif n < cur:
            self.remove_slots(cur - n, now)

    # -- hooks -------------------------------------------------------------
    def on_submit(self, req: Request, payload: Any) -> None:
        pass


class SimBackend(_PooledBackend):
    """Discrete-event execution: batch finish times come from the
    calibrated PerfModel — nothing actually runs (the Fig. 4 path)."""

    name = "sim"

    def execute(self, batch: List[Request], c: int, b: int,
                now: float) -> float:
        return now + float(self.perf.latency(b, c))


class TokenSimBackend(_PooledBackend):
    """Discrete-event *continuous-batching* execution over a token-level
    cost model (``repro.core.cost_model.TokenCostModel``).

    A dispatched gang is served phase-aware: one prefill burst covering
    every prompt (each request's **first token** — its TTFT — lands when
    the burst finishes), then decode steps in which every live stream
    gains one token and requests **leave the running batch as their
    streams finish** (step latency tracks the shrinking slot count, per
    the token cost model).  Per-request ``first_token`` / ``finish`` /
    ``tbt_violations`` are written here — the runner keeps whatever the
    backend recorded — and the slot frees when the last stream drains.

    The cost model also quacks like a PerfModel (full-service
    ``latency(b, c)``), which is what the runner's slack-aware dispatch
    and the pooled-slot bookkeeping consume.  True join-mid-stream
    continuous batching (new requests entering between decode steps of a
    running gang) lives in the struct-of-arrays
    ``repro.serving.fastpath.TokenFastSimRunner``; this backend keeps
    the object-based exact loop intact for token workloads.

    Decode-length uncertainty (ISSUE 7): a non-point
    ``repro.core.uncertainty.UncertaintyConfig`` arms speculative
    execution — every decode stream carries a token budget
    (``config.budget_tokens(slo)``) and a stream that exhausts it
    before finishing is cancelled mid-gang: its request is flagged
    ``cancelled`` (the runner routes it through
    ``Monitor.observe_cancel`` — PR 5's machinery, retracting its λ
    contribution and excluding it from every aggregate) and it stops
    consuming decode steps, so the gang shrinks exactly as the fast
    engine's slot frees.  Finished and overrun streams feed the shared
    length predictor.  With no config (or a point mass) the loop below
    runs verbatim — decision-identical to the pre-uncertainty backend.
    """

    name = "token-sim"

    def __init__(self, cost, c_set: Sequence[int], b_set: Sequence[int],
                 c0: int = 1, resize_penalty: float = 0.005,
                 uncertainty=None):
        super().__init__(cost, c_set, b_set, c0=c0,
                         resize_penalty=resize_penalty)
        self.cost = cost
        self.tokens_served = 0
        self.uncertainty = uncertainty
        self.overrun_cancels = 0

    def execute(self, batch: List[Request], c: int, b: int,
                now: float) -> float:
        unc = self.uncertainty
        track = unc is not None and not unc.is_point()
        spec = track and unc.speculative
        total_prompt = sum(r.prompt_tokens for r in batch)
        t = now + float(self.cost.prefill_latency(c, total_prompt))
        live: List[tuple[Request, int, int]] = []
        for r in batch:
            r.first_token = t
            self.tokens_served += 1          # the prefill's first token
            if r.decode_tokens > 0:
                cap = (unc.budget_tokens(r.slo) if spec else (1 << 60))
                live.append((r, r.decode_tokens, cap))
            else:
                r.finish = t
        while live:
            l_d = float(self.cost.decode_latency(c, len(live)))
            t += l_d
            nxt: List[tuple[Request, int, int]] = []
            for r, remaining, cap in live:
                if l_d > r.tbt_slo + 1e-12:
                    r.tbt_violations += 1
                self.tokens_served += 1
                if remaining - 1 > 0:
                    if spec and cap <= 1:
                        # cancel-on-overrun: budget spent, stream not
                        # done — drop it from the gang (the slot frees)
                        # and let the runner observe the cancel
                        r.cancelled = True
                        self.overrun_cancels += 1
                        if track:
                            unc.observe(unc.planned_length(r.slo),
                                        float(r.decode_tokens), r.slo)
                    else:
                        nxt.append((r, remaining - 1, cap - 1))
                else:
                    r.finish = t
                    if track:
                        unc.observe(unc.planned_length(r.slo),
                                    float(r.decode_tokens), r.slo)
            live = nxt
        return t


@dataclass
class ServedRequest:
    """A live-backend unit of work: the request, the payload it carried
    (e.g. a token array), and the model output filled in by ``execute``."""
    req: Request
    payload: Any
    result: Any = None


class JaxBackend(_PooledBackend):
    """Live execution over a pre-jitted ``(c, b)`` executable table.

    ``step_fns[(c, b)](stacked_payload)`` must be ready to call (compiled
    at deploy — that is what makes the resize in-place; on the TPU target
    each entry is the same step compiled on a c-chip submesh).  ``clock``
    selects how virtual time advances after a batch:

    * ``"measured"`` — by the measured wall latency (the serving default);
    * ``"modeled"``  — by ``perf.latency(b, c)``, making the event stream
      bit-identical to ``SimBackend`` for the same policy + workload
      *provided both backends charge the same resize_penalty* (real
      outputs are still produced and the measured-vs-predicted residual
      is still recorded).  Note the defaults differ deliberately:
      JaxBackend charges 0 — the dictionary flip is free on this
      container — while SimBackend models the TPU weight re-gather
      (5 ms); parity runs must align them, as the parity test does.

    Multi-slot pools are supported: a horizontal policy (FA2-style) can
    target ``Decision.n`` replicas and each slot executes through the
    table entry for its own core count.  Execution and wall-latency
    measurement go through one ``TimedExecutor`` (``core.vertical``).
    """

    name = "jax"

    def __init__(self, step_fns: Dict[tuple[int, int], Callable],
                 pad_payload: Callable, perf: PerfModel,
                 clock: str = "measured", c0: Optional[int] = None,
                 resize_penalty: float = 0.0):
        assert clock in ("measured", "modeled"), clock
        self.table = TimedExecutor(step_fns)
        self.step_fns = self.table.fns
        self.pad_payload = pad_payload
        self.clock = clock
        self.results: List[ServedRequest] = []
        self.measured: List[tuple[float, int, int, float]] = []
        self._payloads: Dict[int, Any] = {}
        c_set = sorted({c for c, _ in step_fns})
        b_set = sorted({b for _, b in step_fns})
        super().__init__(perf, c_set, b_set, c0=c0 or max(c_set),
                         resize_penalty=resize_penalty)

    def warmup(self, example_payload: Any) -> None:
        self.table.warmup(
            lambda c, b: (self.pad_payload([example_payload] * min(b, 2),
                                           b),))

    def on_submit(self, req: Request, payload: Any) -> None:
        self._payloads[req.id] = payload

    def execute(self, batch: List[Request], c: int, b: int,
                now: float) -> float:
        items = [ServedRequest(r, self._payloads.pop(r.id, None))
                 for r in batch]
        out = self.table(c, b, self.pad_payload(
            [it.payload for it in items], b))
        dt = self.table.calls[-1][3]
        for i, it in enumerate(items):
            it.result = _index_result(out, i)
            self.results.append(it)
        predicted = float(self.perf.latency(b, c))
        self.measured.append((now, c, b, dt))
        if self.monitor is not None:
            self.monitor.observe_perf_residual(predicted, dt)
        return now + (dt if self.clock == "measured" else predicted)


def _index_result(out: Any, i: int):
    import jax
    return jax.tree.map(lambda a: np.asarray(a)[i] if hasattr(a, "shape")
                        and getattr(a, "ndim", 0) > 0 else a, out)


# --------------------------------------------------------------------------
# the one loop
# --------------------------------------------------------------------------
@dataclass
class RunReport:
    """Uniform result of a scenario run, backend- and policy-agnostic.
    Dict-style access (``report["p99"]``) is kept for existing callers.

    Fields:

    * ``policy`` / ``backend`` — names of the pair that produced the run.
    * ``n_requests`` — requests observed by the monitor (served + dropped).
    * ``n_violations`` — requests finishing after their absolute deadline
      (strictly later than ``deadline + 1e-9``), plus any drops.
    * ``violation_rate`` — ``n_violations / max(n_requests, 1)``.
    * ``core_seconds`` — allocated-core integral over the horizon, resize
      penalties and dead replicas included (the paper's cost axis).
    * ``avg_cores`` — ``core_seconds / horizon``.
    * ``p50`` / ``p99`` / ``mean_latency`` — end-to-end latency statistics
      measured from client *send* time (comm latency included), seconds.
    * ``core_timeline`` — ``(tick_time, allocated_cores)`` samples.
    * ``decisions`` — the policy's ``(time, Decision)`` log when it keeps
      one (None otherwise).
    * ``buckets`` — per dispatched batch: ``(dispatch_time, cores,
      batch_bucket, actual_batch_len)``.

    Token-serving extras (zero/NaN on fixed-work runs):

    * ``tokens_served`` / ``tokens_per_s`` — generated tokens (first
      token + decode stream) and their rate over the horizon.
    * ``ttft_p50`` / ``ttft_p99`` — time-to-first-token percentiles
      measured from client send time, seconds.
    * ``tbt_violation_rate`` — fraction of decode tokens whose gap from
      the previous token exceeded the request's per-token SLO.

    Online-session extra (``repro.serving.session``):

    * ``n_cancelled`` — requests withdrawn mid-flight via
      ``SpongeSession.cancel``; excluded from every served/violation
      aggregate (0 on closed-world replays).

    Degradation extras (``repro.core.degradation`` fleets; NaN/0/None
    on single-model runs):

    * ``accuracy_goodput`` — accuracy-weighted goodput: the sum of the
      serving model's accuracy score over requests served *within* their
      deadline, divided by the horizon (Orloj's objective — a degraded
      answer in time beats a full-accuracy answer that is late, but
      counts for less than a full-accuracy answer in time).
    * ``mean_served_accuracy`` — mean accuracy score over served
      requests (degradation depth, independent of the rate axis).
    * ``model_swaps`` — committed model swaps over the run.
    * ``model_timeline`` — ``(t, rung_name, accuracy)`` resident-model
      segments (first entry at t=0).
    """
    policy: str
    backend: str
    n_requests: int
    n_violations: int
    violation_rate: float
    core_seconds: float
    avg_cores: float
    p50: float
    p99: float
    mean_latency: float
    core_timeline: List[tuple]
    decisions: Optional[List[tuple]]
    buckets: List[tuple]
    tokens_served: int = 0
    tokens_per_s: float = 0.0
    ttft_p50: float = float("nan")
    ttft_p99: float = float("nan")
    tbt_violation_rate: float = 0.0
    n_cancelled: int = 0
    accuracy_goodput: float = float("nan")
    mean_served_accuracy: float = float("nan")
    model_swaps: int = 0
    model_timeline: Optional[List[tuple]] = None

    def __getitem__(self, key: str):
        return getattr(self, key)

    def get(self, key: str, default=None):
        return getattr(self, key, default)

    def keys(self):
        return [f.name for f in dataclasses.fields(self)]

    def as_dict(self) -> dict:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}


def build_array_report(policy, backend: str, batch, finish: np.ndarray,
                       horizon: float, slots, core_samples,
                       bucket_log, n_cancelled: int = 0) -> RunReport:
    """The ONE report aggregation shared by the struct-of-arrays engines
    (``fastpath.FastSimRunner`` and both ``fleet`` runners): served mask
    over the ``finish`` column, violations strictly past ``deadline +
    1e-9``, end-to-end latency from client send time, the nearest-rank
    percentile rule, and the per-slot core-seconds integral clamped to
    each slot's release point.  Centralized so the acceptance metrics
    (the violation epsilon, the percentile indexing) cannot drift
    between the single-replica and fleet engines."""
    served = ~np.isnan(finish)
    fin = finish[served]
    n_req = int(served.sum())
    viol = int((fin > batch.deadline[served] + 1e-9).sum())
    e2e = np.sort(fin - (batch.arrival[served]
                         - batch.comm_latency[served]))
    nn = e2e.size

    def p(q: float) -> float:
        if not nn:
            return float("nan")
        return float(e2e[min(int(q * nn), nn - 1)])

    core_s = 0.0
    for s in slots:
        end = min(s.dead_at if s.dead_at is not None else horizon,
                  horizon)
        s.account(max(end, s.alive_since))
        core_s += s.core_seconds
    decisions = getattr(policy, "decisions", None)
    if decisions is None:
        decisions = getattr(getattr(policy, "scaler", None),
                            "decisions", None)
    return RunReport(
        policy=getattr(policy, "name", type(policy).__name__),
        backend=backend,
        n_requests=n_req,
        n_violations=viol,
        violation_rate=viol / max(n_req, 1),
        core_seconds=core_s,
        avg_cores=core_s / max(horizon, 1e-9),
        p50=p(0.50), p99=p(0.99),
        mean_latency=float(e2e.sum()) / max(nn, 1),
        core_timeline=core_samples,
        decisions=decisions,
        buckets=bucket_log,
        n_cancelled=n_cancelled,
    )


class ScenarioRunner:
    """The single Sponge control loop: request arrivals, adaptation ticks,
    slack-aware EDF dispatch, server-free events — over any
    (policy, backend) pair.

    The event engine lives on the runner's **online session**
    (``repro.serving.session.ExactSession``): arrivals sit on a pending
    heap keyed ``(arrival, submission order)`` — the price of accepting
    live submits in any order — while adaptation ticks are generated
    incrementally and only dynamic events (batch completions and
    precise wake-ups, deduplicated per slot) join the dynamic heap.
    The pre-refactor loop is kept verbatim in
    ``repro.serving.reference`` as the equivalence oracle;
    ``repro.serving.fastpath`` is the struct-of-arrays engine for
    simulation at full scale (this object-based runner materializes a
    ``Request`` plus one heap tuple per submit, so it is the
    small-scale / live-backend path).

    Dispatch waits to fill the scaler's batch size b and releases a
    partial batch only when the head request's deadline would otherwise
    be at risk (GrandSLAm-style timeout).  Legacy ``on_tick(now, sim)``
    policies receive this runner as ``sim`` and may mutate the pool
    through ``add_server`` / ``remove_servers`` / ``set_batch``;
    decide-protocol policies are driven through :meth:`drive`.
    """

    def __init__(self, policy, backend, tick: float = 1.0,
                 dispatch_margin: float = 0.02):
        self.policy = policy
        self.backend = backend
        self.tick = tick
        self.dispatch_margin = dispatch_margin
        self.queue = EDFQueue()
        self.monitor = Monitor()
        backend.monitor = self.monitor
        self.b = 1
        self.now = 0.0
        self.events_processed = 0
        self.core_samples: List[tuple[float, int]] = []
        self.bucket_log: List[tuple[float, int, int, int]] = []

    # -- facade used by policies (legacy and new) --------------------------
    @property
    def pool(self) -> List[Server]:
        return self.backend.pool

    @property
    def c_set(self) -> Tuple[int, ...]:
        return self.backend.c_set

    @property
    def b_set(self) -> Tuple[int, ...]:
        return self.backend.b_set

    @property
    def allocated_cores(self) -> int:
        return self.backend.allocated_cores

    def add_server(self, c: int, ready_at: float = 0.0) -> Server:
        return self.backend.add_slot(c, ready_at=ready_at, now=self.now)

    def remove_servers(self, n: int, now: float) -> None:
        self.backend.remove_slots(n, now)

    def set_batch(self, b: int) -> None:
        self.b = max(1, int(b))

    def apply_decision(self, d: Decision, now: float) -> None:
        _, b = resolve_decision(self.backend.c_set, d)
        self.set_batch(b)
        self.backend.apply(d, now)

    def drive(self, policy, now: float) -> None:
        """Run one adaptation step of a decide-protocol policy."""
        due = policy.due(now) if hasattr(policy, "due") else True
        if not due:
            return
        lam = self.monitor.rate.rate(now)
        wait0 = max(self.pool[0].busy_until - now, 0.0)
        d = policy.decide(now, self.queue, lam, initial_wait=wait0)
        self.apply_decision(d, now)

    def submit(self, req: Request, payload: Any = None) -> None:
        self.monitor.observe_arrival(req)
        self.queue.push(req)
        self.backend.on_submit(req, payload)

    # -- main loop ---------------------------------------------------------
    def session(self) -> "repro.serving.session.ExactSession":
        """Open an online session on this runner (``submit`` /
        ``update_slo`` / ``cancel`` / ``step_until`` — see
        ``repro.serving.session``).  One session per runner."""
        from repro.serving.session import ExactSession
        return ExactSession(self)

    def run(self, arrivals, horizon: Optional[float] = None) -> RunReport:
        """``arrivals``: Requests, (Request, payload) pairs for live
        backends, or a ``RequestBatch`` (materialized on entry).  Runs the
        event loop to ``horizon`` (default: last arrival + 60 s) in
        virtual time and returns a RunReport.

        This is a thin replay driver over :meth:`session`: every arrival
        is submitted up front (onto the session's pending heap) and the
        session drains to the horizon.  The event cursor merges the
        pending arrivals, the incremental tick train and the dynamic
        completion/wake-up heap with the same total order the reference
        loop produces: time ascending; at equal times arrivals, then
        ticks, then dynamic events in push order.  Every event is
        followed by one dispatch pass.
        """
        from repro.serving.workload import RequestBatch
        if isinstance(arrivals, RequestBatch):
            arrivals = arrivals.to_requests()
        norm = [(a, None) if isinstance(a, Request) else (a[0], a[1])
                for a in arrivals]
        norm.sort(key=lambda p: p[0].arrival)   # stable: ties keep order
        if horizon is None:
            horizon = norm[-1][0].arrival + 60.0 if norm else 60.0
        sess = self.session()
        for req, payload in norm:
            sess.submit(req, payload=payload)
        return sess.finish(horizon)

    def _dispatch(self, t: float, events, seq) -> None:
        queue = self.queue
        if not len(queue):
            return
        for srv in self.pool:
            if srv.ready_at > t or srv.busy_until > t:
                # a slot busy (or cold-starting) past this event with
                # queued work gets a precise wake-up: a resize penalty can
                # extend busy_until beyond the slot's scheduled "free"
                # event, which would otherwise strand the queue until the
                # next tick
                wake_t = max(srv.ready_at, srv.busy_until)
                if self._wake.get(srv.id) != wake_t:
                    self._wake[srv.id] = wake_t
                    heapq.heappush(events,
                                   (wake_t, next(seq), "check", srv.id))
                continue
            while len(queue) and srv.ready_at <= t and srv.busy_until <= t:
                q = len(queue)
                if q < self.b:
                    head = queue.peek()
                    l_full = srv.instance.latency(self.b)
                    t_force = head.deadline - l_full - self.dispatch_margin
                    if t < t_force:
                        # re-check when deadline pressure bites (new
                        # arrivals also re-trigger dispatch); dedup per
                        # slot so a waiting server schedules one wake-up
                        tw = min(t_force, t + self.tick)
                        if self._slack_wake.get(srv.id) != tw:
                            self._slack_wake[srv.id] = tw
                            heapq.heappush(events,
                                           (tw, next(seq), "check", srv.id))
                        break
                batch = queue.pop_batch(self.b)
                bucket = srv.instance.bucket_b(len(batch))
                fin = self.backend.execute(batch, srv.instance.c, bucket, t)
                srv.busy_until = fin
                self.bucket_log.append((t, srv.instance.c, bucket,
                                        len(batch)))
                for r in batch:
                    r.start_proc = t
                    if r.cancelled:
                        # cancel-on-overrun (speculative token backend):
                        # PR 5's machinery — retract λ, count in
                        # n_cancelled, keep it out of every aggregate
                        self.monitor.observe_cancel(r)
                        continue
                    if r.finish is None:   # phase-aware backends record
                        r.finish = fin     # per-request finishes themselves
                    self.monitor.observe_completion(r)
                heapq.heappush(events, (fin, next(seq), "free", srv.id))

    def results(self, horizon: float) -> RunReport:
        mon = self.monitor
        total_core_s = self.backend.core_seconds(horizon)
        lat = mon.e2e_latencies()
        decisions = getattr(self.policy, "decisions", None)
        if decisions is None:
            decisions = getattr(getattr(self.policy, "scaler", None),
                                "decisions", None)
        token_kw = {}
        streamed = [r for r in mon.completed if r.first_token is not None]
        if streamed:
            ttft = sorted(r.first_token - (r.arrival - r.comm_latency)
                          for r in streamed)
            tokens = sum(1 + r.decode_tokens for r in streamed)
            dec_tokens = sum(r.decode_tokens for r in streamed)
            tbt_viol = sum(r.tbt_violations for r in streamed)
            token_kw = dict(
                tokens_served=tokens,
                tokens_per_s=tokens / max(horizon, 1e-9),
                ttft_p50=ttft[min(int(0.50 * len(ttft)), len(ttft) - 1)],
                ttft_p99=ttft[min(int(0.99 * len(ttft)), len(ttft) - 1)],
                tbt_violation_rate=tbt_viol / max(dec_tokens, 1))
        return RunReport(
            policy=getattr(self.policy, "name", type(self.policy).__name__),
            backend=getattr(self.backend, "name", "?"),
            n_requests=mon.n_total,
            n_violations=mon.n_violations,
            violation_rate=mon.violation_rate,
            core_seconds=total_core_s,
            avg_cores=total_core_s / max(horizon, 1e-9),
            p50=mon.p(0.50), p99=mon.p(0.99),
            mean_latency=sum(lat) / max(len(lat), 1),
            core_timeline=self.core_samples,
            decisions=decisions,
            buckets=self.bucket_log,
            n_cancelled=mon.n_cancelled,
            **token_kw,
        )


# --------------------------------------------------------------------------
# facade + config-driven construction
# --------------------------------------------------------------------------
class SpongeServer:
    """Facade composing SchedulingPolicy + ExecutionBackend + the runner."""

    def __init__(self, policy, backend, tick: float = 1.0,
                 dispatch_margin: float = 0.02, prior_rps: float = 0.0):
        self.policy = policy
        self.backend = backend
        self.runner = ScenarioRunner(policy, backend, tick=tick,
                                     dispatch_margin=dispatch_margin)
        self.runner.monitor.rate.prior_rps = prior_rps

    @property
    def monitor(self) -> Monitor:
        return self.runner.monitor

    @property
    def queue(self) -> EDFQueue:
        return self.runner.queue

    @property
    def pool(self) -> List[Server]:
        return self.backend.pool

    def warmup(self, example_payload: Any) -> None:
        self.backend.warmup(example_payload)

    def session(self):
        """Open an online session on the composed runner (``submit`` /
        ``update_slo`` / ``cancel`` / ``step_until`` — the live-client
        surface; see ``repro.serving.session``)."""
        return self.runner.session()

    def run(self, arrivals: Sequence, horizon: Optional[float] = None
            ) -> RunReport:
        return self.runner.run(arrivals, horizon)

    def serve(self, workload: WorkloadGenerator, trace,
              duration: Optional[float] = None,
              horizon: Optional[float] = None) -> RunReport:
        """Generate a workload against a bandwidth trace and run it."""
        return self.run(workload.generate(trace, duration), horizon)


POLICY_NAMES = ("sponge", "sponge-pred", "fa2", "static-8", "static-16",
                "static-<cores>")


def make_policy(name: str, perf: PerfModel, *,
                c_set: Sequence[int] = DEFAULT_C,
                b_set: Sequence[int] = DEFAULT_B,
                adaptation_interval: float = 1.0,
                slo: float = 1.0, expected_rps: float = 0.0,
                **kw):
    """Policy registry: one name -> one SchedulingPolicy instance."""
    from repro.core.baselines import FA2Policy, SpongePolicy, StaticPolicy
    from repro.core.scaler import SpongeScaler
    if name == "sponge":
        return SpongePolicy(SpongeScaler(
            perf, c_set=tuple(c_set), b_set=tuple(b_set),
            adaptation_interval=adaptation_interval, **kw))
    if name == "sponge-pred":
        from repro.core.predictive import (PredictivePolicy,
                                           PredictiveSpongeScaler)
        return PredictivePolicy(PredictiveSpongeScaler(
            perf, c_set=tuple(c_set), b_set=tuple(b_set),
            adaptation_interval=adaptation_interval, **kw))
    if name == "fa2":
        return FA2Policy(perf, slo=slo, b_set=tuple(b_set),
                         expected_rps=expected_rps, **kw)
    if name.startswith("static"):
        cores = int(name.split("-")[1]) if "-" in name else 16
        return StaticPolicy(perf, cores=cores, b_set=tuple(b_set),
                            interval=adaptation_interval, **kw)
    raise KeyError(f"unknown policy {name!r}; known: {POLICY_NAMES}")


def make_sim_server(perf: Optional[PerfModel] = None,
                    policy="sponge", *,
                    c_set: Sequence[int] = DEFAULT_C,
                    b_set: Sequence[int] = DEFAULT_B,
                    c0: int = 1, tick: float = 1.0,
                    prior_rps: float = 0.0,
                    resize_penalty: float = 0.005,
                    dispatch_margin: float = 0.02,
                    **policy_kw) -> SpongeServer:
    """Simulation server: calibrated PerfModel backend + named policy."""
    perf = perf if perf is not None else yolov5s_like()
    pol = (make_policy(policy, perf, c_set=c_set, b_set=b_set, **policy_kw)
           if isinstance(policy, str) else policy)
    backend = SimBackend(perf, c_set, b_set, c0=c0,
                         resize_penalty=resize_penalty)
    return SpongeServer(pol, backend, tick=tick,
                        dispatch_margin=dispatch_margin, prior_rps=prior_rps)


def calibrate_step_fns(fns: Dict[tuple[int, int], Callable],
                       example_for: Callable[[int, int], Any],
                       robust: bool = False) -> PerfModel:
    """Profile every (c, b) executable once and fit the paper's l(b, c)."""
    table = TimedExecutor(fns)
    table.warmup(lambda c, b: (example_for(c, b),))   # compile pass
    for (c, b) in fns:
        table(c, b, example_for(c, b))
    return PerfModel.fit([(b, c, dt) for _, c, b, dt in table.calls],
                         robust=robust)


def build_llm_step_fns(model, params, c_set: Sequence[int],
                       b_set: Sequence[int], prompt_len: int,
                       gen_tokens: int = 8):
    """Executable table for short-generation LLM serving on the reduced
    models: each entry prefills the prompt batch and decodes gen_tokens.

    On TPU each (c, b) would be compiled on its c-chip submesh; on CPU the
    same jitted fn backs every c (see ``JaxBackend``).
    """
    import jax
    import jax.numpy as jnp

    def make(b):
        def fn(tokens):
            logits, cache = model.prefill(params, {"tokens": tokens},
                                          cache_len=prompt_len + gen_tokens)
            def body(carry, _):
                cache, tok = carry
                lg, cache = model.decode_step(params, cache, tok)
                nxt = jnp.argmax(
                    lg[:, :model.cfg.vocab_size], axis=-1
                ).astype(jnp.int32)[:, None]
                return (cache, nxt), nxt[:, 0]
            first = jnp.argmax(logits[:, :model.cfg.vocab_size],
                               axis=-1).astype(jnp.int32)[:, None]
            (_, _), toks = jax.lax.scan(body, (cache, first),
                                        None, length=gen_tokens)
            return toks.T  # (b, gen_tokens)
        return jax.jit(fn)

    fns = {}
    for b in b_set:
        jitted = make(b)
        for c in c_set:
            fns[(c, b)] = jitted
    return fns


def pad_tokens(payloads: List[np.ndarray], b: int) -> np.ndarray:
    """Stack int32 token payloads to the batch bucket ``b``, repeating
    the last entry as padding."""
    x = np.stack(payloads + [payloads[-1]] * (b - len(payloads)))
    return x.astype(np.int32)


def make_live_server(arch: str = "smollm-135m-reduced", *,
                     c_set: Sequence[int] = (1, 2, 4, 8),
                     b_set: Sequence[int] = (1, 2, 4, 8),
                     prompt_len: int = 16, gen_tokens: int = 8,
                     policy="sponge", adaptation_interval: float = 0.5,
                     prior_rps: float = 0.0, clock: str = "measured",
                     perf: Optional[PerfModel] = None,
                     tick: Optional[float] = None, **policy_kw):
    """Live server: resolve ``arch`` through ``configs.registry``, build +
    calibrate the jitted (c, b) executable table, wire the control plane.
    Returns ``(server, model_config)``."""
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    fns = build_llm_step_fns(model, params, c_set, b_set, prompt_len,
                             gen_tokens=gen_tokens)
    if perf is None:
        perf = calibrate_step_fns(
            fns, lambda c, b: np.ones((b, prompt_len), np.int32))
    pol = (make_policy(policy, perf, c_set=c_set, b_set=b_set,
                       adaptation_interval=adaptation_interval, **policy_kw)
           if isinstance(policy, str) else policy)
    backend = JaxBackend(fns, pad_tokens, perf, clock=clock)
    server = SpongeServer(
        pol, backend,
        tick=tick if tick is not None else adaptation_interval,
        prior_rps=prior_rps)
    return server, cfg


# --------------------------------------------------------------------------
# tiny executable table for smoke tests / demos / parity tests
# --------------------------------------------------------------------------
def toy_step_fns(c_set: Sequence[int], b_set: Sequence[int],
                 dim: int = 32, seed: int = 0):
    """Minimal jitted (c, b) table — a tanh layer — for exercising the
    JaxBackend cheaply.  Every c shares the same computation on this CPU
    container, exactly like ``build_llm_step_fns``."""
    import jax
    import jax.numpy as jnp
    w = jnp.asarray(np.random.default_rng(seed)
                    .standard_normal((dim, dim)) / np.sqrt(dim),
                    jnp.float32)

    def make(_b):
        return jax.jit(lambda x: jnp.tanh(x @ w))

    fns = {}
    for b in b_set:
        jitted = make(b)
        for c in c_set:
            fns[(c, b)] = jitted
    return fns


def pad_vectors(payloads: List[np.ndarray], b: int) -> np.ndarray:
    """Stack float payloads to the batch bucket ``b``, repeating the last
    entry as padding (the toy-table counterpart of ``engine.pad_tokens``)."""
    x = np.stack(list(payloads) + [payloads[-1]] * (b - len(payloads)))
    return x.astype(np.float32)
