"""`lax.scan`-jitted decode-stream engine prototype (ISSUE 8 tentpole).

``TokenFastSimRunner`` steps its continuous-batching decode stream one
engine step at a time in Python.  This module re-expresses that step
loop as a **pure** ``(carry, xs) -> (carry, ys)`` function over
fixed-size arrays, compiled with ``jax.lax.scan`` + ``jax.jit`` (the
jitted pure-function idiom from SNIPPETS.md §3), with a NumPy fallback
that runs the *same* step function in a Python loop when JAX is absent.

Model (a deliberately simplified decode stream, documented rather than
bit-matched to ``TokenFastSimRunner``):

* state lives in dense request-indexed arrays over the
  **deadline-presorted** workload — join and leave are masked writes,
  never compaction;
* per step, admission is EDF among arrived un-admitted requests:
  ``rank = cumsum(eligible)`` caps joins at the free slot count, and a
  second masked ``cumsum`` over prompt tokens enforces the prefill
  allowance with break-at-first-overflow prefix semantics (the head
  request always admits, so an oversized prompt runs over allowance
  instead of stalling the stream forever);
* step latency is the token cost model's composition surface quantized
  to **integer microseconds** (``dt = A_p·T + A_d·S + B``); all state
  is integer, so the JAX and NumPy backends compute *identical* values
  — no float contraction or accumulation-order hazards — and the
  engine asserts bit-identity is even possible (horizon < 2^31 µs);
* decisions (new ``(c, b)``) apply at **chunk boundaries**: the host
  runs ``K`` steps per compiled chunk, re-derives the integer cost
  coefficients for the new ``c``, and hands the updated scalars back
  to the same traced function (0-d arrays, so no retrace).

Equivalence contract (``tests/test_scanpath.py``): decision streams,
first-token/finish columns, per-request TBT-violation counts and
core-seconds are identical with and without JAX present.  The JAX
backend exists for RL-scale rollouts (ROADMAP open item 2) where
thousands of simulated traces amortize one compile.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.cost_model import Composition, TokenCostModel
from repro.serving.workload import RequestBatch

try:  # pragma: no cover - exercised via both-backend parity tests
    import jax
    import jax.numpy as jnp
    from jax import lax
    HAVE_JAX = True
except Exception:  # pragma: no cover
    jax = jnp = lax = None
    HAVE_JAX = False

_BIG = np.int32(2**31 - 2)


def _coefficients(cost: TokenCostModel, c: int) -> Tuple[int, int, int]:
    """Integer-µs step-latency coefficients at core count ``c``:
    ``dt_us = A_p·T + A_d·S + B`` for ``T`` prefill tokens and ``S``
    decode slots.  Derived host-side once per chunk, so both backends
    consume identical integers."""
    a_p = cost.gamma_p / c + cost.delta_p
    a_d = cost.gamma_d / c + cost.delta_d
    b = cost.eps / c + cost.eta
    return (int(round(a_p * 1e6)), int(round(a_d * 1e6)),
            int(round(b * 1e6)))


def _step(xp, state, cols, knobs):
    """One decode-stream engine step — pure, backend-agnostic (``xp``
    is ``numpy`` or ``jax.numpy``).  All arithmetic is exact integer
    math, so both backends produce identical values."""
    t, adm, done, rem, first, fin, viol, nsteps = state
    arrival, ptok, tbt = cols
    a_p, a_d, b0, cap, allow = knobs
    i32 = xp.int32
    active = adm & ~done
    s_cnt = xp.sum(active.astype(i32))
    # EDF admission: arrays are deadline-presorted, so a masked cumsum
    # IS the earliest-deadline-first rank
    eligible = (arrival <= t) & ~adm
    rank = xp.cumsum(eligible.astype(i32))
    mask1 = eligible & (rank <= (cap - s_cnt))
    cumtok = xp.cumsum(xp.where(mask1, ptok, xp.int32(0)))
    # break at first overflow, but the head request always admits: an
    # oversized prompt must run (over allowance) rather than livelock
    # the idle-jump (next arrival already <= t, so time cannot advance)
    head1 = xp.cumsum(mask1.astype(i32)) == 1
    newly = mask1 & ((cumtok <= allow) | head1)
    t_cnt = xp.sum(xp.where(newly, ptok, xp.int32(0)))
    advance = (s_cnt + t_cnt) > 0
    dt = a_p * t_cnt + a_d * s_cnt + b0
    # idle: jump to the next un-admitted arrival (if any)
    na = xp.min(xp.where(~adm, arrival, _BIG))
    t_end = xp.where(advance, t + dt,
                     xp.where(xp.any(~adm), xp.maximum(t, na), t))
    adm = adm | newly
    first = xp.where(newly, t_end, first)
    rem = xp.where(active, rem - 1, rem)
    just_done = active & (rem <= 0)
    done = done | just_done
    fin = xp.where(just_done, t_end, fin)
    viol = viol + xp.where(active & (dt > tbt), xp.int32(1), xp.int32(0))
    nsteps = nsteps + xp.where(advance, xp.int32(1), xp.int32(0))
    return (t_end, adm, done, rem, first, fin, viol, nsteps)


class ScanDecodeEngine:
    """Chunked decode-stream simulator: ``K`` steps per compiled chunk,
    decisions at chunk boundaries, identical results on the JAX and
    NumPy backends.

    ``decide`` (optional) is called host-side at every chunk boundary
    with ``(t_seconds, n_waiting, n_active)`` and returns ``(c, b)``;
    the default holds ``(c0, b0)`` static.  Use
    :func:`make_sponge_decide` to adapt a ``SpongeScaler``."""

    def __init__(self, cost: TokenCostModel, *, c0: int = 8, b0: int = 8,
                 chunk_steps: int = 64,
                 prefill_allowance: int = 1 << 30,
                 decide: Optional[Callable] = None):
        self.cost = cost
        self.c0 = int(c0)
        self.b0 = int(b0)
        self.chunk_steps = int(chunk_steps)
        self.prefill_allowance = int(prefill_allowance)
        self.decide = decide
        self.decisions: List[tuple] = []
        self._jit_chunk = None

    # -- backends ----------------------------------------------------------
    def _chunk_numpy(self, state, cols, knobs):
        for _ in range(self.chunk_steps):
            state = _step(np, state, cols, knobs)
        return state

    def _chunk_jax(self, state, cols, knobs):
        if self._jit_chunk is None:
            k = self.chunk_steps

            def chunk(state, cols, knobs):
                def body(st, _):
                    return _step(jnp, st, cols, knobs), None
                st, _ = lax.scan(body, state, None, length=k)
                return st
            self._jit_chunk = jax.jit(chunk)
        return self._jit_chunk(state, cols, knobs)

    # -- entry point -------------------------------------------------------
    def run(self, batch: RequestBatch, horizon: Optional[float] = None,
            backend: str = "auto") -> dict:
        """Simulate the whole workload; returns a dict with per-request
        ``first_tok`` / ``finish`` (seconds, NaN if never served),
        ``tbt_violations`` counts, the decision stream, ``core_seconds``
        and ``steps``.  ``backend`` is ``auto`` (JAX if importable),
        ``jax`` or ``numpy``."""
        if backend == "auto":
            backend = "jax" if HAVE_JAX else "numpy"
        if backend == "jax" and not HAVE_JAX:
            raise RuntimeError("jax backend requested but jax is not "
                               "importable")
        n = len(batch)
        arrival = np.asarray(batch.arrival, np.float64)
        if horizon is None:
            horizon = (float(arrival[-1]) + 60.0) if n else 60.0
        if horizon * 1e6 >= 2**31:
            raise ValueError("scanpath is int32-µs; horizon must be "
                             "< ~2147 s")
        # deadline-presorted request space (EDF admission by cumsum)
        dl = np.asarray(batch.deadline, np.float64)
        order = np.argsort(dl, kind="stable")
        inv = np.empty(n, np.int64)
        inv[order] = np.arange(n)

        def us(x):
            return np.asarray(np.round(np.asarray(x, np.float64) * 1e6),
                              np.int32)
        cols = (us(arrival[order]),
                np.maximum(np.asarray(batch.prompt_tokens,
                                      np.int64)[order], 1).astype(np.int32),
                np.minimum(np.asarray(batch.tbt_slo,
                                      np.float64)[order] * 1e6,
                           float(_BIG)).astype(np.int32))
        rem0 = np.maximum(np.asarray(batch.decode_tokens,
                                     np.int64)[order], 1).astype(np.int32)
        state = (np.int32(0),
                 np.zeros(n, bool), np.zeros(n, bool), rem0,
                 np.full(n, -1, np.int32), np.full(n, -1, np.int32),
                 np.zeros(n, np.int32), np.int32(0))
        run_chunk = (self._chunk_jax if backend == "jax"
                     else self._chunk_numpy)
        c, b = self.c0, self.b0
        self.decisions = []
        horizon_us = int(horizon * 1e6)
        core_us = 0
        while True:
            t_us = int(np.asarray(state[0]))
            done = np.asarray(state[2])
            if t_us >= horizon_us or bool(done.all()):
                break
            if self.decide is not None:
                adm = np.asarray(state[1])
                arrived = np.asarray(cols[0]) <= t_us
                c, b = self.decide(t_us / 1e6,
                                   int((arrived & ~adm).sum()),
                                   int((adm & ~done).sum()))
            self.decisions.append((t_us / 1e6, int(c), int(b)))
            a_p, a_d, b_us = _coefficients(self.cost, c)
            knobs = (np.int32(a_p), np.int32(a_d), np.int32(b_us),
                     np.int32(b), np.int32(self.prefill_allowance))
            state = run_chunk(state, cols, knobs)
            t_end = min(int(np.asarray(state[0])), horizon_us)
            core_us += c * max(t_end - t_us, 0)
        first = np.asarray(state[4], np.int64)[inv]
        fin = np.asarray(state[5], np.int64)[inv]
        viol = np.asarray(state[6], np.int64)[inv]
        to_s = lambda col: np.where(col >= 0, col / 1e6, np.nan)
        return {"backend": backend,
                "first_tok": to_s(first), "finish": to_s(fin),
                "tbt_violations": viol,
                "decisions": list(self.decisions),
                "core_seconds": core_us / 1e6,
                "steps": int(np.asarray(state[7])),
                "n_served": int((fin >= 0).sum())}


def make_sponge_decide(scaler, cost: TokenCostModel,
                       c_set, b_set) -> Callable:
    """Adapt a queue-pressure heuristic over the solver's ``(c, b)``
    grid for chunk-boundary decisions: pick the smallest core count
    whose projected step latency clears the busiest slot cap.  (A
    deliberately simple stand-in for the IP solver — chunk boundaries
    are coarse, and the prototype's contract is backend parity, not
    solver fidelity.)"""
    c_set = sorted(c_set)
    b_set = sorted(b_set)

    def decide(t_s: float, n_waiting: int, n_active: int):
        want = n_waiting + n_active
        b = next((bb for bb in b_set if bb >= want), b_set[-1])
        for c in c_set:
            if cost.step_latency(c, Composition(0, b)) <= getattr(
                    scaler, "target_step_latency", 0.1):
                return c, b
        return c_set[-1], b
    return decide
