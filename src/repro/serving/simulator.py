"""Discrete-event cluster simulator for the Fig. 4 study — now a thin
construction shim over the unified serving API.

.. deprecated::
    New code should construct through ``repro.serving.api``
    (``make_sim_server`` or ``ScenarioRunner`` + ``SimBackend``), or use
    ``repro.serving.fastpath.FastSimRunner`` for million-request traces.
    This module remains only for callers of the historical
    ``ClusterSimulator`` signature.

The event loop, EDF dispatch, pool management and reporting live in
``repro.serving.api.ScenarioRunner``; this module only binds it to a
``SimBackend`` (batch finish times from the calibrated PerfModel) with the
historical constructor signature.  The same runner drives the live engine
(``repro.serving.engine``) — only the ExecutionBackend differs.
"""
from __future__ import annotations

import warnings
from typing import List, Optional, Sequence

from repro.core.perf_model import PerfModel
from repro.core.slo import Request
from repro.serving.api import (RunReport, ScenarioRunner, Server, SimBackend)

warnings.warn(
    "repro.serving.simulator is deprecated: construct through "
    "repro.serving.api (make_sim_server / ScenarioRunner + SimBackend) "
    "or repro.serving.fastpath.FastSimRunner for million-request traces "
    "— see the migration note in docs/api.md",
    DeprecationWarning, stacklevel=2)

__all__ = ["ClusterSimulator", "Server", "simulate"]


class ClusterSimulator(ScenarioRunner):
    """ScenarioRunner preconfigured with a SimBackend.

    Deprecated shim — prefer ``repro.serving.api.make_sim_server``.
    Accepts both decide-protocol policies (``repro.serving.api``) and
    legacy ``on_tick(now, sim)`` policies that mutate the pool directly.
    """

    def __init__(self, perf: PerfModel, policy,
                 c_set: Sequence[int], b_set: Sequence[int],
                 tick: float = 1.0, c0: int = 1,
                 resize_penalty: float = 0.005,
                 dispatch_margin: float = 0.02):
        self.perf = perf
        backend = SimBackend(perf, c_set, b_set, c0=c0,
                             resize_penalty=resize_penalty)
        super().__init__(policy, backend, tick=tick,
                         dispatch_margin=dispatch_margin)

    @property
    def dead(self) -> List[Server]:
        return self.backend.dead


def simulate(perf: PerfModel, policy, requests: List[Request],
             c_set, b_set, tick: float = 1.0, c0: int = 1,
             horizon: Optional[float] = None,
             resize_penalty: float = 0.005) -> RunReport:
    sim = ClusterSimulator(perf, policy, c_set, b_set, tick=tick, c0=c0,
                           resize_penalty=resize_penalty)
    return sim.run(requests, horizon)
