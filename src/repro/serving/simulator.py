"""Discrete-event cluster simulator for the Fig. 4 study.

Event-driven: request arrivals, policy adaptation ticks, server-free events.
Servers process EDF batches sequentially; processing latency comes from the
calibrated PerfModel via each server's VerticalScaledInstance.  The same
simulator runs Sponge (1 vertically scaled server), FA2 (N one-core servers
with cold starts) and the static baselines — only the Policy differs.

The live (non-simulated) engine in ``repro.serving.engine`` shares the
queue/scaler/monitor components but executes real JAX functions.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.core.baselines import Policy
from repro.core.monitor import Monitor
from repro.core.perf_model import PerfModel
from repro.core.queueing import EDFQueue
from repro.core.slo import Request
from repro.core.vertical import VerticalScaledInstance

_sid = itertools.count()


@dataclass
class Server:
    instance: VerticalScaledInstance
    ready_at: float = 0.0
    busy_until: float = 0.0
    alive_since: float = 0.0
    dead_at: Optional[float] = None
    id: int = field(default_factory=lambda: next(_sid))

    def core_seconds(self, horizon: float) -> float:
        end = min(self.dead_at if self.dead_at is not None else horizon,
                  horizon)
        self.instance.account(max(end, self.alive_since))
        return self.instance.core_seconds


class ClusterSimulator:
    def __init__(self, perf: PerfModel, policy: Policy,
                 c_set: Sequence[int], b_set: Sequence[int],
                 tick: float = 1.0, c0: int = 1,
                 resize_penalty: float = 0.005,
                 dispatch_margin: float = 0.02):
        self.perf = perf
        self.policy = policy
        self.c_set = tuple(c_set)
        self.b_set = tuple(b_set)
        self.tick = tick
        self.resize_penalty = resize_penalty
        self.dispatch_margin = dispatch_margin
        self.queue = EDFQueue()
        self.monitor = Monitor()
        self.b = 1
        self.pool: List[Server] = []
        self.dead: List[Server] = []
        self.now = 0.0
        self.core_samples: List[tuple[float, int]] = []
        self.add_server(c0, ready_at=0.0)

    # -- pool management (used by policies) --------------------------------
    def add_server(self, c: int, ready_at: float = 0.0) -> Server:
        inst = VerticalScaledInstance(self.c_set, self.b_set, self.perf,
                                      c0=c, resize_penalty=self.resize_penalty)
        inst.account(self.now)
        srv = Server(instance=inst, ready_at=ready_at,
                     alive_since=self.now)
        self.pool.append(srv)
        return srv

    def remove_servers(self, n: int, now: float) -> None:
        # remove youngest idle-most servers first, never the last one
        for _ in range(min(n, len(self.pool) - 1)):
            srv = self.pool.pop()
            srv.dead_at = max(now, srv.busy_until)
            self.dead.append(srv)

    def set_batch(self, b: int) -> None:
        self.b = max(1, int(b))

    @property
    def allocated_cores(self) -> int:
        return sum(s.instance.c for s in self.pool)

    # -- main loop ----------------------------------------------------------
    def run(self, requests: List[Request], horizon: Optional[float] = None):
        horizon = horizon or (max(r.arrival for r in requests) + 60.0)
        events: list[tuple[float, int, str, object]] = []
        seq = itertools.count()
        for r in requests:
            heapq.heappush(events, (r.arrival, next(seq), "arrival", r))
        t = 0.0
        while t <= horizon:
            heapq.heappush(events, (t, next(seq), "tick", None))
            t += self.tick

        while events:
            t, _, kind, payload = heapq.heappop(events)
            if t > horizon:
                break
            self.now = t
            if kind == "arrival":
                req: Request = payload
                self.monitor.observe_arrival(req)
                self.queue.push(req)
            elif kind == "tick":
                self.policy.on_tick(t, self)
                self.core_samples.append((t, self.allocated_cores))
            # "free" / "check": fall through to the dispatch pass

            self._dispatch(t, events, seq)

        return self.results(horizon)

    def _dispatch(self, t: float, events, seq) -> None:
        """Slack-aware dynamic batching: wait to fill the scaler's batch
        size b; dispatch a partial batch only when the head request's
        deadline would otherwise be at risk (GrandSLAm-style timeout)."""
        for srv in self.pool:
            while (len(self.queue) and srv.ready_at <= t
                   and srv.busy_until <= t):
                q = len(self.queue)
                if q < self.b:
                    head = self.queue.peek()
                    l_full = srv.instance.latency(self.b)
                    t_force = head.deadline - l_full - self.dispatch_margin
                    if t < t_force:
                        # re-check when deadline pressure bites (new
                        # arrivals also re-trigger dispatch)
                        heapq.heappush(events, (min(t_force, t + self.tick),
                                                next(seq), "check", srv.id))
                        break
                batch = self.queue.pop_batch(self.b)
                lat = srv.instance.latency(len(batch))
                fin = t + lat
                srv.busy_until = fin
                for r in batch:
                    r.start_proc = t
                    r.finish = fin
                    self.monitor.observe_completion(r)
                heapq.heappush(events, (fin, next(seq), "free", srv.id))

    def results(self, horizon: float) -> dict:
        mon = self.monitor
        total_core_s = (sum(s.core_seconds(horizon) for s in self.pool)
                        + sum(s.core_seconds(horizon) for s in self.dead))
        lat = mon.e2e_latencies()
        return {
            "policy": getattr(self.policy, "name", "?"),
            "n_requests": mon.n_total,
            "n_violations": mon.n_violations,
            "violation_rate": mon.violation_rate,
            "core_seconds": total_core_s,
            "avg_cores": total_core_s / max(horizon, 1e-9),
            "p50": mon.p(0.50), "p99": mon.p(0.99),
            "mean_latency": sum(lat) / max(len(lat), 1),
            "core_timeline": self.core_samples,
            "decisions": getattr(self.policy, "scaler", None).decisions
            if hasattr(self.policy, "scaler") else None,
        }


def simulate(perf: PerfModel, policy: Policy, requests: List[Request],
             c_set, b_set, tick: float = 1.0, c0: int = 1,
             horizon: Optional[float] = None,
             resize_penalty: float = 0.005) -> dict:
    sim = ClusterSimulator(perf, policy, c_set, b_set, tick=tick, c0=c0,
                           resize_penalty=resize_penalty)
    return sim.run(requests, horizon)
