"""The vectorized batched-tick control plane (ISSUE 8 tentpole).

``FastSimRunner`` (``serving.fastpath``) already strips the object model
down to struct-of-arrays columns, but its event loop still steps one
event at a time in Python: one heap push per arrival, one dispatch
evaluation per event, one λ-pointer increment per request.  At 10M
requests the interpreter is the ceiling.  :class:`VectorSimRunner`
replays the *identical* closed-world event stream window-at-a-time:

* **Vectorized arrival ingestion** — all arrivals inside an
  inter-decision window are admitted with one EDF merge into the sorted
  live set (append-only when the workload's deadline column is globally
  non-decreasing — every mono-SLO scenario — and an argsort +
  ``searchsorted`` + ``insert`` merge otherwise) instead of per-request
  heap pushes.  The merge is exact: new requests carry handles strictly
  larger than every live handle, so inserting at ``side="right"``
  reproduces the heap's ``(deadline, handle)`` pop order bit-for-bit.
  The live set rides in amortized-growth buffers, so the common append
  is two slice writes.
* **Batched dispatch** — between two control events the server either
  drains back-to-back full batches (launch times are the running sum
  ``t, t+l, t+2l, …`` with one fancy-indexed ``finish`` write for the
  whole burst) or sits idle until the *provably next* launch instant —
  the fill arrival that tops the queue up to ``b``, or the slack
  boundary ``head_deadline - latency(c, b) - margin`` that the
  fastpath's wake chain converges to.  Only genuine decision points
  touch Python; everything per-request is an array op.
* **Batched λ updates** — both sliding-window pointers (the observed
  count ``ai`` and the left edge ``w0``) are precomputed for *every*
  adaptation tick with two vectorized ``searchsorted`` calls over the
  whole arrival column before the loop starts; each tick's λ is then
  three scalar flops.  Bit-identical to the per-arrival counter
  (:class:`repro.core.monitor.RateEstimator` /
  :func:`~repro.core.monitor.array_window_rate`) because the canonical
  event order processes every arrival at time ``T`` *before* the tick
  at ``T``, and the tick times themselves are rebuilt with
  ``np.cumsum`` — the same left-fold float chain as ``nt += tick``.
* **Batched decision lookups** — when the policy is the stock
  ``SpongePolicy`` over a memo-solver ``SpongeScaler``, the tick step
  probes the :class:`repro.core.solver.MemoizedSolver` cache directly
  under the solver's own quantized key (the scaler's exact
  headroom/λ-headroom arithmetic followed by ``_quantize``, evaluated
  in preallocated scratch buffers) and replays the scaler's two side
  effects (``_next_t``, the decision log) on a hit — skipping the
  per-tick Python ``decide`` wrapper without changing a single emitted
  Decision (misses fall through to the real ``decide``, which
  populates the same cache under the same key).  Decision application
  is memoized per ``(c, b)`` through the same
  :func:`repro.serving.api.resolve_decision` rule.

Equivalence contract: on every registered closed-world scenario the
decision stream, violation buckets, report floats and core-seconds are
**bit-identical** to ``FastSimRunner`` (``tests/test_determinism.py`` /
``tests/test_vectorpath.py``).  That holds because this engine reuses
the same ``_apply`` / ``_Slot`` accounting, the same latency table, the
same ``build_array_report`` aggregation, and replays dispatch decisions
at exactly the times the event loop would have made them (the wake
chain ``tw = min(t_force, t + tick)`` always lands on ``t_force``
within a window, because a window is at most one tick long).

Scope: the closed-world replay path (``run(batch)``) on a **single**
vertically scaled slot — the paper's Sponge mechanism.  Policies that
emit horizontal targets (``Decision.n > 1``, e.g. the FA2 baseline) or
legacy ``on_tick`` mutators are rejected with a pointer to the fast
path; mid-flight session mutation (submit/cancel/update_slo) stays on
``FastSession``.  See ``docs/performance.md`` for the three speed
tiers and when to pick each.
"""
from __future__ import annotations

import math
from typing import List, Optional

import numpy as np

from repro.core.baselines import SpongePolicy
from repro.core.scaler import SpongeScaler
from repro.serving.api import (RunReport, build_array_report,
                               resolve_decision)
from repro.serving.fastpath import FastSimRunner
from repro.serving.workload import RequestBatch

_INF = float("inf")


# spongelint: inline-of repro.core.monitor.array_window_rate pin=48cc23b00a85
def _lam_at(a: np.ndarray, ai: int, w0: int, now: float,
            window_s: float, prior: float) -> float:
    """:func:`repro.core.monitor.array_window_rate` with the window
    pointers ``(ai, w0)`` precomputed (vectorized ``searchsorted`` over
    the whole tick vector) instead of walked per call — the same
    single-arrival guard and deploy-prior blend, flop for flop."""
    if ai == w0:
        obs = 0.0
    elif ai - w0 == 1:
        obs = 1.0 / window_s
    else:
        span = min(window_s, max(now - a[w0], 1e-6))
        obs = (ai - w0) / span
    if prior <= 0:
        return obs
    seen = max(now - a[0], 0.0) if ai > 0 else 0.0
    w = min(seen / window_s, 1.0)
    return obs * w + prior * (1.0 - w)


class _ArrayEDFView:
    """Read-only EDF queue facade over the runner's sorted live arrays.

    Exposes exactly the surface policies consume (``remaining_array``,
    ``snapshot_remaining``, ``__len__``, ``peek_deadline``).  Because
    the live set is kept sorted by ``(deadline, handle)``,
    ``remaining_array`` is a single vectorized subtraction that matches
    ``FastEDFQueue.remaining_array`` (which sorts its live map) element
    for element."""

    __slots__ = ("_r",)

    def __init__(self, runner: "VectorSimRunner"):
        self._r = runner

    def __len__(self) -> int:
        r = self._r
        return r._qt - r._qh

    def peek_deadline(self) -> Optional[float]:
        r = self._r
        return float(r._q_dl[r._qh]) if r._qt > r._qh else None

    def remaining_array(self, now: float) -> np.ndarray:
        r = self._r
        return r._q_dl[r._qh:r._qt] - now

    def snapshot_remaining(self, now: float) -> List[float]:
        return self.remaining_array(now).tolist()


class VectorSimRunner(FastSimRunner):
    """Window-at-a-time replay of the ``FastSimRunner`` event stream.

    Same constructor, same report, same floats — see the module
    docstring for the equivalence argument.  ``events_processed``
    counts arrivals + adaptation ticks + batch launches (the control
    events the reference loop also pays for; the fastpath's dedup'd
    wake pokes are bookkeeping artifacts and are not counted, which
    only *understates* this engine's events/s)."""

    def run(self, batch: RequestBatch,
            horizon: Optional[float] = None) -> RunReport:
        a = np.asarray(batch.arrival, np.float64)
        n = int(a.size)
        if n and np.any(np.diff(a) < 0):
            raise ValueError("RequestBatch must be sorted by arrival time")
        if n and a[0] < -1e-12:
            raise ValueError("arrival times must be non-negative")
        if horizon is None:
            horizon = (float(a[-1]) + 60.0) if n else 60.0
        if len(self.slots) != 1:
            raise NotImplementedError(
                "vectorpath is single-slot; use FastSimRunner")
        self._acol = a
        self._n_arr = n
        self._dlcol = np.asarray(batch.deadline, np.float64)
        # a globally non-decreasing deadline column (every mono-SLO
        # scenario) turns the EDF merge into a pure append
        self._dl_mono = bool(n < 2 or
                             not np.any(np.diff(self._dlcol) < 0))
        self._hidx = np.arange(n, dtype=np.int64)
        self._finish = np.full(n, np.nan)
        cap = 256
        self._q_dl = np.empty(cap, np.float64)
        self._q_idx = np.empty(cap, np.int64)
        self._qh = 0          # live region is [_qh, _qt)
        self._qt = 0
        # Python-float mirror of the live deadline region [_qh, _qt) —
        # lets the tick loop build its front-cache key with scalar math
        self._q_dll: List[float] = []
        self._p = 0           # arrivals ingested so far (λ pointer too)
        self._now = 0.0
        self._view = _ArrayEDFView(self)
        self._n_batches = 0
        # tick fast path: probe the memo solver's decision cache under
        # its own quantized key (stock SpongePolicy + memo scaler only)
        pol = self.policy
        self._has_due = hasattr(pol, "due")
        self._fast_scaler = self._fast_memo = None
        if type(pol) is SpongePolicy:
            sc = pol.scaler
            if type(sc) is SpongeScaler and sc.solver == "memo":
                self._fast_scaler = sc
                self._fast_memo = sc.memo
        tick = self.tick
        if not tick > 0.0:
            raise ValueError(f"tick must be positive, got {tick!r}")
        # The exact tick chain: the event loop runs `nt += tick` from
        # 0.0 while nt <= horizon.  np.cumsum is the same sequential
        # left-fold addition, so T reproduces every nt bit-for-bit.
        n_up = int(horizon / tick) + 3
        steps = np.full(n_up, tick)
        steps[0] = 0.0
        T = np.cumsum(steps)
        n_ticks = int(T.searchsorted(horizon, side="right"))
        assert n_ticks < n_up, (n_ticks, n_up)
        T = T[:n_ticks]
        # batched λ-window pointers: arrivals observed by each tick
        # (arrivals at T ingest before the tick) and the left window
        # edge — array_window_rate's while-walk, two searchsorted calls
        P = a.searchsorted(T, side="right")
        W0 = a.searchsorted(T - self.rate_window, side="left")
        np.minimum(W0, P, out=W0)   # the walk never passes ai
        if self._fast_scaler is not None:
            self._run_ticks_fast(T.tolist(), P.tolist(), W0.tolist())
        else:
            for nt, pk, wk in zip(T.tolist(), P.tolist(), W0.tolist()):
                self._advance(nt, True, pk)
                self._tick_step(nt, wk, pk)
                self._now = nt
        self._advance(horizon, False,
                      int(a.searchsorted(horizon, side="right")))
        self.events_processed = self._p + n_ticks + self._n_batches
        return build_array_report(self.policy, "sim-vector", batch,
                                  self._finish, horizon,
                                  self.slots + self.dead,
                                  self.core_samples, self.bucket_log)

    # -- control events ----------------------------------------------------
    def _tick_step(self, now: float, w0: int, ai: int) -> None:
        """One adaptation tick for an arbitrary policy: batched λ,
        decide, apply — replicating ``FastSession.drive`` (due-gate,
        tick-granular λ over the whole arrival column, ``initial_wait``
        from the slot's backlog).  The stock Sponge policy takes
        :meth:`_run_ticks_fast` instead."""
        pol = self.policy
        if not self._has_due or pol.due(now):
            lam = _lam_at(self._acol, ai, w0, now,
                          self.rate_window, self.prior_rps)
            wait0 = self.slots[0].busy_until - now
            if wait0 < 0.0:
                wait0 = 0.0
            d = pol.decide(now, self._view, lam, initial_wait=wait0)
            if max(1, getattr(d, "n", 1)) != 1:
                raise NotImplementedError(
                    "vectorpath serves one vertically scaled slot; "
                    "horizontal Decision.n targets need FastSimRunner")
            self._apply(d, now)
            if len(self.slots) != 1:  # pragma: no cover - guarded above
                raise NotImplementedError("vectorpath is single-slot")
        self.core_samples.append((now, self.allocated_cores))

    def _run_ticks_fast(self, Tl: List[float], Pl: List[int],
                        Wl: List[int]) -> None:
        """The whole tick loop for the stock ``SpongePolicy`` over a
        memo-solver ``SpongeScaler``, with every per-tick constant
        hoisted out of the loop:

        * λ from the precomputed window pointers (three scalar flops);
        * the scaler's decide() arithmetic verbatim down to the memo
          solver's ``_quantize``, evaluated in a reused scratch buffer
          (the queue snapshot is already deadline-sorted, so the memo's
          ``np.sort`` would be the identity), then one dict probe; hits
          replay the scaler's two side effects, misses fall through to
          the real ``decide`` which caches under the same key;
        * decision application memoized per ``(d.c, d.b)`` through the
          shared ``resolve_decision`` rule, with the slot's
          core-seconds integrated in place (``_Slot.account``'s exact
          accumulation order).
        """
        sc = self._fast_scaler
        memo = self._fast_memo
        cache = memo.cache
        decs = sc.decisions
        hr = sc.headroom
        lh = sc.lam_headroom
        bq = memo.budget_quantum
        lq = memo.lam_quantum
        ai_step = sc.adaptation_interval
        pen = self.resize_penalty
        pol = self.policy
        s = self.slots[0]
        samples = self.core_samples
        window_s = self.rate_window
        prior = self.prior_rps
        a = self._acol
        a0 = a[0] if self._n_arr else 0.0
        rcache: dict = {}
        # front cache: quantized-state *value* tuple -> Decision.  The
        # scalar key math below is flop-for-flop the ufunc path (same
        # IEEE double ops), so key equality coincides with the memo
        # solver's byte-key equality; a front hit therefore implies a
        # memo hit for the same Decision, and only front misses pay the
        # array round trip that produces the memo's exact byte key.
        front: dict = {}
        scratch = np.empty(1024)
        ceil = math.ceil
        floor = math.floor
        adv = self._advance
        prev = self._now
        for nt, ai, w0 in zip(Tl, Pl, Wl):
            # _advance's busy head-case inline: the slot works past the
            # whole window, so the window is pure bulk ingest
            bu = s.busy_until
            if bu > prev and bu >= nt:
                if ai > self._p:
                    self._ingest(ai)
            else:
                adv(nt, True, ai)
            if nt + 1e-12 >= sc._next_t:        # SpongeScaler.due
                # λ — _lam_at inlined
                # spongelint: inline-of repro.serving.vectorpath._lam_at pin=6a807a195429
                if ai == w0:
                    obs = 0.0
                elif ai - w0 == 1:
                    obs = 1.0 / window_s
                else:
                    span = min(window_s, max(nt - a[w0], 1e-6))
                    obs = (ai - w0) / span
                if prior <= 0:
                    lam = obs
                else:
                    seen = max(nt - a0, 0.0) if ai > 0 else 0.0
                    wgt = min(seen / window_s, 1.0)
                    lam = obs * wgt + prior * (1.0 - wgt)
                wait0 = s.busy_until - nt
                if wait0 < 0.0:
                    wait0 = 0.0
                # the scaler's decide() arithmetic down to the memo
                # solver's _quantize, scalarized:
                # spongelint: inline-of repro.core.scaler.SpongeScaler.decide pin=23615dcd0615
                # spongelint: inline-of repro.core.solver.MemoizedSolver.solve pin=f62550972488
                lam_eff = lam * lh
                lam_q = ceil(lam_eff / lq) * lq if lq > 0 \
                    else float(lam_eff)
                if bq > 0:
                    iw = ceil(wait0 / bq) * bq
                    key = (tuple([
                        floor((0.0 if (x := (dd - nt) - hr) < 0.0
                               else x) / bq) * bq
                        for dd in self._q_dll]), lam_q, iw)
                else:
                    iw = float(wait0)
                    key = (tuple([
                        0.0 if (x := (dd - nt) - hr) < 0.0 else x
                        for dd in self._q_dll]), lam_q, iw)
                d = front.get(key)
                if d is not None:
                    memo.hits += 1
                    sc._next_t = nt + ai_step
                    decs.append((nt, d))
                else:
                    # front miss: the exact array round trip — the memo
                    # solver's own byte key under the scaler's verbatim
                    # arithmetic (queue snapshot already sorted, so the
                    # memo's np.sort would be the identity)
                    qh = self._qh
                    qt = self._qt
                    m = qt - qh
                    if m > scratch.size:
                        scratch = np.empty(max(2 * scratch.size, m))
                    buf = scratch[:m]
                    np.subtract(self._q_dl[qh:qt], nt, out=buf)
                    np.subtract(buf, hr, out=buf)
                    np.maximum(buf, 0.0, out=buf)
                    if bq > 0:
                        np.divide(buf, bq, out=buf)
                        np.floor(buf, out=buf)
                        np.multiply(buf, bq, out=buf)
                    d = cache.get((buf.tobytes(), lam_q, iw))
                    if d is not None:
                        memo.hits += 1
                        sc._next_t = nt + ai_step
                        decs.append((nt, d))
                    else:
                        d = pol.decide(nt, self._view, lam,
                                       initial_wait=wait0)
                    if len(front) >= 200_000:
                        front.clear()
                    front[key] = d
                if max(1, getattr(d, "n", 1)) != 1:
                    raise NotImplementedError(
                        "vectorpath serves one vertically scaled slot; "
                        "horizontal Decision.n targets need FastSimRunner")
                cb = rcache.get((d.c, d.b))
                if cb is None:
                    rcache[(d.c, d.b)] = cb = \
                        resolve_decision(self.c_set, d)
                c, self.b = cb
                if nt > s._last_t:  # spongelint: inline-of repro.serving.fastpath._Slot.account
                    s.core_seconds += s.c * (nt - s._last_t)
                    s._last_t = nt
                # single-slot resize from FastSimRunner._apply:
                # spongelint: inline-of repro.serving.fastpath.FastSimRunner._apply pin=e4a54f71d7e5
                if s.c != c:
                    s.c = c
                    if pen:
                        bu = s.busy_until
                        s.busy_until = (bu if bu > nt else nt) + pen
            samples.append((nt, s.c))
            self._now = prev = nt

    # -- array queue -------------------------------------------------------
    def _grow(self, need: int) -> None:
        """Make room for ``need`` more entries: compact the live region
        to the buffer front, reallocating only when it cannot fit."""
        qh, qt = self._qh, self._qt
        live = qt - qh
        cap = len(self._q_dl)
        if live + need > cap:
            cap = max(2 * cap, live + need + 64)
            nd = np.empty(cap, np.float64)
            ni = np.empty(cap, np.int64)
            nd[:live] = self._q_dl[qh:qt]
            ni[:live] = self._q_idx[qh:qt]
            self._q_dl, self._q_idx = nd, ni
        else:
            self._q_dl[:live] = self._q_dl[qh:qt].copy()
            self._q_idx[:live] = self._q_idx[qh:qt].copy()
        self._qh, self._qt = 0, live

    def _ingest(self, i1: int) -> None:
        """Admit arrivals ``[p, i1)`` with one EDF merge.  New handles
        are strictly larger than every live handle, so a stable argsort
        on deadline plus ``searchsorted(side='right')`` reproduces the
        heap's ``(deadline, handle)`` order exactly.  With a globally
        monotone deadline column the merge is a pure append (two slice
        writes into the live buffers)."""
        p = self._p
        if i1 <= p:
            return
        m = i1 - p
        qh, qt = self._qh, self._qt
        if m == 1:
            # scalar fast path: one arrival is the overwhelmingly common
            # block at sub-second ticks — append in place, or shift-by-
            # one for an interleaved deadline (same (deadline, handle)
            # order np.insert would produce, sans the allocations)
            d0 = self._dlcol[p]
            if self._dl_mono or qt == qh or d0 >= self._q_dl[qt - 1]:
                if qt >= self._q_dl.shape[0]:
                    self._grow(1)
                    qh, qt = self._qh, self._qt
                self._q_dl[qt] = d0
                self._q_idx[qt] = p
                self._qt = qt + 1
                self._q_dll.append(float(d0))
            else:
                if qt >= self._q_dl.shape[0]:
                    self._grow(1)
                    qh, qt = self._qh, self._qt
                pos = qh + int(self._q_dl[qh:qt].searchsorted(
                    d0, side="right"))
                self._q_dl[pos + 1:qt + 1] = self._q_dl[pos:qt].copy()
                self._q_idx[pos + 1:qt + 1] = self._q_idx[pos:qt].copy()
                self._q_dl[pos] = d0
                self._q_idx[pos] = p
                self._qt = qt + 1
                self._q_dll.insert(pos - qh, float(d0))
            self._p = i1
            return
        nd = self._dlcol[p:i1]
        ni = self._hidx[p:i1]
        if not self._dl_mono:
            if m == 2:              # the common small block, sans argsort
                if nd[1] < nd[0]:
                    nd = nd[::-1]
                    ni = ni[::-1]
            elif m > 2:
                order = nd.argsort(kind="stable")
                nd = nd[order]
                ni = ni[order]
            if qt > qh and nd[0] < self._q_dl[qt - 1]:
                # genuine interleave: sorted-merge into fresh buffers
                live_dl = self._q_dl[qh:qt]
                pos = np.searchsorted(live_dl, nd, side="right")
                merged_dl = np.insert(live_dl, pos, nd)
                merged_ix = np.insert(self._q_idx[qh:qt], pos, ni)
                k = merged_dl.size
                cap = max(len(self._q_dl), 2 * k)
                self._q_dl = np.empty(cap, np.float64)
                self._q_idx = np.empty(cap, np.int64)
                self._q_dl[:k] = merged_dl
                self._q_idx[:k] = merged_ix
                self._qh, self._qt = 0, k
                self._q_dll = merged_dl.tolist()
                self._p = i1
                return
        # append path: every new deadline >= the current tail
        if qt + m > len(self._q_dl):
            self._grow(m)
            qt = self._qt
        self._q_dl[qt:qt + m] = nd
        self._q_idx[qt:qt + m] = ni
        self._qt = qt + m
        self._q_dll.extend(nd.tolist())
        self._p = i1

    def _launch(self, t: float, m: int) -> float:
        """Serve the ``m`` earliest-deadline live requests at ``t`` —
        the body of the fastpath's dispatch pop, array-at-a-time."""
        s = self.slots[0]
        qh = self._qh
        bucket = int(self._bucket_arr[m])
        fin = t + self._lat[(s.c, bucket)]
        s.busy_until = fin
        self.bucket_log.append((t, s.c, bucket, m))
        self._finish[self._q_idx[qh:qh + m]] = fin
        self._qh = qh + m
        del self._q_dll[:m]
        self._n_batches += 1
        return fin

    # -- the window engine -------------------------------------------------
    def _advance(self, t_limit: float, open_end: bool, pA: int) -> None:
        """Process every event in the window ending at ``t_limit``.
        ``pA`` is the precomputed arrival bound
        ``searchsorted(arrivals, t_limit, side="right")`` (batched for
        all ticks by ``run``).

        ``open_end=True`` is a tick-bounded window: a completion or
        slack wake at exactly ``t_limit`` loses the tie to the tick and
        is handled by the next window's opening dispatch.  The final
        (horizon-bounded) window is closed: events at exactly the
        horizon are processed.  Arrivals at ``t_limit`` belong to this
        window either way (arrivals precede ticks in the canonical
        order)."""
        a, dlc = self._acol, self._dlcol
        s = self.slots[0]
        t = self._now
        while True:
            fin = s.busy_until
            if fin > t:
                # busy: everything until the completion is bulk ingest
                if fin >= t_limit if open_end else fin > t_limit:
                    if pA > self._p:
                        self._ingest(pA)
                    return
                self._ingest(int(a.searchsorted(fin, side="right")))
                t = fin
            # idle dispatch evaluation at t
            qlen = self._qt - self._qh
            b = self.b
            # t_force must be computed with the event loop's exact float
            # association: (head - l_full) - margin
            l_full = self._lat[(s.c, int(self._bucket_arr[b]))]
            margin = self.dispatch_margin
            if qlen >= b:
                t = self._drain_burst(t, t_limit, open_end, qlen, b)
                continue
            if qlen and t >= self._q_dl[self._qh] - l_full - margin:
                # t stays at the launch time: the loop's busy branch
                # ingests the arrivals that land while the batch runs
                self._launch(t, qlen)
                continue
            # idle scan: walk arrivals one decision at a time until the
            # next launch instant (fill or slack) or the window ends
            head = float(self._q_dl[self._qh]) if qlen else _INF
            p = self._p
            k = 0
            launched = False
            while True:
                nk = p + k
                t_next = a[nk] if nk < pA else _INF
                if qlen + k:
                    tf = head - l_full - margin
                    if tf < t_next:
                        # slack wake fires before the next arrival
                        if tf < t_limit or (not open_end
                                            and tf <= t_limit):
                            self._ingest(nk)
                            self._launch(tf, qlen + k)
                            t = tf
                            launched = True
                        else:
                            self._ingest(pA)  # nk == pA here
                        break
                if nk >= pA:
                    self._ingest(pA)
                    break
                k += 1
                hd = dlc[nk]
                if hd < head:
                    head = float(hd)
                tk = float(a[nk])
                if qlen + k >= b or tk >= head - l_full - margin:
                    # dispatch right after this arrival launches
                    self._ingest(nk + 1)
                    self._launch(tk, min(b, qlen + k))
                    t = tk
                    launched = True
                    break
            if not launched:
                return

    def _drain_burst(self, t: float, t_limit: float, open_end: bool,
                     qlen: int, b: int) -> float:
        """Back-to-back full batches: while the queue holds ``>= b``
        requests and no arrival or window boundary interrupts, launches
        happen at the running-sum times ``t, t+l, t+2l, …`` (the exact
        float chain the event loop produces).  One fancy-indexed write
        finishes the whole burst; only the per-batch log entries touch
        Python."""
        s = self.slots[0]
        c = s.c
        bucket = int(self._bucket_arr[b])
        l = self._lat[(c, bucket)]
        p = self._p
        t_arr = float(self._acol[p]) if p < self._n_arr else _INF
        qh = self._qh
        log = self.bucket_log
        # the opening launch always qualifies: arrivals <= t are already
        # ingested (so t_arr > t) and t is strictly inside the window
        assert t < t_arr and (t < t_limit or (not open_end
                                              and t <= t_limit)), \
            (t, t_limit, open_end, t_arr)
        tj = t + l
        if (qlen < 2 * b or tj >= t_arr
                or (tj >= t_limit if open_end else tj > t_limit)):
            # single full batch — the steady-state common case
            log.append((t, c, bucket, b))
            self._finish[self._q_idx[qh:qh + b]] = tj
            self._qh = qh + b
            del self._q_dll[:b]
            self._n_batches += 1
            s.busy_until = tj
            return t
        times: List[float] = [t]
        kmax = qlen // b
        while len(times) < kmax and tj < t_arr and (
                tj < t_limit or (not open_end and tj <= t_limit)):
            times.append(tj)
            tj += l
        kb = len(times) * b
        self._finish[self._q_idx[qh:qh + kb]] = np.repeat(
            np.array([ti + l for ti in times]), b)
        for ti in times:
            log.append((ti, c, bucket, b))
        self._qh = qh + kb
        del self._q_dll[:kb]
        self._n_batches += len(times)
        s.busy_until = tj
        return times[-1]
