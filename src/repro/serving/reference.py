"""The pre-refactor Sponge event loop, kept verbatim as an oracle.

This is the ``ScenarioRunner.run`` / ``_dispatch`` pair exactly as it
shipped before the million-request refactor (PR 2): every arrival and
every adaptation tick is heap-pushed up front, and each event triggers a
linear scan over the server pool.  It is correct and easy to audit — and
O(n) pre-allocation plus per-event pool scans make it the measured
baseline that ``benchmarks/throughput_bench.py`` reports speedups
against, and the reference that ``tests/test_fastpath.py`` proves the
indexed runner and the struct-of-arrays fast path decision-equivalent to.

Do not "optimize" this module: its value is that it does NOT share code
with the production loop.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, Optional, Sequence

from repro.serving.api import RunReport, ScenarioRunner


class ReferenceRunner(ScenarioRunner):
    """ScenarioRunner with the original (pre-refactor) event loop."""

    def run(self, arrivals: Sequence, horizon: Optional[float] = None
            ) -> RunReport:
        from repro.core.slo import Request
        norm = [(a, None) if isinstance(a, Request) else (a[0], a[1])
                for a in arrivals]
        if horizon is None:
            horizon = (max(r.arrival for r, _ in norm) + 60.0
                       if norm else 60.0)
        events: list[tuple[float, int, str, object]] = []
        seq = itertools.count()
        self.events_processed = 0
        self._wake: Dict[int, float] = {}   # srv.id -> scheduled wake-up
        for r, payload in norm:
            heapq.heappush(events, (r.arrival, next(seq), "arrival",
                                    (r, payload)))
        t = 0.0
        while t <= horizon:
            heapq.heappush(events, (t, next(seq), "tick", None))
            t += self.tick

        while events:
            t, _, kind, item = heapq.heappop(events)
            if t > horizon:
                break
            self.events_processed += 1
            self.now = t
            if kind == "arrival":
                req, payload = item
                self.submit(req, payload)
            elif kind == "tick":
                if hasattr(self.policy, "on_tick"):
                    self.policy.on_tick(t, self)
                else:                       # bare SchedulingPolicy
                    self.drive(self.policy, t)
                self.core_samples.append((t, self.allocated_cores))
            # "free" / "check": fall through to the dispatch pass
            self._dispatch(t, events, seq)

        return self.results(horizon)

    def _dispatch(self, t: float, events, seq) -> None:
        for srv in self.pool:
            # a slot busy (or cold-starting) past this event with queued
            # work gets a precise wake-up: a resize penalty can extend
            # busy_until beyond the slot's scheduled "free" event, which
            # would otherwise strand the queue until the next tick
            wake_t = max(srv.ready_at, srv.busy_until)
            if (len(self.queue) and wake_t > t
                    and self._wake.get(srv.id) != wake_t):
                self._wake[srv.id] = wake_t
                heapq.heappush(events, (wake_t, next(seq), "check", srv.id))
            while (len(self.queue) and srv.ready_at <= t
                   and srv.busy_until <= t):
                q = len(self.queue)
                if q < self.b:
                    head = self.queue.peek()
                    l_full = srv.instance.latency(self.b)
                    t_force = head.deadline - l_full - self.dispatch_margin
                    if t < t_force:
                        # re-check when deadline pressure bites (new
                        # arrivals also re-trigger dispatch)
                        heapq.heappush(events, (min(t_force, t + self.tick),
                                                next(seq), "check", srv.id))
                        break
                batch = self.queue.pop_batch(self.b)
                bucket = srv.instance.bucket_b(len(batch))
                fin = self.backend.execute(batch, srv.instance.c, bucket, t)
                srv.busy_until = fin
                self.bucket_log.append((t, srv.instance.c, bucket,
                                        len(batch)))
                for r in batch:
                    r.start_proc = t
                    r.finish = fin
                    self.monitor.observe_completion(r)
                heapq.heappush(events, (fin, next(seq), "free", srv.id))
