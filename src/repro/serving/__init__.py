from repro.serving.workload import WorkloadGenerator
from repro.serving.simulator import ClusterSimulator, simulate
