"""The Sponge serving package: one control plane, pluggable everything.

Public surface (snapshotted by ``tests/test_public_api.py``):

* construction — ``make_sim_server`` / ``make_live_server`` /
  ``make_policy`` and the ``SpongeServer`` facade;
* engines — the object-based ``ScenarioRunner`` (+ ``SimBackend`` /
  ``JaxBackend``) and the struct-of-arrays ``FastSimRunner`` /
  ``TokenFastSimRunner`` (import from ``repro.serving.fastpath``) and
  fleet runners (``repro.serving.fleet``);
* the online session API — ``SpongeSession`` protocol, the per-engine
  sessions, transcripts (``repro.serving.session``);
* workloads — ``WorkloadGenerator`` / ``RequestBatch`` and the scenario
  registry (``repro.serving.scenarios``);
* multi-tenancy — ``TenantPool`` / ``TenantSpec`` and the shared-pool
  engines (``repro.serving.tenancy``).

The PR 1 shims (``ClusterSimulator`` / ``simulate`` in
``repro.serving.simulator``, ``ServingEngine`` in
``repro.serving.engine``) are no longer re-exported here and warn on
import — see the migration note in ``docs/api.md``.
"""
from repro.serving.workload import RequestBatch, WorkloadGenerator
from repro.serving.api import (JaxBackend, RunReport, ScenarioRunner,
                               SimBackend, SpongeServer, make_live_server,
                               make_policy, make_sim_server, round_up_c)
from repro.serving.session import (ExactSession, FastSession, FleetSession,
                                   SessionTranscript, SpongeSession,
                                   TokenFastSession, drive_session_events,
                                   replay_transcript)
from repro.serving.tenancy import TenantPool, TenantSpec

__all__ = [
    "ExactSession", "FastSession", "FleetSession", "JaxBackend",
    "RequestBatch", "RunReport", "ScenarioRunner", "SessionTranscript",
    "SimBackend", "SpongeServer", "SpongeSession", "TenantPool",
    "TenantSpec", "TokenFastSession", "WorkloadGenerator",
    "drive_session_events", "make_live_server", "make_policy",
    "make_sim_server", "replay_transcript", "round_up_c",
]
