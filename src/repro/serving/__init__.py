from repro.serving.workload import WorkloadGenerator
from repro.serving.api import (JaxBackend, RunReport, ScenarioRunner,
                               SimBackend, SpongeServer, make_live_server,
                               make_policy, make_sim_server, round_up_c)
from repro.serving.simulator import ClusterSimulator, Server, simulate
