"""Live serving engine: the non-simulated execution path — now a thin
construction shim over the unified serving API.

.. deprecated::
    New code should construct through ``repro.serving.api.make_live_server``
    (or compose ``SpongeServer`` with a ``JaxBackend`` directly);
    ``ServingEngine`` remains only for callers holding a prebuilt
    step-fn table and the historical constructor signature.

Runs real jitted JAX inference behind the same Sponge control plane as the
simulator: ``repro.serving.api.ScenarioRunner`` drives a ``JaxBackend``
holding the executable table built at deploy time — one entry per (c, b)
bucket — so applying a Decision is an O(1) dictionary flip (the in-place
vertical scaling mechanism; on the TPU target each entry is the same step
compiled on a c-chip submesh, which ``launch/dryrun.py`` proves lowers and
compiles for every c).

On this CPU container every c entry executes the same computation, so the
engine exposes measured latency per (c, b) for the perf-model residual loop
but vertical scaling affects *scheduling* only; the simulator (calibrated
from the dry-run roofline) is the quantitative Fig. 4 path.

Prefer constructing through ``repro.serving.api.make_live_server`` —
``ServingEngine`` remains for callers holding a prebuilt step-fn table.
"""
from __future__ import annotations

import warnings
from typing import Callable, Dict, List, Sequence


from repro.core.scaler import SpongeScaler
from repro.core.slo import Decision, Request
from repro.serving.api import (JaxBackend, ScenarioRunner, ServedRequest,
                               build_llm_step_fns, pad_tokens)

warnings.warn(
    "repro.serving.engine is deprecated: construct through "
    "repro.serving.api.make_live_server (or compose SpongeServer with a "
    "JaxBackend) — see the migration note in docs/api.md",
    DeprecationWarning, stacklevel=2)

__all__ = ["ServingEngine", "ServedRequest", "build_llm_step_fns",
           "pad_tokens"]


class ServingEngine:
    """Single-instance live engine with in-place vertical scaling.

    Deprecated shim — prefer ``repro.serving.api.make_live_server``.
    Thin facade: queue/monitor/dispatch all run inside ScenarioRunner; the
    scaler itself is the SchedulingPolicy (it conforms to the protocol).
    """

    def __init__(self, step_fns: Dict[tuple[int, int], Callable],
                 scaler: SpongeScaler, pad_payload: Callable,
                 prior_rps: float = 0.0):
        """step_fns[(c, b)](stacked_payload) -> batched result (pre-jitted).
        pad_payload(list_of_payloads, b) -> stacked input of bucket size b."""
        self.backend = JaxBackend(step_fns, pad_payload, scaler.perf,
                                  clock="measured")
        self.scaler = scaler
        self.runner = ScenarioRunner(scaler, self.backend,
                                     tick=scaler.adaptation_interval)
        self.runner.monitor.rate.prior_rps = prior_rps
        self.c_set = self.backend.c_set
        self.b_set = self.backend.b_set

    # -- compat surface ----------------------------------------------------
    @property
    def monitor(self):
        return self.runner.monitor

    @property
    def queue(self):
        return self.runner.queue

    @property
    def results(self) -> List[ServedRequest]:
        return self.backend.results

    @property
    def decision_log(self) -> List[tuple[float, Decision]]:
        return self.scaler.decisions

    @property
    def c(self) -> int:
        return self.backend.pool[0].instance.c

    @property
    def b(self) -> int:
        return self.runner.b

    def warmup(self, example_payload) -> None:
        self.backend.warmup(example_payload)

    def apply(self, d: Decision, now: float) -> None:
        """Apply a decision out-of-band.  c rounds to the smallest
        available entry >= d.c (never below the solver's feasible c),
        falling back to max(c_set) — see ``api.round_up_c``."""
        self.runner.apply_decision(d, now)

    # -- convenience batch-run over a timed request script -----------------
    def run_script(self, arrivals: Sequence[tuple[Request, object]],
                   speedup: float = 1.0) -> dict:
        """Serves a timed request script in virtual time (event-driven;
        arrivals fire at their scripted times, execution advances the
        clock by the measured batch latency).  ``speedup`` is kept for
        backward compatibility and ignored — virtual time makes it moot."""
        del speedup
        report = self.runner.run(list(arrivals))
        mon = self.runner.monitor
        return {
            "n": mon.n_total,
            "violations": mon.n_violations,
            "violation_rate": mon.violation_rate,
            "p50": mon.p(0.5), "p99": mon.p(0.99),
            "decisions": len(self.decision_log),
            "report": report,
        }
