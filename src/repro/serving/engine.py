"""Live serving engine: the non-simulated execution path.

Runs real jitted JAX inference behind the same Sponge control plane used by
the simulator (EDF queue + scaler + monitor).  The executable table is built
at deploy time — one entry per (c, b) bucket — so applying a ScalerDecision
is an O(1) dictionary flip (the in-place vertical scaling mechanism; on the
TPU target each entry is the same step compiled on a c-chip submesh, which
``launch/dryrun.py`` proves lowers and compiles for every c).

On this CPU container every c entry executes the same computation, so the
engine exposes measured latency per (c, b) for the perf-model residual loop
but vertical scaling affects *scheduling* only; the simulator (calibrated
from the dry-run roofline) is the quantitative Fig. 4 path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.monitor import Monitor
from repro.core.perf_model import PerfModel
from repro.core.queueing import EDFQueue
from repro.core.scaler import SpongeScaler
from repro.core.slo import Decision, Request


@dataclass
class ServedRequest:
    req: Request
    payload: Any
    result: Any = None


class ServingEngine:
    """Single-instance engine with in-place vertical scaling."""

    def __init__(self, step_fns: Dict[tuple[int, int], Callable],
                 scaler: SpongeScaler, pad_payload: Callable,
                 prior_rps: float = 0.0):
        """step_fns[(c, b)](stacked_payload) -> batched result (pre-jitted).
        pad_payload(list_of_payloads, b) -> stacked input of bucket size b."""
        self.step_fns = dict(step_fns)
        self.c_set = sorted({c for c, _ in step_fns})
        self.b_set = sorted({b for _, b in step_fns})
        self.scaler = scaler
        self.pad_payload = pad_payload
        self.queue = EDFQueue()
        self.monitor = Monitor()
        self.monitor.rate.prior_rps = prior_rps
        self.c = self.c_set[-1]
        self.b = 1
        self.pending: Dict[int, ServedRequest] = {}
        self.results: List[ServedRequest] = []
        self.decision_log: List[tuple[float, Decision]] = []

    def warmup(self, example_payload) -> None:
        for (c, b), fn in self.step_fns.items():
            fn(self.pad_payload([example_payload] * min(b, 2), b))

    def bucket(self, n: int) -> int:
        for b in self.b_set:
            if b >= n:
                return b
        return self.b_set[-1]

    def submit(self, req: Request, payload: Any) -> None:
        self.monitor.observe_arrival(req)
        self.queue.push(req)
        self.pending[req.id] = ServedRequest(req, payload)

    def apply(self, d: Decision, now: float) -> None:
        self.c = min(self.c_set, key=lambda c: abs(c - d.c) + (c < d.c))
        self.b = d.b if d.b in self.b_set else self.bucket(d.b)
        self.decision_log.append((now, d))

    def maybe_adapt(self, now: float) -> None:
        if self.scaler.due(now):
            lam = self.monitor.rate.rate(now)
            d = self.scaler.decide(now, self.queue, lam)
            self.apply(d, now)

    def step(self, now: float) -> Optional[List[ServedRequest]]:
        """Process one batch if the queue has work.  Returns served items."""
        if not len(self.queue):
            return None
        batch = self.queue.pop_batch(self.b)
        items = [self.pending.pop(r.id) for r in batch]
        bucket = self.bucket(len(items))
        fn = self.step_fns[(self.c, bucket)]
        t0 = time.perf_counter()
        out = fn(self.pad_payload([it.payload for it in items], bucket))
        try:
            import jax
            jax.block_until_ready(out)
        except Exception:
            pass
        dt = time.perf_counter() - t0
        fin = now + dt
        for i, it in enumerate(items):
            it.req.start_proc = now
            it.req.finish = fin
            it.result = _index_result(out, i)
            self.monitor.observe_completion(it.req)
            self.results.append(it)
        self.monitor.observe_perf_residual(
            float(self.scaler.perf.latency(bucket, self.c)), dt)
        return items

    # -- convenience batch-run over a timed request script -----------------
    def run_script(self, arrivals: Sequence[tuple[Request, Any]],
                   speedup: float = 1.0) -> dict:
        """Feeds requests at their (scaled) arrival times on the real clock
        and serves them; returns monitor summary."""
        t_start = time.perf_counter()
        idx = 0
        arrivals = sorted(arrivals, key=lambda ra: ra[0].arrival)
        while idx < len(arrivals) or len(self.queue):
            now = (time.perf_counter() - t_start) * speedup
            while idx < len(arrivals) and arrivals[idx][0].arrival <= now:
                self.submit(*arrivals[idx])
                idx += 1
            self.maybe_adapt(now)
            if len(self.queue):
                self.step(now)
            elif idx < len(arrivals):
                dt = (arrivals[idx][0].arrival - now) / speedup
                time.sleep(min(max(dt, 0.0), 0.05))
        mon = self.monitor
        return {
            "n": mon.n_total,
            "violations": mon.n_violations,
            "violation_rate": mon.violation_rate,
            "p50": mon.p(0.5), "p99": mon.p(0.99),
            "decisions": len(self.decision_log),
        }


def _index_result(out: Any, i: int):
    import jax
    return jax.tree.map(lambda a: np.asarray(a)[i] if hasattr(a, "shape")
                        and getattr(a, "ndim", 0) > 0 else a, out)


def build_llm_step_fns(model, params, c_set: Sequence[int],
                       b_set: Sequence[int], prompt_len: int,
                       gen_tokens: int = 8):
    """Executable table for short-generation LLM serving on the reduced
    models: each entry prefises the prompt batch and decodes gen_tokens.

    On TPU each (c, b) would be compiled on its c-chip submesh; on CPU the
    same jitted fn backs every c (see module docstring).
    """
    import jax
    import jax.numpy as jnp

    def make(b):
        def fn(tokens):
            logits, cache = model.prefill(params, {"tokens": tokens},
                                          cache_len=prompt_len + gen_tokens)
            def body(carry, _):
                cache, tok = carry
                lg, cache = model.decode_step(params, cache, tok)
                nxt = jnp.argmax(
                    lg[:, :model.cfg.vocab_size], axis=-1
                ).astype(jnp.int32)[:, None]
                return (cache, nxt), nxt[:, 0]
            first = jnp.argmax(logits[:, :model.cfg.vocab_size],
                               axis=-1).astype(jnp.int32)[:, None]
            (_, _), toks = jax.lax.scan(body, (cache, first),
                                        None, length=gen_tokens)
            return toks.T  # (b, gen_tokens)
        return jax.jit(fn)

    fns = {}
    for b in b_set:
        jitted = make(b)
        for c in c_set:
            fns[(c, b)] = jitted
    return fns


def pad_tokens(payloads: List[np.ndarray], b: int) -> np.ndarray:
    x = np.stack(payloads + [payloads[-1]] * (b - len(payloads)))
    return x.astype(np.int32)
