"""Million-request simulation fast path: the control plane on bare arrays.

``ScenarioRunner`` is the general loop — any policy, any backend, live
payloads, legacy escape hatches.  At a million requests its per-request
Python objects (``Request``, monitor lists, heap tuples) dominate the
wall clock even after the streamed-event refactor.  ``FastSimRunner`` is
the same control plane rebuilt for scale, for the simulation backend
only:

* the workload is a ``RequestBatch`` — one numpy column per field, no
  ``Request`` objects ever exist;
* the EDF queue holds bare ``(deadline, index)`` pairs
  (``core.queueing.FastEDFQueue``) and the solver snapshot is a single
  vectorized sort;
* arrivals and adaptation ticks are streamed; the event heap holds only
  batch completions and per-slot wake-ups (deduplicated), so the heap
  stays O(pool);
* the λ estimator is a two-pointer sliding window over the arrival
  array (same estimate as ``core.monitor.RateEstimator``, including the
  deploy-prior blend);
* batch latencies come from a table precomputed per ``(c, b)`` — the
  same floats ``SimBackend.execute`` would produce;
* completions are recorded by fancy-indexed array writes and every
  aggregate in the final ``RunReport`` is one vectorized pass.

The contract — enforced by ``tests/test_fastpath.py`` against the
verbatim pre-refactor loop in ``repro.serving.reference`` — is
*decision-for-decision equivalence*: same decision sequence, same batch
buckets, same violation count on the same workload.  Policies must speak
the bare ``decide(now, queue, lam, initial_wait)`` protocol (Sponge,
static, FA2 all do); legacy policies that mutate the pool or inspect
``Request`` objects (``MultiDimPolicy``, ``PredictivePolicy``) need the
object-based ``ScenarioRunner``.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Dict, List, Optional

import numpy as np

from repro.core.cost_model import Composition, TokenCostModel
from repro.core.monitor import array_window_rate
from repro.core.perf_model import PerfModel
from repro.core.queueing import FastEDFQueue, TokenFastEDFQueue
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.serving.api import (RunReport, build_array_report,
                               resolve_decision, round_up_c)
from repro.serving.workload import RequestBatch


class _Slot:
    """One servable slot as plain scalars (the fast-path ``Server``)."""
    __slots__ = ("id", "c", "ready_at", "busy_until", "alive_since",
                 "dead_at", "core_seconds", "_last_t")

    def __init__(self, sid: int, c: int, ready_at: float, now: float):
        self.id = sid
        self.c = c
        self.ready_at = ready_at
        self.busy_until = 0.0
        self.alive_since = now
        self.dead_at: Optional[float] = None
        self.core_seconds = 0.0
        self._last_t: Optional[float] = now

    def account(self, now: float) -> None:
        """Integrate allocated core-seconds up to ``now`` (same monotone
        accumulation as ``VerticalScaledInstance.account``)."""
        if now > self._last_t:
            self.core_seconds += self.c * (now - self._last_t)
            self._last_t = now


class FastSimRunner:
    """The Sponge control loop over a struct-of-arrays workload.

    Drives any decide-protocol ``SchedulingPolicy`` against simulated
    vertically/horizontally scalable slots, with identical scheduling
    semantics to ``ScenarioRunner`` + ``SimBackend`` (slack-aware EDF
    dispatch, adaptation ticks, resize penalties, cold starts) at a
    fraction of the per-event cost.  See the module docstring for the
    equivalence contract.
    """

    def __init__(self, policy, perf: PerfModel,
                 c_set=DEFAULT_C, b_set=DEFAULT_B, *, c0: int = 1,
                 tick: float = 1.0, resize_penalty: float = 0.005,
                 dispatch_margin: float = 0.02, prior_rps: float = 0.0,
                 rate_window: float = 5.0):
        if not hasattr(policy, "decide"):
            raise TypeError(
                f"{type(policy).__name__} has no decide(); the fast path "
                "drives bare SchedulingPolicy objects only — use "
                "ScenarioRunner for legacy on_tick policies")
        self.policy = policy
        self.perf = perf
        self.c_set = tuple(sorted(c_set))
        self.b_set = tuple(sorted(b_set))
        assert c0 in self.c_set, (c0, self.c_set)
        self.tick = tick
        self.resize_penalty = resize_penalty
        self.dispatch_margin = dispatch_margin
        self.prior_rps = prior_rps
        self.rate_window = rate_window
        # precomputed latency table: identical floats to SimBackend.execute
        self._lat: Dict[tuple[int, int], float] = {
            (c, b): float(perf.latency(b, c))
            for c in self.c_set for b in self.b_set}
        bmax = self.b_set[-1]
        buckets = np.empty(bmax + 1, np.int64)
        for x in range(bmax + 1):
            buckets[x] = next((bb for bb in self.b_set if bb >= x), bmax)
        self._bucket_arr = buckets
        self._bmax = bmax
        self._sid = itertools.count()
        self.b = 1
        self.queue = FastEDFQueue()
        self.slots: List[_Slot] = [_Slot(next(self._sid), c0, 0.0, 0.0)]
        self.dead: List[_Slot] = []
        self.core_samples: List[tuple[float, int]] = []
        self.bucket_log: List[tuple[float, int, int, int]] = []
        self.events_processed = 0

    # -- helpers -----------------------------------------------------------
    def _bucket(self, b: int) -> int:
        return int(self._bucket_arr[b]) if b <= self._bmax else self._bmax

    @property
    def allocated_cores(self) -> int:
        return sum(s.c for s in self.slots)

    def _rate(self, now: float) -> float:
        """Sliding-window λ with deploy-prior blend — the shared
        ``core.monitor.array_window_rate`` two-pointer estimate (same
        floats as ``RateEstimator``, single-arrival guard included)."""
        lam, self._w0 = array_window_rate(self._arr, self._ai, self._w0,
                                          now, self.rate_window,
                                          self.prior_rps)
        return lam

    def drive(self, policy, now: float) -> None:
        """One adaptation step (same drive path as ``ScenarioRunner``)."""
        due = policy.due(now) if hasattr(policy, "due") else True
        if not due:
            return
        lam = self._rate(now)
        wait0 = max(self.slots[0].busy_until - now, 0.0)
        d = policy.decide(now, self.queue, lam, initial_wait=wait0)
        self._apply(d, now)

    def _apply(self, d, now: float) -> None:
        c, self.b = resolve_decision(self.c_set, d)
        pen = self.resize_penalty
        for s in self.slots:
            s.account(now)
            if s.c != c:
                s.c = c
                if pen:
                    s.busy_until = max(s.busy_until, now) + pen
        n = max(1, getattr(d, "n", 1))
        cur = len(self.slots)
        if n > cur:
            delay = getattr(d, "scale_up_delay", 0.0)
            for _ in range(n - cur):
                self.slots.append(_Slot(next(self._sid), c,
                                        now + delay, now))
        elif n < cur:
            for _ in range(min(cur - n, cur - 1)):
                s = self.slots.pop()
                s.dead_at = max(now, s.busy_until)
                self.dead.append(s)

    # -- the loop ----------------------------------------------------------
    def run(self, batch: RequestBatch,
            horizon: Optional[float] = None) -> RunReport:
        arr = np.ascontiguousarray(batch.arrival, np.float64)
        dl = np.ascontiguousarray(batch.deadline, np.float64)
        n = arr.size
        if n and np.any(np.diff(arr) < 0):
            raise ValueError("RequestBatch must be sorted by arrival")
        if horizon is None:
            horizon = float(arr[-1]) + 60.0 if n else 60.0
        finish = np.full(n, np.nan)
        self._arr = arr
        self._ai = 0
        self._w0 = 0
        policy = self.policy
        queue = self.queue
        lat = self._lat
        bucket_arr = self._bucket_arr
        margin = self.dispatch_margin
        tick = self.tick
        slack_wake: Dict[int, float] = {}
        busy_wake: Dict[int, float] = {}
        events: list[tuple[float, int, int]] = []
        seq = itertools.count()
        has_on_tick = hasattr(policy, "on_tick")
        push, pop = heapq.heappush, heapq.heappop
        next_tick = 0.0
        ai = 0
        INF = float("inf")
        n_events = 0

        while True:
            ta = arr[ai] if ai < n else INF
            tt = next_tick if next_tick <= horizon else INF
            td = events[0][0] if events else INF
            if ta <= tt and ta <= td:
                t = ta
                kind = 0
            elif tt <= td:
                t = tt
                kind = 1
            else:
                t = td
                kind = 2
            if t == INF or t > horizon:
                break
            n_events += 1
            if kind == 0:
                queue.push(dl[ai], ai)
                ai += 1
                self._ai = ai
            elif kind == 1:
                next_tick += tick
                if has_on_tick:
                    policy.on_tick(t, self)
                else:
                    self.drive(policy, t)
                self.core_samples.append((t, self.allocated_cores))
            else:
                pop(events)
            # -- dispatch pass (inlined hot path) --------------------------
            if len(queue._heap):
                b_now = self.b
                for s in self.slots:
                    if s.ready_at > t or s.busy_until > t:
                        wake_t = (s.ready_at if s.ready_at > s.busy_until
                                  else s.busy_until)
                        if busy_wake.get(s.id) != wake_t:
                            busy_wake[s.id] = wake_t
                            push(events, (wake_t, next(seq), s.id))
                        continue
                    while queue._heap and s.busy_until <= t:
                        q = len(queue._heap)
                        if q < b_now:
                            head_dl = queue._heap[0][0]
                            l_full = lat[(s.c, self._bucket(b_now))]
                            t_force = head_dl - l_full - margin
                            if t < t_force:
                                tw = min(t_force, t + tick)
                                if slack_wake.get(s.id) != tw:
                                    slack_wake[s.id] = tw
                                    push(events, (tw, next(seq), s.id))
                                break
                        idxs = queue.pop_batch(b_now)
                        m = len(idxs)
                        bucket = int(bucket_arr[m])
                        fin = t + lat[(s.c, bucket)]
                        s.busy_until = fin
                        self.bucket_log.append((t, s.c, bucket, m))
                        finish[idxs] = fin
                        push(events, (fin, next(seq), s.id))

        self.events_processed = n_events
        return self._report(batch, finish, horizon)

    # -- reporting ---------------------------------------------------------
    def _report(self, batch: RequestBatch, finish: np.ndarray,
                horizon: float) -> RunReport:
        return build_array_report(self.policy, "sim-fast", batch, finish,
                                  horizon, self.slots + self.dead,
                                  self.core_samples, self.bucket_log)


class TokenFastSimRunner(FastSimRunner):
    """Continuous-batching decode streams on the struct-of-arrays engine.

    The autoregressive extension of :class:`FastSimRunner` (ISSUE 3):
    the workload is a token-shaped ``RequestBatch`` (``prompt_tokens`` /
    ``decode_tokens`` / ``tbt_slo`` columns) and the single vertically
    scaled instance runs a **decode stream** with true continuous
    batching — between consecutive engine steps, requests *join* the
    running batch (their prompts prefill as part of the next step, first
    token = TTFT at the step boundary) and *leave* it the moment their
    stream completes, with per-slot token counters in plain arrays and
    step latency from the token cost model's composition surface
    (``step_latency(c, (prefill_tokens, decode_slots))``).

    Scheduling semantics:

    * admission is greedy EDF: whenever the running batch has free slots
      (``Decision.b`` is the slot cap) the earliest-deadline waiting
      requests join the next step — continuous batching does not hold
      prompts back to fill buckets;
    * the engine never idles while streams run: the next step starts at
      the previous step's boundary; with no work it sleeps until the
      next arrival;
    * in-place vertical resizes (and their penalty) take effect at the
      next step boundary — a step in flight finishes at the old c;
    * per-token SLOs are checked per step: a running slot's token gap is
      the distance between consecutive step boundaries, so a step longer
      than the slot's ``tbt_slo`` counts one violation for that slot.

    This runner is single-instance (the paper's Sponge mechanism);
    horizontal ``Decision.n`` targets are ignored.  It sustains >=100k
    autoregressive requests per run (``benchmarks/token_serving_bench``).
    """

    def __init__(self, policy, cost: TokenCostModel,
                 c_set=DEFAULT_C, b_set=DEFAULT_B, *, c0: int = 1,
                 tick: float = 1.0, resize_penalty: float = 0.005,
                 prior_rps: float = 0.0, rate_window: float = 5.0):
        super().__init__(policy, cost, c_set, b_set, c0=c0, tick=tick,
                         resize_penalty=resize_penalty,
                         prior_rps=prior_rps, rate_window=rate_window)
        self.cost = cost
        self.queue = TokenFastEDFQueue()
        self._pending_penalty = 0.0

    def _apply(self, d, now: float) -> None:
        """In-place vertical resize; the penalty lands on the next step."""
        c, self.b = resolve_decision(self.c_set, d)
        s = self.slots[0]
        s.account(now)
        if s.c != c:
            s.c = c
            self._pending_penalty += self.resize_penalty

    def drive(self, policy, now: float, active_slots: int = 0,
              tbt_budget: float = float("inf"),
              initial_wait: float = 0.0) -> None:
        """One adaptation step over the token-aware decide protocol."""
        due = policy.due(now) if hasattr(policy, "due") else True
        if not due:
            return
        lam = self._rate(now)
        d = policy.decide(now, self.queue, lam, initial_wait=initial_wait,
                          active_slots=active_slots, tbt_budget=tbt_budget)
        self._apply(d, now)

    # -- the loop ----------------------------------------------------------
    def run(self, batch: RequestBatch,
            horizon: Optional[float] = None) -> RunReport:
        arr = np.ascontiguousarray(batch.arrival, np.float64)
        dl = np.ascontiguousarray(batch.deadline, np.float64)
        ptoks = np.ascontiguousarray(batch.prompt_tokens, np.int64)
        dtoks = np.ascontiguousarray(batch.decode_tokens, np.int64)
        tbts = np.ascontiguousarray(batch.tbt_slo, np.float64)
        n = arr.size
        if n and np.any(np.diff(arr) < 0):
            raise ValueError("RequestBatch must be sorted by arrival")
        if horizon is None:
            horizon = float(arr[-1]) + 60.0 if n else 60.0
        self.queue.bind(ptoks, tbts)
        first_tok = np.full(n, np.nan)
        finish = np.full(n, np.nan)
        tbt_bad = np.zeros(n, bool)
        self._arr = arr
        self._ai = 0
        self._w0 = 0
        policy = self.policy
        queue = self.queue
        cost = self.cost
        slot = self.slots[0]
        tick = self.tick
        next_tick = 0.0
        ai = 0
        INF = float("inf")
        n_events = 0
        # running decode streams (slot cap <= max(b_set): plain lists)
        run_idx: list[int] = []
        run_rem: list[int] = []
        run_tbt: list[float] = []
        # the step in flight
        step_end = INF
        step_start = 0.0
        step_admit: list[int] = []
        step_decoders = 0
        tokens_served = 0
        decode_tokens_served = 0
        tbt_viol_tokens = 0

        def start_step(t0: float) -> float:
            """Admit waiting requests, compose the step, return its end
            (INF when there is no work to run).

            Admission is EDF-ordered and **chunk-bounded**: the total
            prefill tokens joining one step are capped by the cost
            model's ``prefill_token_allowance`` for the tightest
            per-token SLO among running streams, so a large joining
            prompt cannot stall running decoders past their TBT budget
            (the deferred prompt re-queues at the head and joins once
            slots free up or the scaler raises c)."""
            nonlocal step_admit, step_decoders, step_start
            free = self.b - len(run_idx)
            admit: list[int] = []
            if free > 0 and queue._heap:
                allowance = (cost.prefill_token_allowance(
                    slot.c, len(run_idx), min(run_tbt))
                    if run_tbt else INF)
                total = 0
                heap = queue._heap
                while heap and len(admit) < free:
                    i = heap[0][1]
                    if total + ptoks[i] > allowance:
                        break
                    heapq.heappop(heap)
                    admit.append(i)
                    total += int(ptoks[i])
            if not admit and not run_idx:
                return INF
            step_admit = admit
            step_decoders = len(run_idx)
            ptok = int(ptoks[admit].sum()) if admit else 0
            l = cost.step_latency(slot.c,
                                  Composition(ptok, step_decoders))
            l += self._pending_penalty
            self._pending_penalty = 0.0
            step_start = t0
            return t0 + l

        while True:
            ta = arr[ai] if ai < n else INF
            tt = next_tick if next_tick <= horizon else INF
            if ta <= tt and ta <= step_end:
                t, kind = ta, 0
            elif tt <= step_end:
                t, kind = tt, 1
            else:
                t, kind = step_end, 2
            if t == INF or t > horizon:
                break
            n_events += 1
            if kind == 0:                        # arrival
                queue.push(dl[ai], ai)
                ai += 1
                self._ai = ai
            elif kind == 1:                      # adaptation tick
                next_tick += tick
                run_tbt_min = min(run_tbt) if run_tbt else INF
                iw = max(step_end - t, 0.0) if step_end < INF else 0.0
                self.drive(policy, t, active_slots=len(run_idx),
                           tbt_budget=run_tbt_min, initial_wait=iw)
                self.core_samples.append((t, slot.c))
            else:                                # step boundary
                gap = t - step_start
                # one decode token per stream that ran this step (the
                # first ``step_decoders`` entries; joins append later)
                nxt_idx: list[int] = []
                nxt_rem: list[int] = []
                nxt_tbt: list[float] = []
                for k in range(step_decoders):
                    i = run_idx[k]
                    tokens_served += 1
                    decode_tokens_served += 1
                    if gap > run_tbt[k] + 1e-12:
                        tbt_viol_tokens += 1
                        tbt_bad[i] = True
                    if run_rem[k] > 1:
                        nxt_idx.append(i)
                        nxt_rem.append(run_rem[k] - 1)
                        nxt_tbt.append(run_tbt[k])
                    else:
                        finish[i] = t
                # first tokens (TTFT) for the requests admitted this step
                for i in step_admit:
                    first_tok[i] = t
                    tokens_served += 1
                    if dtoks[i] > 0:
                        nxt_idx.append(i)
                        nxt_rem.append(int(dtoks[i]))
                        nxt_tbt.append(float(tbts[i]))
                    else:
                        finish[i] = t
                run_idx, run_rem, run_tbt = nxt_idx, nxt_rem, nxt_tbt
                step_admit = []
                step_decoders = 0
                step_end = start_step(t)
            if step_end == INF and (queue._heap or run_idx):
                step_end = start_step(t)

        self.events_processed = n_events
        return self._token_report(batch, first_tok, finish, tbt_bad,
                                  tokens_served, decode_tokens_served,
                                  tbt_viol_tokens, horizon)

    # -- reporting ---------------------------------------------------------
    def _token_report(self, batch: RequestBatch, first_tok: np.ndarray,
                      finish: np.ndarray, tbt_bad: np.ndarray,
                      tokens_served: int, decode_tokens_served: int,
                      tbt_viol_tokens: int, horizon: float) -> RunReport:
        """Vectorized aggregates over the token run."""
        served = ~np.isnan(finish)
        send = batch.arrival - batch.comm_latency
        fin = finish[served]
        n_req = int(served.sum())
        ttft_late = first_tok[served] > batch.deadline[served] + 1e-9
        viol = int((ttft_late | tbt_bad[served]).sum())
        e2e = np.sort(fin - send[served])
        ttft = np.sort(first_tok[served] - send[served])
        nn = e2e.size

        def p(a: np.ndarray, q: float) -> float:
            if not a.size:
                return float("nan")
            return float(a[min(int(q * a.size), a.size - 1)])

        core_s = 0.0
        for s in self.slots + self.dead:
            s.account(horizon)
            core_s += s.core_seconds
        decisions = getattr(self.policy, "decisions", None)
        if decisions is None:
            decisions = getattr(getattr(self.policy, "scaler", None),
                                "decisions", None)
        return RunReport(
            policy=getattr(self.policy, "name", type(self.policy).__name__),
            backend="token-sim-fast",
            n_requests=n_req,
            n_violations=viol,
            violation_rate=viol / max(n_req, 1),
            core_seconds=core_s,
            avg_cores=core_s / max(horizon, 1e-9),
            p50=p(e2e, 0.50), p99=p(e2e, 0.99),
            mean_latency=float(e2e.sum()) / max(nn, 1),
            core_timeline=self.core_samples,
            decisions=decisions,
            buckets=self.bucket_log,
            tokens_served=tokens_served,
            tokens_per_s=tokens_served / max(horizon, 1e-9),
            ttft_p50=p(ttft, 0.50), ttft_p99=p(ttft, 0.99),
            tbt_violation_rate=(tbt_viol_tokens
                                / max(decode_tokens_served, 1)),
        )
