"""Million-request simulation fast path: the control plane on bare arrays.

``ScenarioRunner`` is the general loop — any policy, any backend, live
payloads, legacy escape hatches.  At a million requests its per-request
Python objects (``Request``, monitor lists, heap tuples) dominate the
wall clock even after the streamed-event refactor.  ``FastSimRunner`` is
the same control plane rebuilt for scale, for the simulation backend
only:

* the workload is a ``RequestBatch`` — one numpy column per field, no
  ``Request`` objects ever exist;
* the EDF queue holds bare ``(deadline, index)`` pairs
  (``core.queueing.FastEDFQueue``) and the solver snapshot is a single
  vectorized sort;
* arrivals and adaptation ticks are streamed; the event heap holds only
  batch completions and per-slot wake-ups (deduplicated), so the heap
  stays O(pool);
* the λ estimator is a two-pointer sliding window over the arrival
  array (same estimate as ``core.monitor.RateEstimator``, including the
  deploy-prior blend);
* batch latencies come from a table precomputed per ``(c, b)`` — the
  same floats ``SimBackend.execute`` would produce;
* completions are recorded by fancy-indexed array writes and every
  aggregate in the final ``RunReport`` is one vectorized pass.

The contract — enforced by ``tests/test_fastpath.py`` against the
verbatim pre-refactor loop in ``repro.serving.reference`` — is
*decision-for-decision equivalence*: same decision sequence, same batch
buckets, same violation count on the same workload.  Policies must speak
the bare ``decide(now, queue, lam, initial_wait)`` protocol (Sponge,
static, FA2 all do); legacy policies that mutate the pool or inspect
``Request`` objects (``MultiDimPolicy``, ``PredictivePolicy``) need the
object-based ``ScenarioRunner``.

Since ISSUE 5 the event loops themselves live on the **online
sessions** (``repro.serving.session.FastSession`` /
``TokenFastSession``): this module keeps the engine configuration,
slot pool, decision application and reporting, while ``run()`` is a
thin replay driver — submit the whole workload, drain, report — which
is exactly the no-renegotiation special case of the session.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.cost_model import TokenCostModel
from repro.core.perf_model import PerfModel
from repro.core.queueing import FastEDFQueue, TokenFastEDFQueue
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.serving.api import RunReport, resolve_decision
from repro.serving.workload import RequestBatch


class _Slot:
    """One servable slot as plain scalars (the fast-path ``Server``)."""
    __slots__ = ("id", "c", "ready_at", "busy_until", "alive_since",
                 "dead_at", "core_seconds", "_last_t")

    def __init__(self, sid: int, c: int, ready_at: float, now: float):
        self.id = sid
        self.c = c
        self.ready_at = ready_at
        self.busy_until = 0.0
        self.alive_since = now
        self.dead_at: Optional[float] = None
        self.core_seconds = 0.0
        self._last_t: Optional[float] = now

    def account(self, now: float) -> None:
        """Integrate allocated core-seconds up to ``now`` (same monotone
        accumulation as ``VerticalScaledInstance.account``).

        Inlined into ``VectorSimRunner._run_ticks_fast`` under a strict
        ``spongelint: inline-of`` marker — editing this body fails the
        lint until the inlined copy is updated to alpha-match.
        """
        if now > self._last_t:
            self.core_seconds += self.c * (now - self._last_t)
            self._last_t = now


def build_bucket_array(b_set: Sequence[int]) -> np.ndarray:
    """``arr[x]`` = the smallest configured bucket >= x (``bmax`` past
    the end) — the O(1) batch→bucket map shared by every fast engine
    (previously built inline by both this runner and the fleet base)."""
    bmax = b_set[-1]
    buckets = np.empty(bmax + 1, np.int64)
    for x in range(bmax + 1):
        buckets[x] = next((bb for bb in b_set if bb >= x), bmax)
    return buckets


class FastSimRunner:
    """The Sponge control loop over a struct-of-arrays workload.

    Drives any decide-protocol ``SchedulingPolicy`` against simulated
    vertically/horizontally scalable slots, with identical scheduling
    semantics to ``ScenarioRunner`` + ``SimBackend`` (slack-aware EDF
    dispatch, adaptation ticks, resize penalties, cold starts) at a
    fraction of the per-event cost.  See the module docstring for the
    equivalence contract.
    """

    def __init__(self, policy, perf: PerfModel,
                 c_set=DEFAULT_C, b_set=DEFAULT_B, *, c0: int = 1,
                 tick: float = 1.0, resize_penalty: float = 0.005,
                 dispatch_margin: float = 0.02, prior_rps: float = 0.0,
                 rate_window: float = 5.0):
        if not hasattr(policy, "decide"):
            raise TypeError(
                f"{type(policy).__name__} has no decide(); the fast path "
                "drives bare SchedulingPolicy objects only — use "
                "ScenarioRunner for legacy on_tick policies")
        self.policy = policy
        self.perf = perf
        self.c_set = tuple(sorted(c_set))
        self.b_set = tuple(sorted(b_set))
        assert c0 in self.c_set, (c0, self.c_set)
        self.tick = tick
        self.resize_penalty = resize_penalty
        self.dispatch_margin = dispatch_margin
        self.prior_rps = prior_rps
        self.rate_window = rate_window
        # precomputed latency table: identical floats to SimBackend.execute
        self._lat: Dict[tuple[int, int], float] = {
            (c, b): float(perf.latency(b, c))
            for c in self.c_set for b in self.b_set}
        self._bucket_arr = build_bucket_array(self.b_set)
        self._bmax = self.b_set[-1]
        self._sid = itertools.count()
        self.b = 1
        self.queue = FastEDFQueue()
        self.slots: List[_Slot] = [_Slot(next(self._sid), c0, 0.0, 0.0)]
        self.dead: List[_Slot] = []
        self.core_samples: List[tuple[float, int]] = []
        self.bucket_log: List[tuple[float, int, int, int]] = []
        self.events_processed = 0

    # -- helpers -----------------------------------------------------------
    def _bucket(self, b: int) -> int:
        return int(self._bucket_arr[b]) if b <= self._bmax else self._bmax

    @property
    def allocated_cores(self) -> int:
        return sum(s.c for s in self.slots)

    def _apply(self, d, now: float) -> None:
        c, self.b = resolve_decision(self.c_set, d)
        pen = self.resize_penalty
        for s in self.slots:
            s.account(now)
            if s.c != c:
                s.c = c
                if pen:
                    s.busy_until = max(s.busy_until, now) + pen
        n = max(1, getattr(d, "n", 1))
        cur = len(self.slots)
        if n > cur:
            delay = getattr(d, "scale_up_delay", 0.0)
            for _ in range(n - cur):
                self.slots.append(_Slot(next(self._sid), c,
                                        now + delay, now))
        elif n < cur:
            for _ in range(min(cur - n, cur - 1)):
                s = self.slots.pop()
                s.dead_at = max(now, s.busy_until)
                self.dead.append(s)

    # -- entry points ------------------------------------------------------
    def session(self) -> "repro.serving.session.FastSession":
        """Open the online session on this runner (``submit`` /
        ``update_slo`` / ``cancel`` / ``step_until`` — see
        ``repro.serving.session``).  The session owns the event cursor
        and the dispatch pass; one session per runner."""
        from repro.serving.session import FastSession
        return FastSession(self)

    def run(self, batch: RequestBatch,
            horizon: Optional[float] = None) -> RunReport:
        """Thin replay driver over :meth:`session`: submit the whole
        (arrival-sorted) workload, drain to ``horizon`` (default: last
        arrival + 60 s) and report.  With no mid-flight events the
        session processes the identical event stream the closed-world
        loop did (the ``tests/test_fastpath.py`` contract)."""
        sess = self.session()
        sess.submit_batch(batch)
        return sess.finish(horizon)

    def vectorized(self) -> "repro.serving.vectorpath.VectorSimRunner":
        """A fresh :class:`~repro.serving.vectorpath.VectorSimRunner`
        with this runner's exact configuration (policy object included —
        hand over before running either engine).  The vectorpath replays
        closed-world workloads bit-identically to :meth:`run` at >=100x
        the events/s; see ``docs/performance.md`` for when to use it."""
        from repro.serving.vectorpath import VectorSimRunner
        return VectorSimRunner(
            self.policy, self.perf, self.c_set, self.b_set,
            c0=self.slots[0].c, tick=self.tick,
            resize_penalty=self.resize_penalty,
            dispatch_margin=self.dispatch_margin,
            prior_rps=self.prior_rps, rate_window=self.rate_window)


class TokenFastSimRunner(FastSimRunner):
    """Continuous-batching decode streams on the struct-of-arrays engine.

    The autoregressive extension of :class:`FastSimRunner` (ISSUE 3):
    the workload is a token-shaped ``RequestBatch`` (``prompt_tokens`` /
    ``decode_tokens`` / ``tbt_slo`` columns) and the single vertically
    scaled instance runs a **decode stream** with true continuous
    batching — between consecutive engine steps, requests *join* the
    running batch (their prompts prefill as part of the next step, first
    token = TTFT at the step boundary) and *leave* it the moment their
    stream completes, with per-slot token counters in plain arrays and
    step latency from the token cost model's composition surface
    (``step_latency(c, (prefill_tokens, decode_slots))``).

    Scheduling semantics:

    * admission is greedy EDF: whenever the running batch has free slots
      (``Decision.b`` is the slot cap) the earliest-deadline waiting
      requests join the next step — continuous batching does not hold
      prompts back to fill buckets;
    * the engine never idles while streams run: the next step starts at
      the previous step's boundary; with no work it sleeps until the
      next arrival;
    * in-place vertical resizes (and their penalty) take effect at the
      next step boundary — a step in flight finishes at the old c;
    * per-token SLOs are checked per step: a running slot's token gap is
      the distance between consecutive step boundaries, so a step longer
      than the slot's ``tbt_slo`` counts one violation for that slot.

    This runner is single-instance (the paper's Sponge mechanism);
    horizontal ``Decision.n`` targets are ignored.  It sustains >=100k
    autoregressive requests per run (``benchmarks/token_serving_bench``).
    """

    def __init__(self, policy, cost: TokenCostModel,
                 c_set=DEFAULT_C, b_set=DEFAULT_B, *, c0: int = 1,
                 tick: float = 1.0, resize_penalty: float = 0.005,
                 prior_rps: float = 0.0, rate_window: float = 5.0,
                 uncertainty=None):
        super().__init__(policy, cost, c_set, b_set, c0=c0, tick=tick,
                         resize_penalty=resize_penalty,
                         prior_rps=prior_rps, rate_window=rate_window)
        self.cost = cost
        self.queue = TokenFastEDFQueue()
        self._pending_penalty = 0.0
        # decode-length uncertainty (ISSUE 7): a non-point
        # ``repro.core.uncertainty.UncertaintyConfig`` arms speculative
        # admission with cancel-on-overrun on the session loop; None or
        # a point mass keeps the deterministic loop verbatim
        self.uncertainty = uncertainty
        self.overrun_cancels = 0   # set by the session at report time

    def _apply(self, d, now: float) -> None:
        """In-place vertical resize; the penalty lands on the next step."""
        c, self.b = resolve_decision(self.c_set, d)
        s = self.slots[0]
        s.account(now)
        if s.c != c:
            s.c = c
            self._pending_penalty += self.resize_penalty

    # -- entry points ------------------------------------------------------
    def session(self) -> "repro.serving.session.TokenFastSession":
        """Open the online session on this runner (TTFT renegotiation /
        cancellation for requests still waiting for admission — see
        ``repro.serving.session``)."""
        from repro.serving.session import TokenFastSession
        return TokenFastSession(self)

    def run(self, batch: RequestBatch,
            horizon: Optional[float] = None) -> RunReport:
        """Thin replay driver over :meth:`session` (submit the workload,
        drain, report) — the continuous-batching loop itself lives on
        :class:`~repro.serving.session.TokenFastSession`."""
        sess = self.session()
        sess.submit_batch(batch)
        return sess.finish(horizon)

    def scan_engine(self, *, chunk_steps: int = 64, decide=None
                    ) -> "repro.serving.scanpath.ScanDecodeEngine":
        """A :class:`~repro.serving.scanpath.ScanDecodeEngine` built
        from this runner's cost model and current allocation — the
        ``lax.scan``-jitted decode-stream prototype (NumPy fallback when
        JAX is absent).  Its step semantics are a documented
        simplification of this runner's, not a bit-identical replay;
        the contract is JAX/NumPy backend parity."""
        from repro.serving.scanpath import ScanDecodeEngine
        return ScanDecodeEngine(self.cost, c0=self.slots[0].c,
                                b0=self.b_set[-1],
                                chunk_steps=chunk_steps, decide=decide)

    # -- reporting ---------------------------------------------------------
    def _token_report(self, batch: RequestBatch, first_tok: np.ndarray,
                      finish: np.ndarray, tbt_bad: np.ndarray,
                      tokens_served: int, decode_tokens_served: int,
                      tbt_viol_tokens: int, horizon: float,
                      n_cancelled: int = 0) -> RunReport:
        """Vectorized aggregates over the token run."""
        served = ~np.isnan(finish)
        send = batch.arrival - batch.comm_latency
        fin = finish[served]
        n_req = int(served.sum())
        ttft_late = first_tok[served] > batch.deadline[served] + 1e-9
        viol = int((ttft_late | tbt_bad[served]).sum())
        e2e = np.sort(fin - send[served])
        ttft = np.sort(first_tok[served] - send[served])
        nn = e2e.size

        def p(a: np.ndarray, q: float) -> float:
            if not a.size:
                return float("nan")
            return float(a[min(int(q * a.size), a.size - 1)])

        core_s = 0.0
        for s in self.slots + self.dead:
            s.account(horizon)
            core_s += s.core_seconds
        decisions = getattr(self.policy, "decisions", None)
        if decisions is None:
            decisions = getattr(getattr(self.policy, "scaler", None),
                                "decisions", None)
        return RunReport(
            policy=getattr(self.policy, "name", type(self.policy).__name__),
            backend="token-sim-fast",
            n_requests=n_req,
            n_violations=viol,
            violation_rate=viol / max(n_req, 1),
            core_seconds=core_s,
            avg_cores=core_s / max(horizon, 1e-9),
            p50=p(e2e, 0.50), p99=p(e2e, 0.99),
            mean_latency=float(e2e.sum()) / max(nn, 1),
            core_timeline=self.core_samples,
            decisions=decisions,
            buckets=self.bucket_log,
            tokens_served=tokens_served,
            tokens_per_s=tokens_served / max(horizon, 1e-9),
            ttft_p50=p(ttft, 0.50), ttft_p99=p(ttft, 0.99),
            tbt_violation_rate=(tbt_viol_tokens
                                / max(decode_tokens_served, 1)),
            n_cancelled=n_cancelled,
        )
