"""The fleet layer: joint horizontal + vertical scaling over N replicas.

Sponge scopes itself to in-place vertical scaling of a single instance;
its follow-up ("A Tale of Two Scales", Razavi et al. 2024) shows the
real win is reconciling vertical with horizontal replica scaling under
one cost model, and Orloj (Yu et al. 2022) shows per-replica
deadline-aware dispatch is what keeps tail SLOs honest at fleet scale.
This module is that layer on top of the PR 1–3 single-replica control
plane:

* :class:`FleetReplica` — one replica: a vertically scalable core count,
  its **own EDF queue** (per-replica dispatch, not a shared global
  queue), availability/cold-start state, and core-second accounting.
* **Routers** (:func:`route_request`) — pluggable arrival routing across
  admitting replicas: ``least-loaded`` (shortest queue, busy replicas
  penalized), ``jsq`` (join-shortest-queue) and ``edf-deadline``
  (Orloj-style: join the replica where the new request has the fewest
  earlier-deadline requests ahead of it).
* :class:`FleetSpongeScaler` — the joint scaler: every adaptation
  interval it snapshots the *global* queue state and solves the joint
  ``(n, c, b)`` IP (``repro.core.solver.JointMemoizedSolver``) that
  minimizes total core allocation ``n*c`` subject to every request's
  dynamic SLO.  Scale-ups take effect immediately (new replicas pay a
  cold start); **scale-downs are hysteretic** — the lower replica target
  must persist for ``down_patience`` consecutive decisions, and in the
  meantime ``(c, b)`` re-solves with ``n`` pinned at the current fleet
  size, so a transient lull sheds cores vertically before it sheds
  replicas.
* **Scale-down drain** — a retiring replica stops admitting, its queued
  requests re-route to the survivors through the same router (EDF
  order), it finishes its in-flight batch, and only then releases its
  cores (``dead_at = max(now, busy_until)`` bounds the core-second
  integral).
* Two event engines, one semantics: :class:`FleetFastSimRunner` (the
  struct-of-arrays engine — streamed arrivals/ticks/fleet events, bare
  ``(deadline, index)`` queues, ≥500k requests/run across ≥8 replicas,
  ``benchmarks/fleet_bench.py``) and :class:`FleetExactRunner` (the
  exact gang loop: every event pre-heaped, ``Request`` objects,
  object-based EDF queues — the decision-identity oracle
  ``tests/test_fleet.py`` holds the fast engine to).

Fleet disruptions (``replica-failure`` / ``rolling-restart`` scenarios)
arrive as **fleet events**: ``(t, "kill", i)`` retires the i-th active
replica abruptly (in-flight batch completes, queue re-routes, cores
release — fail-stop after the current batch), ``(t, "restart", i,
delay)`` does the same but immediately spawns a replacement that cold
starts for ``delay`` seconds.  Recovery is the scaler's job: the next
adaptation tick sees the shrunken fleet and re-targets ``n``.

Since ISSUE 5 the fast engine's event loop lives on the online session
(``repro.serving.session.FleetSession`` — mid-flight ``update_slo``
re-routes tightened budgets through the arrival router, ``cancel``
excises queued work) and ``FleetFastSimRunner.run`` is its
no-renegotiation replay; ``FleetExactRunner`` keeps the pre-heaped
closed-world gang loop as the decision-identity oracle.
"""
from __future__ import annotations

import heapq
import itertools
from bisect import bisect_left, insort
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.cost_model import CostModel
from repro.core.monitor import (accuracy_weighted_goodput,
                                array_window_rate, tick_window_rate)
from repro.core.perf_model import PerfModel
from repro.core.queueing import EDFQueue, FastEDFQueue
from repro.core.slo import Decision
from repro.core.solver import (DEFAULT_B, DEFAULT_C, DEFAULT_N,
                               JointMemoizedSolver)
from repro.serving.api import (RunReport, build_array_report,
                               resolve_decision)
from repro.serving.fastpath import build_bucket_array
from repro.serving.workload import RequestBatch

ROUTERS = ("least-loaded", "jsq", "edf-deadline")


class FleetReplica:
    """One fleet replica as plain scalars + its own EDF queue.

    The queue substrate differs by engine (``FastEDFQueue`` of bare
    ``(deadline, index)`` pairs on the fast path, ``EDFQueue`` of
    ``Request`` objects on the exact gang loop) but both expose
    ``_heap`` with ``item[0]`` the absolute deadline, which is all the
    routers and the dispatch pass read.  Core-second accounting is the
    same monotone integral as ``repro.serving.fastpath._Slot``.
    """

    __slots__ = ("id", "c", "ready_at", "busy_until", "alive_since",
                 "dead_at", "core_seconds", "_last_t", "queue", "dls")

    def __init__(self, rid: int, c: int, ready_at: float, now: float,
                 queue):
        self.id = rid
        self.c = c
        self.ready_at = ready_at
        self.busy_until = 0.0
        self.alive_since = now
        self.dead_at: Optional[float] = None
        self.core_seconds = 0.0
        self._last_t: Optional[float] = now
        self.queue = queue
        # sorted mirror of the queued deadlines, maintained at every
        # push/pop alongside the heap: lets the edf-deadline router count
        # a newcomer's EDF position with one bisect instead of scanning
        # the whole heap per arrival (O(total backlog) per request turns
        # the overloaded phases of a 500k-request run quadratic)
        self.dls: List[float] = []

    def account(self, now: float) -> None:
        """Integrate allocated core-seconds up to ``now``."""
        if now > self._last_t:
            self.core_seconds += self.c * (now - self._last_t)
            self._last_t = now


def route_request(router: str, replicas: Sequence[FleetReplica],
                  deadline: float, now: float,
                  cold_load=None) -> int:
    """Pick the replica (index into ``replicas``) a new arrival joins.

    All keys tie-break on list position (creation order), so routing is
    deterministic and identical across the fast and exact engines:

    * ``least-loaded`` — lowest load, where load is queue length plus one
      slot for a busy replica plus the replica's remaining cold-start
      time converted to queue-slot equivalents (``cold_load``) — a
      replica still booting only attracts work once the warm queues are
      deeper than its boot time is long;
    * ``jsq``          — join-shortest-queue, queue length only (the
      classic baseline, deliberately cold-start-blind);
    * ``edf-deadline`` — deadline-aware (Orloj-style): join the replica
      where the fewest queued requests have an *earlier* deadline than
      the newcomer (the newcomer's EDF position, cold-start adjusted),
      then shortest queue.  Counted with one bisect into each replica's
      sorted deadline mirror (``FleetReplica.dls``), not a heap scan —
      O(R log q) per arrival even when an overload spike piles tens of
      thousands of requests into the queues.

    ``cold_load`` maps a replica to its cold-start penalty in fractional
    queue slots (the runners pass remaining boot seconds divided by the
    replica's per-request service time); ``None`` disables the term.
    """
    best = 0
    best_key: Optional[tuple] = None
    for i, r in enumerate(replicas):
        qn = len(r.queue)               # live entries only (renegotiation
        cold = cold_load(r) if cold_load is not None else 0.0   # safe)
        if router == "least-loaded":
            busy = 1 if (r.busy_until > now or r.ready_at > now) else 0
            key = (qn + busy + cold, i)
        elif router == "jsq":
            key = (qn, i)
        elif router == "edf-deadline":
            ahead = bisect_left(r.dls, deadline)
            key = (ahead + cold, qn, i)
        else:
            raise KeyError(f"unknown router {router!r}; known: {ROUTERS}")
        if best_key is None or key < best_key:
            best_key = key
            best = i
    return best


# --------------------------------------------------------------------------
# fleet scheduling policies
# --------------------------------------------------------------------------
class _JointPolicyBase:
    """Shared fleet-policy plumbing: the adaptation-cadence gate and the
    lazily built joint memoized solver — one copy, so the sponge scaler
    and the static baseline it is benchmarked against cannot drift on
    the cadence epsilon or the cache-stats shape.  Subclasses provide
    ``_make_memo()`` and set ``_next_t`` in ``decide_fleet``."""

    def due(self, now: float) -> bool:
        """Adaptation-interval gate (same cadence rule as SpongeScaler)."""
        return now + 1e-12 >= self._next_t

    def _make_memo(self) -> JointMemoizedSolver:  # pragma: no cover
        raise NotImplementedError

    @property
    def memo(self) -> JointMemoizedSolver:
        """The lazily built memoized joint solver."""
        if self._memo is None:
            self._memo = self._make_memo()
        return self._memo

    def solver_stats(self) -> dict:
        """Cache economics of the memoized joint solver."""
        if self._memo is None:
            return {}
        return {"hits": self._memo.hits, "misses": self._memo.misses,
                "hit_rate": self._memo.hit_rate}


@dataclass
class FleetSpongeScaler(_JointPolicyBase):
    """The joint (n, c, b) Sponge scaler with scale-down hysteresis.

    Every adaptation interval: snapshot the global EDF budgets, solve
    the joint IP (minimum total cores ``n*c``), and emit a
    :class:`~repro.core.slo.Decision` carrying the replica target ``n``.
    Scale-ups are immediate (the Decision carries ``scale_up_delay`` —
    the cold start new replicas pay); a scale-down must persist for
    ``down_patience`` consecutive decisions before it is emitted — until
    then ``(c, b)`` re-solves with ``n`` pinned at the current fleet
    size, so transient lulls shed cores in place (the cheap axis)
    instead of churning replicas.  Quanta at 0 keep the memoized joint
    solver exact (the decision-identity configuration); positive quanta
    are the conservative production bucketing
    (``benchmarks/fleet_bench.py``).
    """
    perf: Union[PerfModel, CostModel]
    name: str = "sponge-fleet"
    c_set: Sequence[int] = DEFAULT_C
    b_set: Sequence[int] = DEFAULT_B
    n_set: Sequence[int] = DEFAULT_N
    adaptation_interval: float = 1.0
    # wider margins than the single-replica SpongeScaler: the joint
    # solver plans a perfectly balanced striped split, real routers
    # approximate it, and the gap eats into both budgets
    headroom: float = 0.15              # latency safety margin (seconds)
    lam_headroom: float = 1.3           # provision for lam * this factor
    budget_quantum: float = 0.0
    lam_quantum: float = 0.0
    # per-replica core-equivalent overhead in the joint objective: keeps
    # the solver vertical-first instead of sharding into 1-core replicas
    # whose thin latency margins amplify routing imbalance
    replica_pen: float = 1.0
    scale_up_delay: float = 2.0         # cold start of a new replica (s)
    down_patience: int = 5              # consecutive lower-n decisions
    # total-core ceiling imposed from above (multi-tenant pool slice);
    # None = unconstrained, the single-tenant fleet behaviour
    core_cap: Optional[int] = None
    decisions: List[tuple] = field(default_factory=list)
    _next_t: float = 0.0
    _down_streak: int = 0
    _memo: Optional[JointMemoizedSolver] = field(default=None, repr=False)

    def _make_memo(self) -> JointMemoizedSolver:
        return JointMemoizedSolver(
            self.perf, self.c_set, self.b_set, self.n_set,
            budget_quantum=self.budget_quantum,
            lam_quantum=self.lam_quantum,
            replica_pen=self.replica_pen)

    def _solve_full(self, rem: np.ndarray, lam_eff: float,
                    initial_wait: float) -> Decision:
        """The unconstrained joint solve (degradation subclasses widen
        this to the (m, n, c, b) search)."""
        return self.memo.solve(rem, lam_eff, initial_wait=initial_wait,
                               max_cores=self.core_cap)

    def _solve_pinned_n(self, d: Decision, rem: np.ndarray, lam_eff: float,
                        initial_wait: float, pin: int) -> Decision:
        """The hysteresis-hold re-solve at a pinned replica count
        (degradation subclasses additionally pin the model to ``d.m``)."""
        return self.memo.solve(rem, lam_eff, initial_wait=initial_wait,
                               only_n=pin, max_cores=self.core_cap)

    def _model_gate(self, d: Decision, rem: np.ndarray, lam_eff: float,
                    initial_wait: float) -> Decision:
        """Model-swap hysteresis hook — identity for the single-model
        scaler."""
        return d

    def decide_fleet(self, now: float, remaining: np.ndarray, lam: float,
                     initial_wait: float = 0.0,
                     active_n: int = 1) -> Decision:
        """One adaptation step over the global queue snapshot."""
        self._next_t = now + self.adaptation_interval
        rem = np.maximum(np.asarray(remaining, np.float64) - self.headroom,
                         0.0)
        lam_eff = lam * self.lam_headroom
        d = self._solve_full(rem, lam_eff, initial_wait)
        d = self._model_gate(d, rem, lam_eff, initial_wait)
        if d.n < active_n:
            self._down_streak += 1
            if self._down_streak < self.down_patience:
                # hysteresis: hold the fleet size and re-solve (c, b) at
                # the nearest n_set entry NOT ABOVE it — active_n can sit
                # outside a sparse n_set right after a kill event, and a
                # smaller assumed n makes the (c, b) re-solve strictly
                # more conservative (tighter drain + throughput)
                fits = [n for n in self.n_set if n <= active_n]
                pin = max(fits) if fits else min(self.n_set)
                held = self._solve_pinned_n(d, rem, lam_eff, initial_wait,
                                            pin)
                d = replace(held, n=active_n)
            else:
                self._down_streak = 0
        else:
            self._down_streak = 0
        if d.n > active_n and self.scale_up_delay:
            d = replace(d, scale_up_delay=self.scale_up_delay)
        self.decisions.append((now, d))
        return d


@dataclass
class DegradingFleetScaler(FleetSpongeScaler):
    """The (m, n, c, b) scaler: model size as the third scaling axis.

    Wraps the joint fleet scaler around a
    :class:`~repro.core.degradation.ModelLadder`: every adaptation
    interval the :class:`~repro.core.solver.MultiModelMemoizedSolver`
    searches rungs accuracy-descending, so accuracy is **shed only
    when no (n, c, b) at the resident model is feasible**, never below
    ``accuracy_floor``.  The search is swap-cost-aware — a non-resident
    rung's feasibility is checked with its weights-load time added to
    the initial wait — and model swaps are hysteretic the same way
    scale-downs are: a proposed swap must persist for a run of
    consecutive decisions (same target rung) before it is emitted;
    until then (n, c, b) re-solves with ``m`` pinned at the resident
    model, which reduces bit-identically to the PR 4 joint solver.
    The patience is asymmetric: a *shed* (accuracy-decreasing swap)
    commits after ``shed_patience`` proposals — it protects the SLO,
    and every held tick grows the backlog — while a *recovery*
    (accuracy-increasing swap) waits the longer ``swap_patience``,
    because recovering onto a rung that is only marginally feasible
    flips straight back and pays two weights loads for nothing.

    The emitted :class:`~repro.core.slo.Decision` carries the target
    rung in ``d.m``; the fleet runners apply the swap with
    drain-before-swap semantics (in-flight batches finish on the old
    model, the weights-load penalty blocks new dispatch — see
    ``_FleetRunnerBase._apply``).
    """
    ladder: Optional[object] = None      # ModelLadder (required)
    accuracy_floor: float = 0.0
    swap_patience: int = 6               # recovery (accuracy-up) patience
    shed_patience: int = 2               # shed (accuracy-down) patience
    m0: Optional[str] = None             # initial resident rung
    name: str = "sponge-degrade"
    _swap_streak: int = 0
    _swap_target: Optional[str] = None

    def __post_init__(self):
        if self.ladder is None:
            raise ValueError("DegradingFleetScaler needs a ModelLadder")
        self.model = (self.m0 if self.m0 is not None
                      else self.ladder.best(self.accuracy_floor).name)
        self.ladder.rung(self.model)     # validate m0

    def _make_memo(self):
        from repro.core.solver import MultiModelMemoizedSolver
        return MultiModelMemoizedSolver(
            self.ladder, self.c_set, self.b_set, self.n_set,
            budget_quantum=self.budget_quantum,
            lam_quantum=self.lam_quantum,
            replica_pen=self.replica_pen)

    def _solve_full(self, rem: np.ndarray, lam_eff: float,
                    initial_wait: float) -> Decision:
        return self.memo.solve(rem, lam_eff, initial_wait=initial_wait,
                               max_cores=self.core_cap,
                               accuracy_floor=self.accuracy_floor,
                               current_m=self.model)

    def _solve_pinned_n(self, d: Decision, rem: np.ndarray, lam_eff: float,
                        initial_wait: float, pin: int) -> Decision:
        # the n-hysteresis hold also holds the (already model-gated)
        # rung, so a held decision never smuggles a swap past the gate
        return self.memo.solve(rem, lam_eff, initial_wait=initial_wait,
                               only_n=pin, max_cores=self.core_cap,
                               accuracy_floor=self.accuracy_floor,
                               m_set=(d.m,), current_m=self.model)

    def _model_gate(self, d: Decision, rem: np.ndarray, lam_eff: float,
                    initial_wait: float) -> Decision:
        """``down_patience``-style hysteresis on the model axis: commit
        a swap only after enough consecutive decisions propose the
        *same* target rung (``shed_patience`` for accuracy-decreasing
        swaps, ``swap_patience`` for recoveries); hold the resident
        model (full re-solve with ``m`` pinned) in the meantime."""
        if d.m == self.model:
            self._swap_streak, self._swap_target = 0, None
            return d
        if d.m == self._swap_target:
            self._swap_streak += 1
        else:
            self._swap_streak, self._swap_target = 1, d.m
        patience = (self.shed_patience
                    if (self.ladder.accuracy(d.m)
                        < self.ladder.accuracy(self.model))
                    else self.swap_patience)
        if self._swap_streak >= patience:
            self._swap_streak, self._swap_target = 0, None
            self.model = d.m             # commit; runners pay the load
            return d
        held = self.memo.solve(rem, lam_eff, initial_wait=initial_wait,
                               max_cores=self.core_cap,
                               accuracy_floor=self.accuracy_floor,
                               m_set=(self.model,), current_m=self.model)
        return held


@dataclass
class StaticFleetPolicy(_JointPolicyBase):
    """The static-fleet baseline: ``replicas`` x ``cores`` pinned, batch
    size via the same joint solver with (n, c) fixed — what a peak-
    provisioned deployment without joint autoscaling looks like (the
    core-seconds baseline ``benchmarks/fleet_bench.py`` reports savings
    against)."""
    perf: Union[PerfModel, CostModel]
    replicas: int = 8
    cores: int = 16
    b_set: Sequence[int] = DEFAULT_B
    interval: float = 1.0
    budget_quantum: float = 0.0
    lam_quantum: float = 0.0
    scale_up_delay: float = 2.0         # failure recovery pays a cold start
    name: str = "static-fleet"
    decisions: List[tuple] = field(default_factory=list)
    _next_t: float = 0.0
    _memo: Optional[JointMemoizedSolver] = field(default=None, repr=False)

    def __post_init__(self):
        self.name = f"static-fleet-{self.replicas}x{self.cores}"

    def _make_memo(self) -> JointMemoizedSolver:
        # the joint solver with the (n, c) grid pinned to the static fleet
        return JointMemoizedSolver(
            self.perf, (self.cores,), self.b_set, (self.replicas,),
            budget_quantum=self.budget_quantum,
            lam_quantum=self.lam_quantum)

    def decide_fleet(self, now: float, remaining: np.ndarray, lam: float,
                     initial_wait: float = 0.0,
                     active_n: int = 1) -> Decision:
        """Batch-only adaptation at the pinned fleet shape (replicas
        lost to fleet events are replaced, paying the cold start)."""
        self._next_t = now + self.interval
        d = self.memo.solve(remaining, lam, initial_wait=initial_wait)
        if d.n > active_n and self.scale_up_delay:
            d = replace(d, scale_up_delay=self.scale_up_delay)
        self.decisions.append((now, d))
        return d


# --------------------------------------------------------------------------
# the two fleet engines
# --------------------------------------------------------------------------
def normalize_fleet_events(events) -> List[tuple]:
    """Sort + validate fleet events: ``(t, "kill", i)`` or ``(t,
    "restart", i[, delay])``, time-ascending (stable on ties)."""
    out = []
    for ev in (events or ()):
        t, kind, *args = ev
        if kind not in ("kill", "restart"):
            raise ValueError(f"unknown fleet event kind {kind!r}")
        out.append((float(t), kind, tuple(args)))
    out.sort(key=lambda e: e[0])
    return out


class _FleetRunnerBase:
    """Config + the semantics shared verbatim by both fleet engines:
    routing, decision application, drain/retire, fleet events, the λ
    estimator and reporting.  Only the event-loop organization and the
    queue substrate differ per subclass (that is the point: the exact
    gang loop is the oracle the fast engine is held to)."""

    backend_name = "fleet"

    def __init__(self, policy, perf: Union[PerfModel, CostModel],
                 c_set=DEFAULT_C, b_set=DEFAULT_B, *, n0: int = 1,
                 c0: int = 1, tick: float = 1.0,
                 resize_penalty: float = 0.005,
                 dispatch_margin: float = 0.02, prior_rps: float = 0.0,
                 rate_window: float = 5.0, router: str = "least-loaded",
                 ladder=None, m0: Optional[str] = None):
        if not hasattr(policy, "decide_fleet"):
            raise TypeError(
                f"{type(policy).__name__} has no decide_fleet(); fleet "
                "runners drive fleet policies (FleetSpongeScaler, "
                "StaticFleetPolicy)")
        if router not in ROUTERS:
            raise KeyError(f"unknown router {router!r}; known: {ROUTERS}")
        self.policy = policy
        self.perf = perf
        self.c_set = tuple(sorted(c_set))
        self.b_set = tuple(sorted(b_set))
        assert c0 in self.c_set, (c0, self.c_set)
        self.tick = tick
        self.resize_penalty = resize_penalty
        self.dispatch_margin = dispatch_margin
        self.prior_rps = prior_rps
        self.rate_window = rate_window
        self.router = router
        # only the edf-deadline router bisects the sorted deadline
        # mirror; the other routers skip its upkeep (an O(backlog)
        # insort per arrival that nothing would read)
        self._track_dls = router == "edf-deadline"
        # model ladder (ISSUE 9): per-rung latency tables + the resident
        # rung; ``self._lat`` is mutated IN PLACE on a swap because both
        # engines hold local aliases to it across dispatch loops
        self.ladder = ladder
        if ladder is not None:
            self.model = (m0 or getattr(policy, "model", None)
                          or ladder[0].name)
            ladder.rung(self.model)          # validate
            self._lat_by_m: Dict[str, dict] = {
                rung.name: {(c, b): float(rung.cost.latency(b, c))
                            for c in self.c_set for b in self.b_set}
                for rung in ladder}
            self._lat: Dict[tuple[int, int], float] = dict(
                self._lat_by_m[self.model])
            self.model_log: List[tuple[float, str, float]] = [
                (0.0, self.model, ladder.accuracy(self.model))]
        else:
            self.model = None
            self.model_log = []
            # precomputed latency table: identical floats to perf.latency
            self._lat = {(c, b): float(perf.latency(b, c))
                         for c in self.c_set for b in self.b_set}
        self._bucket_arr = build_bucket_array(self.b_set)
        self._bmax = self.b_set[-1]
        self._rid = itertools.count()
        self.b = 1
        self.replicas: List[FleetReplica] = []
        self.dead: List[FleetReplica] = []
        self.max_replicas = 0
        for _ in range(max(1, n0)):
            self._add_replica(c0, ready_at=0.0, now=0.0)
        self.core_samples: List[tuple[float, int]] = []
        self.bucket_log: List[tuple[float, int, int, int]] = []
        self.events_processed = 0

    # -- substrate hooks (overridden per engine) ---------------------------
    def _new_queue(self):
        raise NotImplementedError

    def _requeue(self, src: FleetReplica, now: float) -> None:
        """Re-route every queued item of ``src`` onto the survivors (EDF
        order, through the configured router)."""
        raise NotImplementedError

    # -- shared helpers ----------------------------------------------------
    def _bucket(self, b: int) -> int:
        return int(self._bucket_arr[b]) if b <= self._bmax else self._bmax

    def _cold_load(self, now: float):
        """Cold-start penalty hook for :func:`route_request`: a booting
        replica's remaining boot seconds expressed in queue-slot
        equivalents (requests a warm replica of the same shape would
        serve in that time), so routers only send work to a cold replica
        once every warm queue is at least that deep."""
        b = self._bucket(self.b)
        lat = self._lat

        def f(r: FleetReplica) -> float:
            w = r.ready_at - now
            if w <= 0.0:
                return 0.0
            return w * b / lat[(r.c, b)]

        return f

    @property
    def allocated_cores(self) -> int:
        return sum(r.c for r in self.replicas)

    def _add_replica(self, c: int, ready_at: float, now: float
                     ) -> FleetReplica:
        r = FleetReplica(next(self._rid), c, ready_at, now,
                         self._new_queue())
        self.replicas.append(r)
        self.max_replicas = max(self.max_replicas,
                                len(self.replicas))
        return r

    def _retire(self, r: FleetReplica, now: float) -> None:
        """Scale-down drain: stop admitting (the caller already removed
        ``r`` from the active list), re-route its queue, finish in-flight
        work, release cores at ``max(now, busy_until)``."""
        self._requeue(r, now)
        r.dead_at = max(now, r.busy_until)
        self.dead.append(r)

    def _rate(self, now: float) -> float:
        """Sliding-window λ — the shared ``core.monitor.array_window_rate``
        estimate, resolved through one helper by the single-replica fast
        path and both fleet engines so decisions cannot drift on the
        estimator."""
        if self._ai is None:
            # closed-world batch replay: the observed-arrival count is
            # derived from the sorted column at tick time (bit-identical
            # to the per-arrival counter, since arrivals at T precede
            # the tick at T) — no Python work per arrival
            lam, self._w0 = tick_window_rate(self._arr, self._w0, now,
                                             self.rate_window,
                                             self.prior_rps)
        else:
            lam, self._w0 = array_window_rate(self._arr, self._ai,
                                              self._w0, now,
                                              self.rate_window,
                                              self.prior_rps)
        return lam

    def _drive(self, now: float, lam: Optional[float] = None) -> None:
        """One adaptation step: global snapshot -> joint decide -> apply.

        ``lam`` overrides the λ source (the online session passes its
        cancel-aware estimate; the closed-world oracle loop uses the
        runner's own two-pointer window) — one copy of the drive rule,
        so the session and oracle paths cannot drift."""
        pol = self.policy
        if hasattr(pol, "due") and not pol.due(now):
            return
        if lam is None:
            lam = self._rate(now)
        reps = self.replicas
        iw = min(max(r.busy_until - now, 0.0) for r in reps)
        rem = np.sort(np.concatenate(
            [r.queue.remaining_array(now) for r in reps]))
        d = pol.decide_fleet(now, rem, lam, initial_wait=iw,
                             active_n=len(reps))
        self._apply(d, now)

    def _apply(self, d: Decision, now: float) -> None:
        """Apply a joint decision: retire extras (drain), swap the model
        if the decision carries a new rung, resize the survivors in
        place, then add cold-starting replicas.

        **Drain-before-swap**: a model swap never interrupts in-flight
        work — batches already dispatched keep their finish times (they
        were computed on the old rung's surface) and the weights-load
        penalty extends ``busy_until`` past them, so a replica serves
        its first new-model batch only after the old-model batch
        completed *and* the new weights loaded.  The penalty is the
        model-swap analogue of the resize penalty / horizontal cold
        start, and like them it only delays dispatch: core-second
        accounting is untouched (the replica's cores stay allocated
        either way — property-tested in ``tests/test_degradation.py``).
        """
        c, self.b = resolve_decision(self.c_set, d)
        n = max(1, int(getattr(d, "n", 1)))
        reps = self.replicas
        if n < len(reps):
            for _ in range(min(len(reps) - n, len(reps) - 1)):
                self._retire(reps.pop(), now)       # youngest first
        swap_pen = 0.0
        m = getattr(d, "m", None)
        if self.ladder is not None and m is not None and m != self.model:
            swap_pen = float(self.ladder.swap_cost(m))
            self.model = m
            # in place: both engines alias self._lat across dispatches
            self._lat.clear()
            self._lat.update(self._lat_by_m[m])
            self.model_log.append((now, m, self.ladder.accuracy(m)))
        pen = self.resize_penalty
        for r in reps:
            r.account(now)
            if r.c != c:
                r.c = c
                if pen:
                    r.busy_until = max(r.busy_until, now) + pen
            if swap_pen:
                r.busy_until = max(r.busy_until, now) + swap_pen
        if n > len(reps):
            delay = getattr(d, "scale_up_delay", 0.0)
            for _ in range(n - len(reps)):
                self._add_replica(c, ready_at=now + delay, now=now)

    def _fleet_event(self, kind: str, args: tuple, now: float) -> None:
        """Apply one fleet disruption (see the module docstring)."""
        reps = self.replicas
        if kind == "kill":
            if len(reps) <= 1:
                return                 # never kill the last replica
            r = reps.pop(int(args[0]) % len(reps))
            self._retire(r, now)
        else:                          # restart
            r = reps.pop(int(args[0]) % len(reps))
            delay = float(args[1]) if len(args) > 1 else 5.0
            self._add_replica(r.c, ready_at=now + delay, now=now)
            self._retire(r, now)

    def _report(self, batch: RequestBatch, finish: np.ndarray,
                horizon: float) -> RunReport:
        """Aggregate through the shared ``serving.api.build_array_report``
        (same served/violation/percentile/core-second conventions as the
        single-replica fast path, by construction); ladder runs add the
        accuracy-weighted goodput axes from the resident-model
        timeline."""
        rep = build_array_report(self.policy, self.backend_name, batch,
                                 finish, horizon,
                                 self.replicas + self.dead,
                                 self.core_samples, self.bucket_log)
        return self._enrich_report(rep, finish, batch.deadline, horizon)

    def _enrich_report(self, rep: RunReport, finish: np.ndarray,
                       deadline: np.ndarray, horizon: float) -> RunReport:
        """Attach the degradation axes (accuracy-weighted goodput, swap
        count, resident-model timeline) on ladder runs — shared by the
        closed-world report above and the online ``FleetSession`` report,
        so the two cannot drift on the metric."""
        if self.ladder is None:
            return rep
        agp, macc = accuracy_weighted_goodput(finish, deadline,
                                              self.model_log, horizon)
        return replace(rep, accuracy_goodput=agp,
                       mean_served_accuracy=macc,
                       model_swaps=len(self.model_log) - 1,
                       model_timeline=list(self.model_log))


class FleetFastSimRunner(_FleetRunnerBase):
    """The fleet control loop on bare arrays — the ≥500k-request engine.

    Streamed arrivals / adaptation ticks / fleet events (three sorted
    cursors), an event heap holding only batch completions and per-
    replica wake-ups (deduplicated), per-replica ``FastEDFQueue``s of
    bare ``(deadline, index)`` pairs, and one dispatch pass per event
    mirroring ``FastSimRunner``'s slack-aware EDF rules replica by
    replica.  Event order at equal times: arrivals, then ticks, then
    fleet events, then dynamic events — exactly the order the exact
    gang loop's pre-heaped ``(t, seq)`` keys produce, which is one half
    of the decision-identity contract (``tests/test_fleet.py``).
    """

    backend_name = "fleet-fast"

    def _new_queue(self) -> FastEDFQueue:
        return FastEDFQueue()

    def _requeue(self, src: FleetReplica, now: float) -> None:
        items = src.queue.drain()                           # EDF order
        src.dls.clear()
        cold = self._cold_load(now)
        for dl, idx in items:
            j = route_request(self.router, self.replicas, dl, now,
                              cold_load=cold)
            tgt = self.replicas[j]
            tgt.queue.push(dl, idx)
            if self._track_dls:
                insort(tgt.dls, dl)

    def session(self, fleet_events=()
                ) -> "repro.serving.session.FleetSession":
        """Open the online session on this fleet (``submit`` /
        ``update_slo`` / ``cancel`` / ``step_until``; a tightened budget
        re-routes through the arrival router — see
        ``repro.serving.session``).  ``fleet_events`` are the optional
        kill/restart disruptions."""
        from repro.serving.session import FleetSession
        return FleetSession(self, fleet_events=fleet_events)

    def run(self, batch: RequestBatch, horizon: Optional[float] = None,
            events=()) -> RunReport:
        """Thin replay driver over :meth:`session`: submit the whole
        struct-of-arrays workload (plus optional fleet events), drain
        to the horizon, report.  With no mid-flight renegotiation the
        session replays the identical event stream the closed-world
        fleet loop did — the decision-identity contract
        ``tests/test_fleet.py`` holds against the pre-heaped
        :class:`FleetExactRunner` oracle."""
        sess = self.session(fleet_events=events)
        sess.submit_batch(batch)
        return sess.finish(horizon)


class FleetExactRunner(_FleetRunnerBase):
    """The exact fleet gang loop — the decision-identity oracle.

    Organized like ``repro.serving.reference.ReferenceRunner``: every
    arrival, every adaptation tick and every fleet event is heap-pushed
    up front with a sequence number (so ties resolve arrivals → ticks →
    fleet events → dynamic events), requests are real ``Request``
    objects on per-replica ``EDFQueue``s, and each event triggers a full
    dispatch scan over the pool.  Slow and easy to audit — exactly what
    an oracle should be.  ``tests/test_fleet.py`` proves
    :class:`FleetFastSimRunner` produces identical ``(n, c, b)``
    decision streams, batch buckets and aggregates on the fleet
    scenarios.
    """

    backend_name = "fleet-exact"

    def _new_queue(self) -> EDFQueue:
        return EDFQueue()

    def _requeue(self, src: FleetReplica, now: float) -> None:
        items = src.queue.pop_batch(len(src.queue))         # EDF order
        src.dls.clear()
        cold = self._cold_load(now)
        for req in items:
            j = route_request(self.router, self.replicas, req.deadline,
                              now, cold_load=cold)
            tgt = self.replicas[j]
            tgt.queue.push(req)
            if self._track_dls:
                insort(tgt.dls, req.deadline)

    def run(self, batch: RequestBatch, horizon: Optional[float] = None,
            events=()) -> RunReport:
        """Materialize ``Request`` objects and run the pre-heaped gang
        loop over them; returns a :class:`RunReport` with the same
        conventions as the fast engine."""
        arr = np.ascontiguousarray(batch.arrival, np.float64)
        n = arr.size
        if n and np.any(np.diff(arr) < 0):
            raise ValueError("RequestBatch must be sorted by arrival")
        if horizon is None:
            horizon = float(arr[-1]) + 60.0 if n else 60.0
        reqs = batch.to_requests()
        pos = {r.id: i for i, r in enumerate(reqs)}
        finish = np.full(n, np.nan)
        self._arr = arr
        self._ai = None              # tick-granular λ (no cancels here)
        self._w0 = 0
        lat = self._lat
        bucket_arr = self._bucket_arr
        margin = self.dispatch_margin
        tick = self.tick
        track_dls = self._track_dls
        slack_wake: Dict[int, float] = {}
        busy_wake: Dict[int, float] = {}
        events_heap: list = []
        seq = itertools.count()
        push, pop = heapq.heappush, heapq.heappop
        for req in reqs:                         # arrivals first...
            push(events_heap, (req.arrival, next(seq), "arrival", req))
        t = 0.0
        while t <= horizon:                      # ...then the tick train
            push(events_heap, (t, next(seq), "tick", None))
            t += tick
        for (te, kind, args) in normalize_fleet_events(events):
            push(events_heap, (te, next(seq), "fleet", (kind, args)))
        n_events = 0

        while events_heap:
            t, _, kind, item = pop(events_heap)
            if t > horizon:
                break
            n_events += 1
            if kind == "arrival":
                j = route_request(self.router, self.replicas,
                                  item.deadline, t,
                                  cold_load=self._cold_load(t))
                tgt = self.replicas[j]
                tgt.queue.push(item)
                if track_dls:
                    insort(tgt.dls, item.deadline)
            elif kind == "tick":
                self._drive(t)
                self.core_samples.append((t, self.allocated_cores))
            elif kind == "fleet":
                ev_kind, ev_args = item
                self._fleet_event(ev_kind, ev_args, t)
            # else: "check" — fall through to the dispatch scan
            b_now = self.b
            for r in self.replicas:
                queue = r.queue
                if not len(queue):
                    continue
                if r.ready_at > t or r.busy_until > t:
                    wake_t = (r.ready_at if r.ready_at > r.busy_until
                              else r.busy_until)
                    if busy_wake.get(r.id) != wake_t:
                        busy_wake[r.id] = wake_t
                        push(events_heap, (wake_t, next(seq), "check",
                                           r.id))
                    continue
                while len(queue) and r.busy_until <= t:
                    if len(queue) < b_now:
                        head = queue.peek()
                        l_full = lat[(r.c, self._bucket(b_now))]
                        t_force = head.deadline - l_full - margin
                        if t < t_force:
                            tw = min(t_force, t + tick)
                            if slack_wake.get(r.id) != tw:
                                slack_wake[r.id] = tw
                                push(events_heap, (tw, next(seq), "check",
                                                   r.id))
                            break
                    gang = queue.pop_batch(b_now)
                    m = len(gang)
                    if track_dls:
                        del r.dls[:m]   # pop_batch took the m earliest
                    bucket = int(bucket_arr[m])
                    fin = t + lat[(r.c, bucket)]
                    r.busy_until = fin
                    self.bucket_log.append((t, r.c, bucket, m))
                    for req in gang:
                        req.start_proc = t
                        req.finish = fin
                        finish[pos[req.id]] = fin
                    push(events_heap, (fin, next(seq), "check", r.id))

        self.events_processed = n_events
        return self._report(batch, finish, horizon)
