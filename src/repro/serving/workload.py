"""Workload generation (paper §4): asynchronous requests at a fixed (or
Poisson) rate with per-request communication latency from the bandwidth
trace and a predefined SLO.

Two output shapes, one arrival model:

* ``WorkloadGenerator.generate`` — a list of ``Request`` objects in send
  order (the historical surface; the per-request fields are now computed
  in batched numpy, the Python loop only materializes the dataclasses).
* ``WorkloadGenerator.generate_batch`` / ``RequestBatch`` — the
  struct-of-arrays form used by the million-request fast path
  (``repro.serving.fastpath``) and the scenario registry: every column is
  one numpy array, sorted by server-arrival time, and no ``Request``
  object exists until ``to_requests()`` materializes them for the exact
  event loop.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.slo import Request
from repro.network.latency import comm_latency_many
from repro.network.traces import BandwidthTrace


@dataclass(frozen=True)
class RequestBatch:
    """A workload as parallel numpy columns, sorted by ``arrival``.

    Fields mirror ``repro.core.slo.Request``: ``send`` is the client send
    time, ``arrival = send + comm_latency`` the server-side arrival, and
    ``deadline = arrival - cl + slo`` the absolute EDF deadline
    (computed with the same float expression ``Request.make`` uses, so a
    materialized batch is bit-identical to per-request construction).

    Token columns (the autoregressive extension, ISSUE 3):
    ``prompt_tokens`` to prefill, ``decode_tokens`` to stream after the
    first token, ``tbt_slo`` the per-token deadline.  For token-shaped
    requests ``deadline`` is the TTFT deadline.  The columns default to
    the fixed-work shape (1/0/inf), so every pre-token consumer of a
    batch is unchanged.

    Uncertainty attachment (ISSUE 7): ``decode_dist`` optionally carries
    the workload's declared decode-length distribution
    (``repro.core.uncertainty.LengthDistribution`` — one object for the
    batch, not a column).  ``decode_tokens`` stays the realized ground
    truth the engines serve; the distribution is what the *scheduler* is
    allowed to know.  None (or a point mass) keeps every deterministic
    path bit-identical.
    """
    send: np.ndarray
    arrival: np.ndarray
    comm_latency: np.ndarray
    slo: np.ndarray
    deadline: np.ndarray
    size_kb: np.ndarray
    prompt_tokens: Optional[np.ndarray] = None
    decode_tokens: Optional[np.ndarray] = None
    tbt_slo: Optional[np.ndarray] = None
    decode_dist: Optional[object] = None

    def __post_init__(self):
        n = self.arrival.size
        if self.prompt_tokens is None:
            object.__setattr__(self, "prompt_tokens",
                               np.ones(n, np.int64))
        if self.decode_tokens is None:
            object.__setattr__(self, "decode_tokens",
                               np.zeros(n, np.int64))
        if self.tbt_slo is None:
            object.__setattr__(self, "tbt_slo",
                               np.full(n, np.inf, np.float64))

    @classmethod
    def from_send(cls, send: np.ndarray, comm_latency: np.ndarray,
                  slo, size_kb=200.0, prompt_tokens=None,
                  decode_tokens=None, tbt_slo=None,
                  decode_dist=None) -> "RequestBatch":
        """Build + arrival-sort a batch from send times and comm latencies
        (``slo`` / ``size_kb`` / the token columns may be scalars or
        per-request arrays; token columns default to fixed work)."""
        send = np.asarray(send, np.float64)
        cl = np.asarray(comm_latency, np.float64)
        slo = np.broadcast_to(np.asarray(slo, np.float64), send.shape)
        size_kb = np.broadcast_to(np.asarray(size_kb, np.float64),
                                  send.shape)
        arrival = send + cl
        order = np.argsort(arrival, kind="stable")

        def col(x, dtype, default):
            if x is None:
                return np.full(send.shape, default, dtype)[order].copy()
            return np.broadcast_to(np.asarray(x, dtype),
                                   send.shape)[order].copy()

        pt = col(prompt_tokens, np.int64, 1)
        dt = col(decode_tokens, np.int64, 0)
        tbt = col(tbt_slo, np.float64, np.inf)
        send, cl = send[order], cl[order]
        slo, size_kb = slo[order].copy(), size_kb[order].copy()
        arrival = arrival[order]
        return cls(send=send, arrival=arrival, comm_latency=cl, slo=slo,
                   deadline=arrival - cl + slo, size_kb=size_kb,
                   prompt_tokens=pt, decode_tokens=dt, tbt_slo=tbt,
                   decode_dist=decode_dist)

    def __len__(self) -> int:
        return int(self.arrival.size)

    @property
    def total_tokens(self) -> int:
        """Generated tokens this workload asks for (first + decode)."""
        return int(self.decode_tokens.sum()) + len(self)

    def head(self, k: int) -> "RequestBatch":
        """The first ``k`` arrivals — a true prefix of the scenario (used
        to benchmark baseline runners on a slice of the same workload)."""
        return RequestBatch(send=self.send[:k], arrival=self.arrival[:k],
                            comm_latency=self.comm_latency[:k],
                            slo=self.slo[:k], deadline=self.deadline[:k],
                            size_kb=self.size_kb[:k],
                            prompt_tokens=self.prompt_tokens[:k],
                            decode_tokens=self.decode_tokens[:k],
                            tbt_slo=self.tbt_slo[:k],
                            decode_dist=self.decode_dist)

    def to_requests(self) -> List[Request]:
        """Materialize ``Request`` objects (arrival order) for the exact
        event loop — only sensible at small scale."""
        return [Request(deadline=float(d), arrival=float(a),
                        comm_latency=float(c), slo=float(s),
                        size_kb=float(k), prompt_tokens=int(pt),
                        decode_tokens=int(dt), tbt_slo=float(tb),
                        decode_dist=self.decode_dist)
                for d, a, c, s, k, pt, dt, tb in zip(
                    self.deadline, self.arrival, self.comm_latency,
                    self.slo, self.size_kb, self.prompt_tokens,
                    self.decode_tokens, self.tbt_slo)]


@dataclass
class WorkloadGenerator:
    rps: float = 20.0
    slo: float = 1.0
    size_kb: float = 200.0
    poisson: bool = False
    size_jitter: float = 0.0           # +- fraction of size_kb
    seed: int = 0

    def _columns(self, trace: BandwidthTrace,
                 duration_s: Optional[float] = None):
        """Vectorized arrival model: (send, comm_latency, size) arrays."""
        dur = duration_s or trace.duration
        rng = np.random.default_rng(self.seed)
        if self.poisson:
            n_est = int(self.rps * dur * 1.5) + 10
            gaps = rng.exponential(1.0 / self.rps, size=n_est)
            send_times = np.cumsum(gaps)
            send_times = send_times[send_times < dur]
        else:
            send_times = np.arange(0, dur, 1.0 / self.rps)
        sizes = np.full(send_times.shape, self.size_kb, np.float64)
        if self.size_jitter:
            sizes = self.size_kb * (1.0 + rng.uniform(
                -self.size_jitter, self.size_jitter, size=len(send_times)))
        cl = comm_latency_many(sizes, trace, send_times)
        return send_times, cl, sizes

    def generate(self, trace: BandwidthTrace,
                 duration_s: Optional[float] = None) -> List[Request]:
        """Request objects in send order (the historical surface)."""
        send, cl, sizes = self._columns(trace, duration_s)
        return [Request.make(arrival=float(ts + c), comm_latency=float(c),
                             slo=self.slo, size_kb=float(k))
                for ts, c, k in zip(send, cl, sizes)]

    def generate_batch(self, trace: BandwidthTrace,
                       duration_s: Optional[float] = None) -> RequestBatch:
        """The same workload as an arrival-sorted ``RequestBatch``."""
        send, cl, sizes = self._columns(trace, duration_s)
        return RequestBatch.from_send(send, cl, slo=self.slo, size_kb=sizes)


def lognormal_lengths(rng: np.random.Generator, n: int, median: float,
                      sigma: float, lo: int, hi: int) -> np.ndarray:
    """Bounded log-normal token lengths (int64) — the standard shape of
    LLM prompt/response length distributions.  ``median`` is the
    distribution median (exp(μ)); samples are clipped to [lo, hi]."""
    x = rng.lognormal(mean=np.log(median), sigma=sigma, size=n)
    return np.clip(np.round(x), lo, hi).astype(np.int64)
