"""Workload generator (paper §4): asynchronous requests at a fixed (or
Poisson) rate with per-request communication latency from the bandwidth
trace and a predefined SLO."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.slo import Request
from repro.network.latency import comm_latency
from repro.network.traces import BandwidthTrace


@dataclass
class WorkloadGenerator:
    rps: float = 20.0
    slo: float = 1.0
    size_kb: float = 200.0
    poisson: bool = False
    size_jitter: float = 0.0           # +- fraction of size_kb
    seed: int = 0

    def generate(self, trace: BandwidthTrace,
                 duration_s: Optional[float] = None) -> List[Request]:
        dur = duration_s or trace.duration
        rng = np.random.default_rng(self.seed)
        if self.poisson:
            n_est = int(self.rps * dur * 1.5) + 10
            gaps = rng.exponential(1.0 / self.rps, size=n_est)
            send_times = np.cumsum(gaps)
            send_times = send_times[send_times < dur]
        else:
            send_times = np.arange(0, dur, 1.0 / self.rps)
        reqs = []
        for ts in send_times:
            size = self.size_kb
            if self.size_jitter:
                size *= 1.0 + rng.uniform(-self.size_jitter, self.size_jitter)
            cl = comm_latency(size, trace, ts)
            reqs.append(Request.make(arrival=float(ts + cl),
                                     comm_latency=float(cl),
                                     slo=self.slo, size_kb=float(size)))
        return reqs
