"""Multi-tenant serving: one core pool, many per-tenant SLO solvers.

Sponge (and the PR 4 fleet layer above it) allocates cores to **one**
model; a production cluster serves a zoo of heterogeneous models with
per-tenant SLO distributions competing for one budget — the
horizontal/vertical reconciliation problem of "A Tale of Two Scales"
(Razavi et al. 2024) taken across tenants.  This module is that layer:

* :class:`TenantSpec` — one tenant: a cost model (fixed-work
  :class:`~repro.core.perf_model.PerfModel` or a token
  :class:`~repro.core.cost_model.TokenCostModel` via its fixed-work
  surface), its own workload (:class:`RequestBatch` with per-request
  dynamic SLOs), a rate prior, and the pool-facing knobs (``weight``
  for fair-share, ``priority`` for preemption order).
* :class:`TenantPool` — owns the fixed core ``budget`` and the
  per-tenant caps.  Every reallocation round it prices a core transfer
  by **marginal SLO value**: each tenant's
  :meth:`~repro.core.solver.JointSolverTable.min_violations` frontier
  gives ``V(cap)`` (fewest predicted EDF violations achievable under
  the cap), and the pool compares the receiver's ``gain = V(cap) -
  V(cap + step)`` against the donor's ``loss = V(cap - step) - V(cap)``
  under a pluggable policy (``greedy-marginal`` / ``fair-share`` /
  ``priority``).  A proposed swap must persist ``swap_patience``
  consecutive rounds before it executes (the same hysteresis idea as
  the fleet scaler's ``down_patience``), and the losing tenant sheds
  cores through the PR 4 drain-before-release machinery — its next
  capped solve emits a smaller fleet, retiring replicas re-route their
  queues and finish in-flight work before the cores actually free.
* Two engines, one semantics: :class:`TenantFastRunner` interleaves
  every tenant's struct-of-arrays request stream in **one** event loop
  (per-tenant arrival cursors, one global tick train, one dynamic-event
  heap; each tenant keeps its own EDF substrate — a
  :class:`~repro.serving.fleet.FleetFastSimRunner` fleet under a capped
  :class:`~repro.serving.fleet.FleetSpongeScaler`), and
  :class:`TenantExactRunner` is the pre-heaped oracle (every arrival
  and tick heap-pushed up front with ``(t, seq)`` keys, ``Request``
  objects, the :class:`~repro.serving.fleet.FleetExactRunner` gang
  dispatch) the fast engine is held decision-identical to
  (``tests/test_tenancy.py``, every ``mixed-zoo`` scenario × policy).

Tie order at equal event times: tenant arrivals (tenant index
ascending), then the pool tick (reallocate, then drive every tenant's
scaler in index order), then dynamic events — the exact engine's
pre-heap sequence numbers produce the same order by construction.

Caps are a **planning** constraint, not an instantaneous one: a tenant
whose cap just dropped keeps its cores until the drain completes (the
hysteresis pin can hold ``n`` above the capped solve for
``down_patience`` ticks), so ``sum(caps) <= budget`` is the invariant
the pool maintains while allocated cores converge to it from above.
"""
from __future__ import annotations

import heapq
import itertools
from bisect import insort
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.cost_model import CostModel
from repro.core.perf_model import PerfModel
from repro.core.solver import (DEFAULT_B, DEFAULT_C, DEFAULT_N,
                               JointSolverTable)
from repro.serving.api import RunReport, build_array_report
from repro.serving.fleet import (FleetExactRunner, FleetFastSimRunner,
                                 FleetSpongeScaler, route_request)
from repro.serving.workload import RequestBatch

POOL_POLICIES = ("priority", "fair-share", "greedy-marginal")
INF = float("inf")


@dataclass(frozen=True)
class TenantSpec:
    """One tenant of the shared pool.

    ``cost`` is anything the joint solver understands — a fixed-work
    :class:`~repro.core.perf_model.PerfModel` or a
    :class:`~repro.core.cost_model.TokenCostModel` (whose batch-latency
    surface prices a batch of mean-shaped autoregressive requests, so a
    chat tenant shares the pool with vision tenants at request
    granularity).  ``batch`` is the tenant's own workload;
    ``expected_rps`` seeds its λ window.  ``weight`` sets the
    fair-share target, ``priority`` the preemption order (lower =
    more important).  ``n0`` replicas deploy at t=0; the per-tenant
    ``(c_set, b_set, n_set)`` grids bound its joint solver.
    """
    name: str
    cost: Union[PerfModel, CostModel]
    batch: RequestBatch
    expected_rps: float
    weight: float = 1.0
    priority: int = 0
    n0: int = 2
    c_set: Sequence[int] = DEFAULT_C
    b_set: Sequence[int] = DEFAULT_B
    n_set: Sequence[int] = DEFAULT_N


class _PoolPolicyView:
    """Aggregate-report shim: the pool has no single decision stream
    (each tenant's scaler keeps its own), so the pool-level
    :class:`~repro.serving.api.RunReport` carries only the policy name."""

    def __init__(self, name: str):
        self.name = name
        self.decisions = None


class TenantPool:
    """The fixed core budget and its division into per-tenant caps.

    Initial caps are the largest-remainder proportional split of
    ``budget`` by tenant ``weight`` (floored at ``min_cores``), unless
    ``initial_caps`` overrides them.  :meth:`reallocate` runs one
    swap round: compute every tenant's marginal profile
    (:meth:`marginal_profile`), let the policy propose at most one
    ``(donor, receiver, amount)`` transfer, and execute it only after
    the **same** donor/receiver pair has been proposed for
    ``swap_patience`` consecutive rounds (swap hysteresis — transient
    load blips don't churn cores).  ``sum(caps) <= budget`` always;
    ``cap_log`` and ``swaps`` record the trajectory for tests and the
    benchmark.
    """

    def __init__(self, specs: Sequence[TenantSpec], *, budget: int = 128,
                 policy: str = "greedy-marginal", swap_step: int = 16,
                 swap_patience: int = 2, min_cores: int = 4,
                 price_window: float = 1.0, min_gain: float = 2.0,
                 initial_caps: Optional[Sequence[int]] = None):
        if policy not in POOL_POLICIES:
            raise KeyError(f"unknown pool policy {policy!r}; "
                           f"known: {POOL_POLICIES}")
        self.specs = list(specs)
        k = len(self.specs)
        if not k:
            raise ValueError("TenantPool needs at least one tenant")
        if budget < k * min_cores:
            raise ValueError(f"budget {budget} cannot floor {k} tenants "
                             f"at {min_cores} cores each")
        self.budget = int(budget)
        self.policy = policy
        self.swap_step = int(swap_step)
        self.swap_patience = int(swap_patience)
        self.min_cores = int(min_cores)
        self.price_window = float(price_window)
        self.min_gain = float(min_gain)
        self._targets = self._proportional()
        if initial_caps is not None:
            caps = [int(c) for c in initial_caps]
            if len(caps) != k or any(c < min_cores for c in caps) \
                    or sum(caps) > budget:
                raise ValueError(f"bad initial_caps {caps!r} for "
                                 f"budget {budget}")
            self.caps = caps
        else:
            self.caps = list(self._targets)
        self._tables: List[Optional[JointSolverTable]] = [None] * k
        self.cap_log: List[tuple] = []
        self.swaps: List[tuple] = []
        self._streak = 0
        self._streak_key: Optional[tuple] = None

    # -- allocation arithmetic ---------------------------------------------
    def _proportional(self) -> List[int]:
        """Largest-remainder split of the budget by tenant weight,
        floored at ``min_cores`` (deterministic at every tie)."""
        w = np.asarray([max(float(s.weight), 0.0) for s in self.specs])
        if w.sum() <= 0:
            w = np.ones_like(w)
        raw = self.budget * w / w.sum()
        caps = np.floor(raw).astype(int)
        rem_order = sorted(range(len(caps)),
                           key=lambda i: (-(raw[i] - caps[i]), i))
        for i in rem_order[:self.budget - int(caps.sum())]:
            caps[i] += 1
        caps = np.maximum(caps, self.min_cores)
        while caps.sum() > self.budget:          # flooring overshot
            i = int(np.argmax(caps))             # ties -> lowest index
            assert caps[i] > self.min_cores
            caps[i] -= 1
        return [int(c) for c in caps]

    def bind_table(self, k: int, table: JointSolverTable) -> None:
        """Attach tenant ``k``'s solver table (its feasibility frontier
        is what :meth:`marginal_profile` differentiates)."""
        self._tables[k] = table

    # -- marginal SLO value ------------------------------------------------
    def _value(self, table: JointSolverTable, rem: np.ndarray, lam: float,
               iw: float, cap: int) -> float:
        """``V(cap)``: predicted violations for tenant state
        ``(rem, lam, iw)`` under a core cap.  Two terms: the backlog
        term (:meth:`JointSolverTable.min_violations` — queued requests
        no capped config can save) plus the **overflow** term
        ``max(0, λ - max_rate(cap)) * price_window`` — arrivals the
        capped frontier cannot absorb over the next pricing window.
        The overflow term is what keeps the marginal signal alive
        through a sustained overload: once a backlog is doomed, extra
        cores stop moving the backlog term, but they keep raising the
        sustainable-rate ceiling until λ fits.
        """
        over = max(0.0, lam - table.max_rate(cap)) * self.price_window
        if rem.size == 0:
            return over
        return table.min_violations(rem, lam, initial_wait=iw,
                                    max_cores=cap) + over

    def marginal_profile(self, k: int, snapshot) -> dict:
        """Price tenant ``k``'s next core transfer from a queue snapshot.

        ``snapshot`` is ``(remaining, lam, initial_wait)`` — the same
        headroom-adjusted budgets the tenant's scaler would solve with.
        Returns ``{"v", "gain", "loss"}``: ``v = V(cap)`` predicted
        violations at the current cap (backlog + λ-overflow, see
        :meth:`_value`), ``gain = V(cap) - V(cap+step)`` the violations
        one step of cores would remove, and ``loss = V(cap-step) -
        V(cap)`` (clamped at 0 — the violation grid is a prediction and
        may wobble non-monotonically) the violations donating a step
        would cost.  ``loss`` is ``None`` when the donation would
        breach ``min_cores`` — the tenant cannot donate.  A tenant at
        ``V = 0`` has nothing to gain and skips the ``cap+step`` solve.
        """
        rem, lam, iw = snapshot
        cap = self.caps[k]
        step = self.swap_step
        can_donate = cap - step >= self.min_cores
        rem = np.asarray(rem, np.float64)
        table = self._tables[k]
        assert table is not None, f"tenant {k} has no bound solver table"
        v = self._value(table, rem, lam, iw, cap)
        gain = 0.0
        if v > 0:
            gain = max(0.0, v - self._value(table, rem, lam, iw,
                                            cap + step))
        loss = None
        if can_donate:
            loss = max(0.0, self._value(table, rem, lam, iw,
                                        cap - step) - v)
        return {"v": v, "gain": gain, "loss": loss}

    # -- the swap round ----------------------------------------------------
    def reallocate(self, now: float, snapshots: Sequence) -> List[dict]:
        """One swap round at time ``now`` over per-tenant snapshots.

        Computes every tenant's marginal profile, asks the policy for a
        proposal, applies swap hysteresis, executes at most one
        transfer, and logs ``caps`` — returns the profiles (the engines
        ignore them; tests and the benchmark read the logs).
        """
        profiles = [self.marginal_profile(k, s)
                    for k, s in enumerate(snapshots)]
        prop = self._propose(profiles)
        if prop is None:
            self._streak = 0
            self._streak_key = None
        else:
            key = prop[:2]
            self._streak = self._streak + 1 if key == self._streak_key \
                else 1
            self._streak_key = key
            if self._streak >= self.swap_patience:
                donor, recv, amt = prop
                self.caps[donor] -= amt
                self.caps[recv] += amt
                self.swaps.append((now, donor, recv, amt))
                self._streak = 0
                self._streak_key = None
        assert sum(self.caps) <= self.budget, (self.caps, self.budget)
        self.cap_log.append((now, tuple(self.caps)))
        return profiles

    def _propose(self, profiles: List[dict]) -> Optional[tuple]:
        """Policy dispatch: at most one ``(donor, receiver, amount)``."""
        if self.policy == "greedy-marginal":
            return self._propose_greedy(profiles)
        if self.policy == "fair-share":
            return self._propose_fair()
        return self._propose_priority(profiles)

    def _propose_greedy(self, profiles: List[dict]) -> Optional[tuple]:
        """Highest marginal gain receives; the donor losing the least
        gives (ties: deepest cap, then index); swap iff gain > loss and
        gain clears ``min_gain`` (prediction-noise gains of a request
        or two must not churn cores)."""
        recv, best_gain = None, 0.0
        for k, p in enumerate(profiles):
            if p["gain"] > best_gain:
                recv, best_gain = k, p["gain"]
        if recv is None or best_gain < self.min_gain:
            return None
        donor, best_key = None, None
        for k, p in enumerate(profiles):
            if k == recv or p["loss"] is None:
                continue
            key = (p["loss"], -self.caps[k], k)
            if best_key is None or key < best_key:
                donor, best_key = k, key
        if donor is None or best_gain <= profiles[donor]["loss"]:
            return None
        return (donor, recv, self.swap_step)

    def _propose_fair(self) -> Optional[tuple]:
        """Steer caps to the weight-proportional targets: the deepest
        deficit receives from the deepest surplus, transfer sized so the
        pair never overshoots — proposals cease exactly at the target
        (convergence is property-tested)."""
        deficit = [self._targets[k] - self.caps[k]
                   for k in range(len(self.caps))]
        recv = max(range(len(deficit)), key=lambda k: (deficit[k], -k))
        donor = min(range(len(deficit)), key=lambda k: (deficit[k], k))
        if deficit[recv] <= 0 or deficit[donor] >= 0:
            return None
        amt = min(self.swap_step, deficit[recv], -deficit[donor])
        return (donor, recv, amt)

    def _propose_priority(self, profiles: List[dict]) -> Optional[tuple]:
        """Strict preemption: the most important violating tenant
        (lowest ``priority`` number) takes a step from the least
        important tenant that can still donate — donor loss is ignored
        by design, so a low-priority tenant under overload is starved
        down to ``min_cores`` and simply reports its violations (the
        floor is what makes starvation livelock-free)."""
        specs = self.specs
        order = sorted(range(len(specs)),
                       key=lambda k: (specs[k].priority, k))
        recv = next((k for k in order
                     if profiles[k]["v"] > 0
                     and profiles[k]["gain"] >= self.min_gain),
                    None)
        if recv is None:
            return None
        donors = [k for k, p in enumerate(profiles)
                  if p["loss"] is not None
                  and specs[k].priority > specs[recv].priority]
        if not donors:
            return None
        donor = min(donors, key=lambda k: (-specs[k].priority,
                                           -self.caps[k], k))
        return (donor, recv, self.swap_step)


# --------------------------------------------------------------------------
# the two multi-tenant engines
# --------------------------------------------------------------------------
class _TenantRunnerBase:
    """Config + semantics shared verbatim by both tenant engines.

    Each tenant gets a private fleet substrate (an instance of the
    engine-matched fleet runner class, never driven through its own
    ``run``) under a capped :class:`FleetSpongeScaler`; the tenant
    loop owns the event ordering, the pool tick (reallocate + drive
    every scaler) and reporting.  Only the event-loop organization
    differs per subclass — the exact pre-heaped loop is the oracle the
    interleaved fast loop is held to.
    """

    backend_name = "tenant-pool"
    _sub_cls: type = None

    def __init__(self, specs: Sequence[TenantSpec], *, budget: int = 128,
                 policy: str = "greedy-marginal",
                 realloc_interval: float = 1.0, swap_step: int = 16,
                 swap_patience: int = 2, min_cores: int = 4,
                 min_gain: float = 2.0,
                 tick: float = 0.5, router: str = "least-loaded",
                 budget_quantum: float = 0.0, lam_quantum: float = 0.0,
                 down_patience: int = 5, marginal_cap: int = 2048,
                 initial_caps: Optional[Sequence[int]] = None):
        self.specs = list(specs)
        self.pool = TenantPool(self.specs, budget=budget, policy=policy,
                               swap_step=swap_step,
                               swap_patience=swap_patience,
                               min_cores=min_cores,
                               price_window=realloc_interval,
                               min_gain=min_gain,
                               initial_caps=initial_caps)
        self.tick = float(tick)
        self.realloc_interval = float(realloc_interval)
        self.marginal_cap = int(marginal_cap)
        self._next_realloc = 0.0
        self.subs = []
        for k, (spec, cap) in enumerate(zip(self.specs, self.pool.caps)):
            scaler = FleetSpongeScaler(
                spec.cost, name=f"sponge-tenant-{spec.name}",
                c_set=tuple(spec.c_set), b_set=tuple(spec.b_set),
                n_set=tuple(spec.n_set), adaptation_interval=self.tick,
                budget_quantum=budget_quantum, lam_quantum=lam_quantum,
                down_patience=down_patience, core_cap=cap)
            n0 = max(1, int(spec.n0))
            # deploy the largest core count whose n0-replica fleet fits
            # the tenant's initial cap
            fits = [c for c in sorted(spec.c_set) if n0 * c <= cap]
            c0 = max(fits) if fits else min(spec.c_set)
            sub = self._sub_cls(scaler, spec.cost, spec.c_set, spec.b_set,
                                n0=n0, c0=c0, tick=self.tick,
                                prior_rps=spec.expected_rps, router=router)
            self.pool.bind_table(k, scaler.memo.table)
            self.subs.append(sub)
        self.core_timeline: List[tuple] = []
        self.events_processed = 0
        self.tenant_reports: List[RunReport] = []

    # -- pool control ------------------------------------------------------
    def _snapshot(self, sub, t: float):
        """Tenant queue snapshot in the scaler's own solve coordinates
        (headroom-adjusted budgets, λ with provisioning margin), so the
        marginal prices and the capped solves read the same frontier.
        ``marginal_cap`` bounds the grid work per round; the λ window
        read is idempotent at a fixed ``(now, arrivals)`` so the drive
        that follows sees the identical estimate."""
        sc = sub.policy
        reps = sub.replicas
        iw = min(max(r.busy_until - t, 0.0) for r in reps)
        rem = np.sort(np.concatenate(
            [r.queue.remaining_array(t) for r in reps]))
        rem = np.maximum(rem - sc.headroom, 0.0)[:self.marginal_cap]
        lam = sub._rate(t) * sc.lam_headroom
        return (rem, lam, iw)

    def _pool_tick(self, t: float) -> None:
        """The tick handler both engines share: reallocate when due
        (push the new caps into every scaler), then drive each tenant's
        scaler in index order and sample the core timelines."""
        if t + 1e-12 >= self._next_realloc:
            self._next_realloc = t + self.realloc_interval
            snaps = [self._snapshot(sub, t) for sub in self.subs]
            self.pool.reallocate(t, snaps)
            for sub, cap in zip(self.subs, self.pool.caps):
                sub.policy.core_cap = cap
        total = 0
        for sub in self.subs:
            sub._drive(t)
            sub.core_samples.append((t, sub.allocated_cores))
            total += sub.allocated_cores
        self.core_timeline.append((t, total))

    # -- reporting ---------------------------------------------------------
    def _default_horizon(self) -> float:
        last = max((float(s.batch.arrival[-1]) for s in self.specs
                    if len(s.batch)), default=0.0)
        return last + 60.0

    def _finalize(self, finishes: List[np.ndarray],
                  horizon: float) -> RunReport:
        """Per-tenant reports through each substrate's own
        ``_report`` (the shared fleet aggregation), then the pool-level
        aggregate over the concatenated columns, every replica of every
        tenant, and the pool core timeline."""
        self.tenant_reports = [
            sub._report(spec.batch, fin, horizon)
            for spec, sub, fin in zip(self.specs, self.subs, finishes)]
        batches = [s.batch for s in self.specs]
        merged = RequestBatch(
            send=np.concatenate([b.send for b in batches]),
            arrival=np.concatenate([b.arrival for b in batches]),
            comm_latency=np.concatenate([b.comm_latency for b in batches]),
            slo=np.concatenate([b.slo for b in batches]),
            deadline=np.concatenate([b.deadline for b in batches]),
            size_kb=np.concatenate([b.size_kb for b in batches]))
        slots = [r for sub in self.subs for r in sub.replicas + sub.dead]
        buckets = sorted(itertools.chain.from_iterable(
            sub.bucket_log for sub in self.subs))
        view = _PoolPolicyView(f"tenant-pool-{self.pool.policy}")
        return build_array_report(view, self.backend_name, merged,
                                  np.concatenate(finishes), horizon,
                                  slots, self.core_timeline, buckets)


class TenantFastRunner(_TenantRunnerBase):
    """The interleaved struct-of-arrays engine — the ≥200k-request path.

    One event loop over per-tenant arrival cursors (ties resolve to the
    lowest tenant index), one global tick train, and one dynamic-event
    heap keyed ``(t, seq, tenant, replica)`` with per-(tenant, replica)
    deduplicated wake-ups; each event is followed by the fleet fast
    path's slack-aware EDF dispatch scan over every tenant's replicas
    in index order.  Decision-identical to :class:`TenantExactRunner`
    (``tests/test_tenancy.py``).
    """

    backend_name = "tenant-fast"
    _sub_cls = FleetFastSimRunner

    def run(self, horizon: Optional[float] = None) -> RunReport:
        """Drain every tenant's workload to the horizon; returns the
        pool-level aggregate (per-tenant reports on
        ``self.tenant_reports``)."""
        subs = self.subs
        K = len(subs)
        arrs = [np.ascontiguousarray(s.batch.arrival, np.float64)
                for s in self.specs]
        dls = [np.ascontiguousarray(s.batch.deadline, np.float64)
               for s in self.specs]
        finishes = [np.full(a.size, np.nan) for a in arrs]
        for sub, arr in zip(subs, arrs):
            # tick-granular λ: the sorted arrival column replaces the
            # per-arrival counter (closed world, no cancels)
            sub._arr, sub._ai, sub._w0 = arr, None, 0
        if horizon is None:
            horizon = self._default_horizon()
        ptrs = [0] * K
        next_tick = 0.0
        events: list = []
        seq = itertools.count()
        busy_wake: Dict[tuple, float] = {}
        slack_wake: Dict[tuple, float] = {}
        tick = self.tick
        pop, push = heapq.heappop, heapq.heappush
        n_events = 0
        while True:
            ta, ka = INF, -1
            for k in range(K):
                p = ptrs[k]
                if p < arrs[k].size and arrs[k][p] < ta:
                    ta, ka = arrs[k][p], k
            tt = next_tick
            td = events[0][0] if events else INF
            if ta <= tt and ta <= td:
                et, kind = ta, 0
            elif tt <= td:
                et, kind = tt, 1
            else:
                et, kind = td, 2
            if et == INF or et > horizon:
                break
            n_events += 1
            if kind == 0:                        # arrival: route + enqueue
                sub = subs[ka]
                h = ptrs[ka]
                ptrs[ka] += 1
                d = dls[ka][h]
                j = route_request(sub.router, sub.replicas, d, et,
                                  cold_load=sub._cold_load(et))
                tgt = sub.replicas[j]
                tgt.queue.push(d, h)
                if sub._track_dls:
                    insort(tgt.dls, d)
            elif kind == 1:                      # pool tick
                next_tick += tick
                self._pool_tick(et)
            else:                                # completion / wake-up
                pop(events)
            self._dispatch(et, finishes, events, seq, busy_wake,
                           slack_wake)
        self.events_processed = n_events
        return self._finalize(finishes, horizon)

    # spongelint: inline-of repro.serving.session.FleetSession._dispatch pin=3453d8c8e7ff
    def _dispatch(self, t: float, finishes, events, seq, busy_wake,
                  slack_wake) -> None:
        """Per-replica slack-aware EDF dispatch (the fleet fast-path
        rules, verbatim) over every tenant in index order."""
        tick = self.tick
        push = heapq.heappush
        for k, sub in enumerate(self.subs):
            b_now = sub.b
            lat = sub._lat
            bucket_arr = sub._bucket_arr
            margin = sub.dispatch_margin
            track_dls = sub._track_dls
            fin_arr = finishes[k]
            for rep in sub.replicas:
                q = rep.queue._heap
                if not q:
                    continue
                key = (k, rep.id)
                if rep.ready_at > t or rep.busy_until > t:
                    wake_t = (rep.ready_at
                              if rep.ready_at > rep.busy_until
                              else rep.busy_until)
                    if busy_wake.get(key) != wake_t:
                        busy_wake[key] = wake_t
                        push(events, (wake_t, next(seq), k, rep.id))
                    continue
                live = rep.queue._live
                while q and rep.busy_until <= t:
                    if len(live) < b_now:
                        head_dl = q[0][0]
                        l_full = lat[(rep.c, sub._bucket(b_now))]
                        t_force = head_dl - l_full - margin
                        if t < t_force:
                            tw = min(t_force, t + tick)
                            if slack_wake.get(key) != tw:
                                slack_wake[key] = tw
                                push(events, (tw, next(seq), k, rep.id))
                            break
                    idxs = rep.queue.pop_batch(b_now)
                    m = len(idxs)
                    if track_dls:
                        del rep.dls[:m]   # pop_batch took the m earliest
                    bucket = int(bucket_arr[m])
                    fin = t + lat[(rep.c, bucket)]
                    rep.busy_until = fin
                    sub.bucket_log.append((t, rep.c, bucket, m))
                    for i in idxs:
                        fin_arr[i] = fin
                    push(events, (fin, next(seq), k, rep.id))


class TenantExactRunner(_TenantRunnerBase):
    """The pre-heaped multi-tenant oracle.

    Organized like :class:`~repro.serving.fleet.FleetExactRunner`:
    every tenant's arrivals (tenant-major, so equal-time ties resolve
    to the lowest tenant index) and the tick train are heap-pushed up
    front with sequence numbers, requests are real ``Request`` objects
    on per-replica object queues, and each event triggers the full
    gang dispatch scan over every tenant's pool.  Slow and auditable —
    the decision-identity oracle ``tests/test_tenancy.py`` holds
    :class:`TenantFastRunner` to.
    """

    backend_name = "tenant-exact"
    _sub_cls = FleetExactRunner

    def run(self, horizon: Optional[float] = None) -> RunReport:
        """Materialize every tenant's ``Request`` objects and run the
        pre-heaped gang loop; same reporting as the fast engine."""
        subs = self.subs
        arrs = [np.ascontiguousarray(s.batch.arrival, np.float64)
                for s in self.specs]
        finishes = [np.full(a.size, np.nan) for a in arrs]
        for sub, arr in zip(subs, arrs):
            sub._arr, sub._ai, sub._w0 = arr, None, 0
        if horizon is None:
            horizon = self._default_horizon()
        reqs = [s.batch.to_requests() for s in self.specs]
        pos = [{r.id: i for i, r in enumerate(rs)} for rs in reqs]
        events_heap: list = []
        seq = itertools.count()
        push, pop = heapq.heappush, heapq.heappop
        for k, rs in enumerate(reqs):            # arrivals first...
            for req in rs:
                push(events_heap,
                     (req.arrival, next(seq), 0, (k, req)))
        t = 0.0
        while t <= horizon:                      # ...then the tick train
            push(events_heap, (t, next(seq), 1, None))
            t += self.tick
        busy_wake: Dict[tuple, float] = {}
        slack_wake: Dict[tuple, float] = {}
        n_events = 0
        while events_heap:
            t, _, kind, item = pop(events_heap)
            if t > horizon:
                break
            n_events += 1
            if kind == 0:                        # arrival
                k, req = item
                sub = subs[k]
                j = route_request(sub.router, sub.replicas, req.deadline,
                                  t, cold_load=sub._cold_load(t))
                tgt = sub.replicas[j]
                tgt.queue.push(req)
                if sub._track_dls:
                    insort(tgt.dls, req.deadline)
            elif kind == 1:                      # pool tick
                self._pool_tick(t)
            # else kind == 2: "check" — fall through to the dispatch scan
            for k, sub in enumerate(subs):
                b_now = sub.b
                lat = sub._lat
                bucket_arr = sub._bucket_arr
                margin = sub.dispatch_margin
                track_dls = sub._track_dls
                fin_arr = finishes[k]
                pos_k = pos[k]
                for rep in sub.replicas:
                    queue = rep.queue
                    if not len(queue):
                        continue
                    key = (k, rep.id)
                    if rep.ready_at > t or rep.busy_until > t:
                        wake_t = (rep.ready_at
                                  if rep.ready_at > rep.busy_until
                                  else rep.busy_until)
                        if busy_wake.get(key) != wake_t:
                            busy_wake[key] = wake_t
                            push(events_heap, (wake_t, next(seq), 2, key))
                        continue
                    while len(queue) and rep.busy_until <= t:
                        if len(queue) < b_now:
                            head = queue.peek()
                            l_full = lat[(rep.c, sub._bucket(b_now))]
                            t_force = head.deadline - l_full - margin
                            if t < t_force:
                                tw = min(t_force, t + self.tick)
                                if slack_wake.get(key) != tw:
                                    slack_wake[key] = tw
                                    push(events_heap,
                                         (tw, next(seq), 2, key))
                                break
                        gang = queue.pop_batch(b_now)
                        m = len(gang)
                        if track_dls:
                            del rep.dls[:m]
                        bucket = int(bucket_arr[m])
                        fin = t + lat[(rep.c, bucket)]
                        rep.busy_until = fin
                        sub.bucket_log.append((t, rep.c, bucket, m))
                        for req in gang:
                            req.start_proc = t
                            req.finish = fin
                            fin_arr[pos_k[req.id]] = fin
                        push(events_heap, (fin, next(seq), 2, key))
        self.events_processed = n_events
        return self._finalize(finishes, horizon)
