"""Real-kernel autoregressive serving: the ``TokenJaxBackend`` (ISSUE 3).

This is the live counterpart of ``repro.serving.api.TokenSimBackend``:
a dispatched gang is executed phase-aware on **real jitted
executables** —

* prefill runs the model's prompt pass with attention routed through
  the Pallas ``swa_prefill`` kernel (``cfg.use_pallas_prefill``; full
  causal attention is the window >= S special case), producing every
  request's first token *and* the gang KV cache;
* each decode step runs the model's single-token pass with attention
  routed through the Pallas ``decode_attention`` flash-decode kernel
  (``cfg.use_pallas_decode``), one token per running slot.

The gang cache's batch axis is the **KV-cache slot pool**: slot i holds
request i's cache lines; requests *leave* the pool between decode steps
by masking (their slots keep stepping as padding — the real cost an
engine pays without cache compaction) and the gang ends when the
longest stream finishes.  Everything is jitted per ``(c, b)`` exactly
like the fixed-work executable table, so applying a Decision stays an
O(1) dictionary flip (the in-place vertical scaling mechanism; on the
TPU target each entry is the same step compiled on a c-chip submesh —
on this CPU container the kernels run in interpret mode and every c
shares the computation, so vertical scaling affects scheduling only).

``calibrate_token_fns`` profiles the two tables once and fits a
``TokenCostModel``, which closes the loop: the solver plans token
compositions on the same cost surface the kernels exhibit.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.cost_model import TokenCostModel
from repro.core.scaler import TokenSpongeScaler
from repro.core.slo import Request
from repro.core.vertical import TimedExecutor
from repro.serving.api import ScenarioRunner, _PooledBackend


def build_token_step_fns(model, params, c_set: Sequence[int],
                         b_set: Sequence[int], prompt_len: int,
                         max_decode: int = 8):
    """Two executable tables for phase-aware LLM serving.

    ``prefill_fns[(c, b)](tokens)`` maps (b, prompt_len) int32 prompts to
    ``(first_token (b,), gang_cache)``; ``decode_fns[(c, b)](cache, tok)``
    advances every slot one token.  The cache holds
    ``prompt_len + max_decode + 1`` positions per slot.  On TPU each
    (c, b) entry would be compiled on its c-chip submesh; on CPU the same
    jitted fn backs every c (see the module docstring).
    """
    import jax
    import jax.numpy as jnp
    cache_len = prompt_len + max_decode + 1
    vocab = model.cfg.vocab_size

    def make_prefill(b):
        def fn(tokens):
            logits, cache = model.prefill(params, {"tokens": tokens},
                                          cache_len=cache_len)
            first = jnp.argmax(logits[:, :vocab], axis=-1).astype(jnp.int32)
            return first, cache
        return jax.jit(fn)

    def make_decode(b):
        def fn(cache, tok):
            lg, cache = model.decode_step(params, cache, tok[:, None])
            nxt = jnp.argmax(lg[:, :vocab], axis=-1).astype(jnp.int32)
            return nxt, cache
        return jax.jit(fn)

    prefill_fns, decode_fns = {}, {}
    for b in b_set:
        pf, df = make_prefill(b), make_decode(b)
        for c in c_set:
            prefill_fns[(c, b)] = pf
            decode_fns[(c, b)] = df
    return prefill_fns, decode_fns


def pad_prompts(payloads: List[np.ndarray], b: int,
                prompt_len: int) -> np.ndarray:
    """Stack prompt-token payloads into the (b, prompt_len) bucket:
    each prompt is right-padded (zeros) or truncated to ``prompt_len``,
    the batch axis padded by repeating the last entry."""
    rows = []
    for p in payloads:
        p = np.zeros(prompt_len, np.int32) if p is None \
            else np.asarray(p, np.int32).ravel()[:prompt_len]
        if p.size < prompt_len:
            p = np.pad(p, (0, prompt_len - p.size))
        rows.append(p)
    rows += [rows[-1]] * (b - len(rows))
    return np.stack(rows)


def warmup_token_fns(prefill_fns: Dict, decode_fns: Dict,
                     prompt_len: int) -> None:
    """Compile every (c, b) entry of both tables (deploy-time pass —
    this is what makes the later resize in-place).  Entries sharing one
    jitted function (every c maps to the same fn per b on this CPU
    container) are compiled once, not once per c."""
    seen: set[int] = set()
    for (c, b), pf in prefill_fns.items():
        if id(pf) in seen:
            continue
        seen.add(id(pf))
        tokens = np.ones((b, prompt_len), np.int32)
        first, cache = pf(tokens)
        decode_fns[(c, b)](cache, first)


def calibrate_token_fns(prefill_fns: Dict, decode_fns: Dict,
                        prompt_len: int, mean_prompt: float = 0.0,
                        mean_decode: float = 4.0) -> TokenCostModel:
    """Profile both tables once per (c, b) and fit the token cost model.

    Prefill samples are (b·prompt_len tokens, c, wall); decode samples
    are (b slots, c, wall) — the measured surface the solver then plans
    on (run :func:`warmup_token_fns` first so compiles are excluded).
    """
    import jax
    pre_samples, dec_samples = [], []
    for (c, b), pf in prefill_fns.items():
        tokens = np.ones((b, prompt_len), np.int32)
        t0 = time.perf_counter()
        first, cache = jax.block_until_ready(pf(tokens))
        pre_samples.append((float(b * prompt_len), float(c),
                            time.perf_counter() - t0))
        t0 = time.perf_counter()
        jax.block_until_ready(decode_fns[(c, b)](cache, first))
        dec_samples.append((float(b), float(c), time.perf_counter() - t0))
    return TokenCostModel.fit(
        pre_samples, dec_samples,
        mean_prompt=mean_prompt or float(prompt_len),
        mean_decode=mean_decode)


class TokenJaxBackend(_PooledBackend):
    """Continuous-batching execution over real Pallas-kernel executables.

    See the module docstring for the execution model (phase-aware gangs
    over a KV-cache slot pool).  ``clock`` follows ``JaxBackend``:
    ``"measured"`` advances virtual time by wall latency per phase,
    ``"modeled"`` by the calibrated :class:`TokenCostModel` (kernels
    still execute and produce real tokens).  Per-request lifecycle
    (``first_token`` / ``finish`` / ``tbt_violations``) is written here;
    generated token ids are collected in ``generated[request.id]``.
    """

    name = "token-jax"

    def __init__(self, prefill_fns: Dict[tuple[int, int], Callable],
                 decode_fns: Dict[tuple[int, int], Callable],
                 cost: TokenCostModel, prompt_len: int,
                 max_decode: int = 8, clock: str = "measured",
                 c0: Optional[int] = None, resize_penalty: float = 0.0):
        assert clock in ("measured", "modeled"), clock
        self.pre_table = TimedExecutor(prefill_fns)
        self.dec_table = TimedExecutor(decode_fns)
        self.cost = cost
        self.prompt_len = prompt_len
        self.max_decode = max_decode
        self.clock = clock
        self.generated: Dict[int, List[int]] = {}
        self.tokens_served = 0
        self._payloads: Dict[int, Any] = {}
        c_set = sorted({c for c, _ in prefill_fns})
        b_set = sorted({b for _, b in prefill_fns})
        super().__init__(cost, c_set, b_set, c0=c0 or max(c_set),
                         resize_penalty=resize_penalty)

    def warmup(self) -> None:
        """Compile every (c, b) prefill + decode entry."""
        warmup_token_fns(self.pre_table.fns, self.dec_table.fns,
                         self.prompt_len)

    def on_submit(self, req: Request, payload: Any) -> None:
        self._payloads[req.id] = payload

    def execute(self, batch: List[Request], c: int, b: int,
                now: float) -> float:
        tokens = pad_prompts([self._payloads.pop(r.id, None)
                              for r in batch], b, self.prompt_len)
        first, cache = self.pre_table(c, b, tokens)
        first = np.asarray(first)
        dt = self.pre_table.calls[-1][3]
        if self.clock == "modeled":
            total_prompt = sum(r.prompt_tokens for r in batch)
            dt = float(self.cost.prefill_latency(c, total_prompt))
        t = now + dt
        remaining = np.zeros(b, np.int64)
        for i, r in enumerate(batch):
            r.first_token = t
            self.generated[r.id] = [int(first[i])]
            self.tokens_served += 1
            remaining[i] = min(r.decode_tokens, self.max_decode)
            if remaining[i] == 0:
                r.finish = t
        tok = first
        while (remaining > 0).any():
            nxt, cache = self.dec_table(c, b, cache, tok)
            nxt = np.asarray(nxt)
            dt = self.dec_table.calls[-1][3]
            if self.clock == "modeled":
                dt = float(self.cost.decode_latency(
                    c, int((remaining > 0).sum())))
            t += dt
            for i, r in enumerate(batch):
                if remaining[i] <= 0:
                    continue            # slot already left the pool
                if dt > r.tbt_slo + 1e-12:
                    r.tbt_violations += 1
                self.generated[r.id].append(int(nxt[i]))
                self.tokens_served += 1
                remaining[i] -= 1
                if remaining[i] == 0:
                    r.finish = t
            tok = nxt
        return t


def make_token_live_server(arch: str = "smollm-135m-reduced", *,
                           c_set: Sequence[int] = (1, 2, 4),
                           b_set: Sequence[int] = (1, 2, 4),
                           prompt_len: int = 16, max_decode: int = 8,
                           clock: str = "measured", tick: float = 0.5,
                           prior_rps: float = 0.0,
                           cost: Optional[TokenCostModel] = None):
    """Build the full real-kernel token serving stack.

    Resolves ``arch`` through ``configs.registry`` with the Pallas
    prefill/decode kernel routes enabled, builds + compiles the two
    (c, b) executable tables, calibrates a :class:`TokenCostModel` from
    them, and wires a :class:`repro.core.scaler.TokenSpongeScaler` +
    :class:`TokenJaxBackend` behind the standard ``ScenarioRunner``.
    Returns ``(runner, backend, cfg, cost)``.
    """
    import dataclasses
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    cfg = dataclasses.replace(get_config(arch), use_pallas_prefill=True,
                              use_pallas_decode=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prefill_fns, decode_fns = build_token_step_fns(
        model, params, c_set, b_set, prompt_len, max_decode=max_decode)
    warmup_token_fns(prefill_fns, decode_fns, prompt_len)
    if cost is None:
        cost = calibrate_token_fns(prefill_fns, decode_fns, prompt_len,
                                   mean_decode=max_decode / 2.0)
    scaler = TokenSpongeScaler(cost, c_set=tuple(c_set),
                               b_set=tuple(b_set),
                               adaptation_interval=tick)
    backend = TokenJaxBackend(prefill_fns, decode_fns, cost, prompt_len,
                              max_decode=max_decode, clock=clock)
    runner = ScenarioRunner(scaler, backend, tick=tick)
    runner.monitor.rate.prior_rps = prior_rps
    return runner, backend, cfg, cost


def run_token_jax_scenario(name: str, *, requests: int = 24, seed: int = 0,
                           arch: str = "smollm-135m-reduced",
                           prompt_len: int = 16, max_decode: int = 8,
                           clock: str = "measured", rps: Optional[float] =
                           None):
    """Run a slice of a registered token scenario on the real kernels.

    Materializes ``requests`` arrivals from the scenario's workload
    (prompts truncated to the table's ``prompt_len`` bucket, decode
    streams clipped to ``max_decode`` — the executable-table budget),
    serves them through :func:`make_token_live_server`, and returns
    ``(RunReport, stats)``.
    """
    from repro.serving.scenarios import build_scenario
    batch, meta = build_scenario(name, requests=requests, seed=seed,
                                 rps=rps)
    if not meta.get("token"):
        raise ValueError(f"{name!r} is not a token scenario")
    runner, backend, cfg, cost = make_token_live_server(
        arch, prompt_len=prompt_len, max_decode=max_decode, clock=clock,
        prior_rps=meta["expected_rps"], tick=meta.get("tick", 0.5))
    rng = np.random.default_rng(seed)
    arrivals = []
    for r in batch.head(requests).to_requests():
        r = Request.make(arrival=r.arrival, comm_latency=r.comm_latency,
                         slo=r.slo, size_kb=r.size_kb,
                         prompt_tokens=min(r.prompt_tokens, prompt_len),
                         decode_tokens=min(r.decode_tokens, max_decode),
                         tbt_slo=r.tbt_slo)
        prompt = rng.integers(0, cfg.vocab_size,
                              r.prompt_tokens).astype(np.int32)
        arrivals.append((r, prompt))
    t0 = time.perf_counter()
    report = runner.run(arrivals)
    stats = {"engine": "token-jax", "arch": cfg.name,
             "events": runner.events_processed,
             "run_wall_s": time.perf_counter() - t0,
             "tokens_executed": backend.tokens_served,
             "cost_r2": (cost.r2_prefill, cost.r2_decode), "meta": meta}
    return report, stats
