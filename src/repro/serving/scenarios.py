"""Scenario registry: named million-request-capable workload scripts.

Each scenario is a *vectorized* workload generator — arrival pattern,
network/dynamic-SLO model, request mix — returning a ``RequestBatch``
plus the metadata policies need (nominal SLO, expected rate).  One
scenario runs on either engine:

* ``engine="fast"``  — ``FastSimRunner`` + memoized solver, the
  million-request path (``benchmarks/throughput_bench.py``);
* ``engine="exact"`` — ``make_sim_server``'s object-based
  ``ScenarioRunner``, decision-equivalent at small scale and required
  for legacy/object-inspecting policies (e.g. ``sponge-pred``).

Registered scenarios (see ``docs/scenarios.md`` for the full briefs):

* ``steady``         — fixed-rate arrivals over a 4G trace; the Fig. 4
  study continued to arbitrary scale.
* ``diurnal``        — one compressed day: sinusoidal Poisson rate
  between ~25% and 100% of peak.
* ``flash-crowd``    — low base load with two sudden arrival spikes that
  exceed cluster capacity; exercises the solver's infeasible fallback.
* ``network-replay`` — fixed-rate arrivals, clients split across a 4G
  and a 5G bandwidth replay; the paper's dynamic-SLO mechanism under
  heterogeneous networks.
* ``mixed-slo``      — three interleaved request classes (interactive /
  standard / batch) with different SLOs and payload sizes.
* ``llm-chat``       — autoregressive chat serving: log-normal prompt /
  decode token lengths, TTFT + per-token (TBT) SLOs, continuous
  batching (``meta["token"] is True`` routes the run through the
  token-level engines).
* ``llm-mixed-len``  — chat traffic interleaved with long-document
  requests (8x longer prompts, longer streams, looser SLOs) — batch
  *composition* varies wildly, which is exactly what the token-level
  cost model exists for.
* ``replica-failure`` / ``rolling-restart`` / ``fleet-flash-crowd`` —
  fleet scenarios (``meta["fleet"] is True`` routes the run through the
  joint horizontal + vertical engines in ``repro.serving.fleet``):
  mid-run replica loss, a rolling deploy under live traffic, and
  arrival spikes against a peak-provisioned static-fleet baseline.
* ``degrade-sustained-overload`` / ``degrade-flash-overload`` /
  ``degrade-fade-overload`` — degrade-under-pressure scenarios: fleet
  scenarios whose meta additionally carries a model-ladder spec
  (``meta["ladder"]``, resolved via ``repro.core.degradation``) and an
  ``accuracy_floor`` — the (m, n, c, b) planner sheds model size only
  when no (n, c, b) at the resident rung is feasible, and never below
  the floor.
* ``llm-heavy-tail``  — chat traffic with *heavy-tailed* decode lengths
  (lognormal sigma=1.4, p90 ~6x the median) whose generating
  distribution is declared to the scheduler (``meta["decode_dist"]``):
  quantile-based admission and speculative cancel-on-overrun
  (``repro.core.uncertainty``) vs the deterministic-cost scaler.
* ``retrieve-then-generate`` — multi-stage RAG mix: ~35% of requests
  spend a variable retrieval stage *before* arriving (it eats the TTFT
  budget like a slow network), then decode against a declared
  two-component mixture; per-SLO-class planning quantiles.
* ``slo-renegotiation`` / ``cancel-storm`` — online-session scenarios
  (``meta["session_events"]`` routes the run through the session API,
  ``repro.serving.session``): network telemetry re-keys queued
  requests' deadlines mid-flight (fades tighten, recoveries relax);
  overload spikes in which half the queued spike traffic cancels.

Adding a scenario: write a ``build(duration, rps, rng) ->
(RequestBatch, meta)`` function, wrap it in :class:`Scenario`, decorate
with :func:`register`.  It is immediately runnable via
``launch/serve.py --scenario <name>`` and picked up by the docs check
and the scenario smoke tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.cost_model import TokenCostModel
from repro.core.perf_model import PerfModel, yolov5s_like
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.network.latency import comm_latency_many
from repro.network.traces import synth_4g_trace, synth_5g_trace
from repro.serving.workload import RequestBatch, lognormal_lengths


@dataclass(frozen=True)
class Scenario:
    """A named workload script.

    ``build(duration_s, rps, rng)`` returns ``(RequestBatch, meta)``;
    ``meta`` must carry ``slo`` (nominal, what SLO-blind policies like
    FA2 plan with) and ``expected_rps`` (deploy-time rate prior).
    ``mean_rate_factor`` maps the scenario's ``rps`` knob to its actual
    mean arrival rate, so ``requests=`` targets convert to a duration.
    """
    name: str
    summary: str
    build: Callable[[float, float, np.random.Generator],
                    Tuple[RequestBatch, dict]]
    default_rps: float
    default_duration: float
    mean_rate_factor: float = 1.0


SCENARIOS: Dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    """Add a scenario to the registry (returns it, decorator-style)."""
    SCENARIOS[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    """Look up a registered scenario; KeyError lists what exists."""
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {sorted(SCENARIOS)}") from None


def list_scenarios() -> Dict[str, str]:
    """name -> one-line summary, for --help output and the docs check."""
    return {s.name: s.summary for s in SCENARIOS.values()}


# --------------------------------------------------------------------------
# arrival-process helpers (all batched numpy — no per-request Python)
# --------------------------------------------------------------------------
def poisson_times(rate: float, duration: float,
                  rng: np.random.Generator) -> np.ndarray:
    """Homogeneous Poisson send times on [0, duration)."""
    n = rng.poisson(rate * duration)
    return np.sort(rng.uniform(0.0, duration, n))


def inhomogeneous_poisson_times(rate_fn: Callable[[np.ndarray], np.ndarray],
                                rate_max: float, duration: float,
                                rng: np.random.Generator) -> np.ndarray:
    """Thinning: draw at ``rate_max``, keep each point w.p. rate(t)/max."""
    t = poisson_times(rate_max, duration, rng)
    keep = rng.uniform(0.0, 1.0, t.size) < rate_fn(t) / rate_max
    return t[keep]


def _trace_seconds(duration: float) -> int:
    return int(duration) + 5


# --------------------------------------------------------------------------
# the registered scenarios
# --------------------------------------------------------------------------
def _build_steady(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    send = np.arange(0, duration, 1.0 / rps)
    cl = comm_latency_many(np.full(send.shape, 200.0), trace, send)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=200.0)
    return batch, {"slo": 1.0, "expected_rps": rps, "trace": trace}


register(Scenario(
    name="steady",
    summary="fixed-rate arrivals over a 4G bandwidth replay (Fig. 4 at "
            "arbitrary scale)",
    build=_build_steady, default_rps=20.0, default_duration=600.0))


def _build_diurnal(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)

    def rate(t):
        # one compressed "day": trough ~25% of peak, peak at mid-window
        return rps * (0.25 + 0.75 * 0.5 * (1 - np.cos(2 * np.pi
                                                      * t / duration)))

    send = inhomogeneous_poisson_times(rate, rps, duration, rng)
    cl = comm_latency_many(np.full(send.shape, 200.0), trace, send)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=200.0)
    return batch, {"slo": 1.0, "expected_rps": 0.625 * rps, "trace": trace,
                   "tick": 0.5}


register(Scenario(
    name="diurnal",
    summary="sinusoidal day/night Poisson load, trough 25% of peak — "
            "tests sustained scale-down without violations",
    build=_build_diurnal, default_rps=16.0, default_duration=600.0,
    mean_rate_factor=0.625))


def _build_flash_crowd(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    spikes = ((0.40, 0.02, 6.0), (0.70, 0.03, 3.0))   # (start, len, x-rate)

    def rate(t):
        r = np.full(t.shape, float(rps))
        for frac, width, mult in spikes:
            s = frac * duration
            r = np.where((t >= s) & (t < s + width * duration),
                         rps * mult, r)
        return r

    send = inhomogeneous_poisson_times(rate, rps * 6.0, duration, rng)
    cl = comm_latency_many(np.full(send.shape, 200.0), trace, send)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=200.0)
    return batch, {"slo": 1.0, "expected_rps": rps, "trace": trace}


register(Scenario(
    name="flash-crowd",
    summary="low base load with two arrival spikes beyond cluster "
            "capacity — exercises the infeasible-fallback drain",
    build=_build_flash_crowd, default_rps=10.0, default_duration=600.0,
    mean_rate_factor=1.16))   # 1 + 0.02*(6-1) + 0.03*(3-1)


def _build_network_replay(duration, rps, rng):
    s4 = int(rng.integers(2**31))
    s5 = int(rng.integers(2**31))
    t4 = synth_4g_trace(_trace_seconds(duration), seed=s4)
    t5 = synth_5g_trace(_trace_seconds(duration), seed=s5)
    send = np.arange(0, duration, 1.0 / rps)
    on_5g = rng.uniform(0.0, 1.0, send.size) < 0.5
    sizes = np.full(send.shape, 200.0)
    cl = np.where(on_5g, comm_latency_many(sizes, t5, send),
                  comm_latency_many(sizes, t4, send))
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=sizes)
    return batch, {"slo": 1.0, "expected_rps": rps,
                   "trace": t4, "trace_5g": t5}


register(Scenario(
    name="network-replay",
    summary="fixed-rate clients split 50/50 across 4G and 5G bandwidth "
            "replays — the paper's dynamic-SLO squeeze, heterogeneous",
    build=_build_network_replay, default_rps=20.0,
    default_duration=600.0))


def _build_mixed_slo(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    send = poisson_times(rps, duration, rng)
    # class mix: (weight, slo, size_kb).  The interactive SLO sits close
    # to — but inside — the perf model's batch-latency floor, so EDF must
    # consistently front-run the tight class for the run to stay clean.
    classes = np.array([[0.20, 0.6, 50.0],
                        [0.55, 1.0, 200.0],
                        [0.25, 3.0, 800.0]])
    pick = rng.choice(3, size=send.size, p=classes[:, 0])
    slo = classes[pick, 1]
    sizes = classes[pick, 2]
    cl = comm_latency_many(sizes, trace, send)
    batch = RequestBatch.from_send(send, cl, slo=slo, size_kb=sizes)
    return batch, {"slo": float(classes[:, 1].min()),
                   "expected_rps": rps, "trace": trace,
                   "tick": 0.5}


register(Scenario(
    name="mixed-slo",
    summary="three interleaved SLO classes (0.6s/1s/3s, 50KB-800KB) — "
            "EDF + per-request budgets must prioritize the tight class",
    build=_build_mixed_slo, default_rps=12.0, default_duration=600.0))


# --------------------------------------------------------------------------
# autoregressive (token-level) scenarios — ISSUE 3
# --------------------------------------------------------------------------
def _token_meta(batch: RequestBatch, rps: float, trace, slo: float,
                tbt: float) -> dict:
    """Shared meta for token scenarios: the cost model's mean request
    shape is calibrated to the *generated* length distributions."""
    cost = TokenCostModel.smollm_like(
        mean_prompt=float(batch.prompt_tokens.mean()),
        mean_decode=float(batch.decode_tokens.mean()))
    return {"slo": slo, "expected_rps": rps, "trace": trace,
            "token": True, "cost": cost, "tbt": tbt, "tick": 0.25}


def _build_llm_chat(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    send = poisson_times(rps, duration, rng)
    n = send.size
    prompt = lognormal_lengths(rng, n, median=64, sigma=0.7, lo=8, hi=512)
    decode = lognormal_lengths(rng, n, median=24, sigma=0.6, lo=1, hi=128)
    # chat payloads are small: ~8 bytes per prompt token on the wire
    sizes = np.maximum(prompt * 0.008, 1.0)
    cl = comm_latency_many(sizes, trace, send)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=sizes,
                                   prompt_tokens=prompt,
                                   decode_tokens=decode, tbt_slo=0.08)
    return batch, _token_meta(batch, rps, trace, slo=1.0, tbt=0.08)


register(Scenario(
    name="llm-chat",
    summary="autoregressive chat: log-normal prompt/decode lengths, "
            "1s TTFT + 80ms TBT SLOs, continuous batching",
    build=_build_llm_chat, default_rps=25.0, default_duration=600.0))


def _build_llm_mixed_len(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    send = poisson_times(rps, duration, rng)
    n = send.size
    is_doc = rng.uniform(0.0, 1.0, n) < 0.25
    prompt = np.where(
        is_doc,
        lognormal_lengths(rng, n, median=384, sigma=0.4, lo=128, hi=1024),
        lognormal_lengths(rng, n, median=48, sigma=0.5, lo=8, hi=256))
    decode = np.where(
        is_doc,
        lognormal_lengths(rng, n, median=48, sigma=0.5, lo=8, hi=192),
        lognormal_lengths(rng, n, median=16, sigma=0.5, lo=1, hi=64))
    slo = np.where(is_doc, 2.5, 0.8)            # TTFT budgets
    tbt = np.where(is_doc, 0.15, 0.06)          # per-token budgets
    sizes = np.maximum(prompt * 0.008, 1.0)
    cl = comm_latency_many(sizes, trace, send)
    batch = RequestBatch.from_send(send, cl, slo=slo, size_kb=sizes,
                                   prompt_tokens=prompt,
                                   decode_tokens=decode, tbt_slo=tbt)
    meta = _token_meta(batch, rps, trace, slo=0.8, tbt=0.06)
    return batch, meta


register(Scenario(
    name="llm-mixed-len",
    summary="chat + long-document mix (8x prompt spread, per-class "
            "TTFT/TBT SLOs) — batch composition varies wildly",
    build=_build_llm_mixed_len, default_rps=18.0, default_duration=600.0))


# --------------------------------------------------------------------------
# uncertainty scenarios (decode lengths unknown at admission — ISSUE 7)
# --------------------------------------------------------------------------
def _build_llm_heavy_tail(duration, rps, rng):
    """Chat traffic whose decode lengths are *heavy-tailed* (Orloj's
    regime): the declared ``LognormalLengths`` is exactly the generating
    distribution, so the scheduler knows the distribution but not any
    request's realized length.  The tail above the p90 carries ~half the
    total decode mass — a deterministic-cost scaler planning at the mean
    lets a few monster streams hog every slot."""
    from repro.core.uncertainty import LognormalLengths
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    send = poisson_times(rps, duration, rng)
    n = send.size
    prompt = lognormal_lengths(rng, n, median=64, sigma=0.7, lo=8, hi=512)
    decode = lognormal_lengths(rng, n, median=16, sigma=1.4, lo=1, hi=1024)
    sizes = np.maximum(prompt * 0.008, 1.0)
    cl = comm_latency_many(sizes, trace, send)
    dist = LognormalLengths(median=16, sigma=1.4, lo=1, hi=1024)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=sizes,
                                   prompt_tokens=prompt,
                                   decode_tokens=decode, tbt_slo=0.08,
                                   decode_dist=dist)
    meta = _token_meta(batch, rps, trace, slo=1.0, tbt=0.08)
    meta["decode_dist"] = dist
    meta["admission_quantile"] = 0.9       # scenario default; CLI overrides
    return batch, meta


register(Scenario(
    name="llm-heavy-tail",
    summary="heavy-tailed decode lengths (lognormal sigma=1.4, declared "
            "distribution): quantile admission + cancel-on-overrun vs "
            "the deterministic-cost scaler",
    build=_build_llm_heavy_tail, default_rps=25.0, default_duration=600.0))


def _build_retrieve_then_generate(duration, rps, rng):
    """Vortex-style multi-stage requests under one end-to-end budget:
    ~35% of requests run a retrieval stage first (variable-duration,
    gamma-distributed, spent *before* the prompt reaches the server — it
    eats the TTFT budget exactly like slow networks do in the paper's
    dynamic-SLO mechanism) and then generate against a much longer
    retrieved context.  Decode lengths follow a two-component mixture
    the scheduler declares but cannot resolve per request."""
    from repro.core.uncertainty import LognormalLengths, MixtureLengths
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    send = poisson_times(rps, duration, rng)
    n = send.size
    is_rag = rng.uniform(0.0, 1.0, n) < 0.35
    prompt = np.where(
        is_rag,
        lognormal_lengths(rng, n, median=320, sigma=0.5, lo=64, hi=1024),
        lognormal_lengths(rng, n, median=48, sigma=0.5, lo=8, hi=256))
    direct = LognormalLengths(median=16, sigma=0.6, lo=1, hi=128)
    rag = LognormalLengths(median=64, sigma=0.9, lo=8, hi=768)
    decode = np.where(is_rag,
                      rag.sample(rng, n).astype(np.int64),
                      direct.sample(rng, n).astype(np.int64))
    # the retrieval stage: gamma-distributed seconds added before the
    # request arrives at the generator (deadline = send + slo stands,
    # so retrieval time comes straight out of the TTFT budget)
    retrieval = np.where(is_rag, rng.gamma(2.0, 0.12, n), 0.0)
    sizes = np.maximum(prompt * 0.008, 1.0)
    cl = comm_latency_many(sizes, trace, send) + retrieval
    slo = np.where(is_rag, 2.0, 0.9)
    tbt = np.where(is_rag, 0.10, 0.07)
    dist = MixtureLengths((direct, rag), (0.65, 0.35))
    batch = RequestBatch.from_send(send, cl, slo=slo, size_kb=sizes,
                                   prompt_tokens=prompt,
                                   decode_tokens=decode, tbt_slo=tbt,
                                   decode_dist=dist)
    meta = _token_meta(batch, rps, trace, slo=0.9, tbt=0.07)
    meta["decode_dist"] = dist
    meta["admission_quantile"] = 0.9
    # tight class (direct, slo<=0.9) plans higher up the distribution
    meta["class_quantiles"] = ((1.0, 0.95),)
    return batch, meta


register(Scenario(
    name="retrieve-then-generate",
    summary="multi-stage RAG mix: variable-duration retrieval eats the "
            "TTFT budget, decode is a declared two-component mixture — "
            "per-SLO-class quantile admission",
    build=_build_retrieve_then_generate, default_rps=20.0,
    default_duration=600.0))


# --------------------------------------------------------------------------
# fleet scenarios (joint horizontal + vertical scaling — ISSUE 4)
# --------------------------------------------------------------------------
def _fleet_meta(rps: float, trace, *, n0: int, c0: int = 16,
                events=(), router: str = "least-loaded",
                tick: float = 0.5) -> dict:
    """Shared meta for fleet scenarios: ``fleet=True`` routes the run
    through the fleet engines (``repro.serving.fleet``); ``n0``/``c0``
    size the deploy-time fleet, ``fleet_events`` inject disruptions."""
    return {"slo": 1.0, "expected_rps": rps, "trace": trace,
            "fleet": True, "n0": n0, "c0": c0, "router": router,
            "fleet_events": tuple(events), "tick": tick}


def _build_replica_failure(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    send = poisson_times(rps, duration, rng)
    cl = comm_latency_many(np.full(send.shape, 200.0), trace, send)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=200.0)
    events = ((0.45 * duration, "kill", 1),)
    return batch, _fleet_meta(rps, trace, n0=4, events=events)


register(Scenario(
    name="replica-failure",
    summary="steady fleet load; one replica fails mid-run — the joint "
            "scaler must re-target n and absorb the re-routed queue",
    build=_build_replica_failure, default_rps=60.0,
    default_duration=600.0))


def _build_rolling_restart(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    send = poisson_times(rps, duration, rng)
    cl = comm_latency_many(np.full(send.shape, 200.0), trace, send)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=200.0)
    # restart each deploy-time replica in turn, 4 s cold start apiece
    events = tuple((frac * duration, "restart", 0, 4.0)
                   for frac in (0.30, 0.45, 0.60, 0.75))
    return batch, _fleet_meta(rps, trace, n0=4, events=events)


register(Scenario(
    name="rolling-restart",
    summary="each replica is drained and replaced in sequence (4 s cold "
            "start) — a deploy rollout under live traffic",
    build=_build_rolling_restart, default_rps=60.0,
    default_duration=600.0))


def _build_fleet_flash_crowd(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    spikes = ((0.40, 0.03, 3.0), (0.70, 0.04, 2.0))   # (start, len, x-rate)

    def rate(t):
        r = np.full(t.shape, float(rps))
        for frac, width, mult in spikes:
            s = frac * duration
            r = np.where((t >= s) & (t < s + width * duration),
                         rps * mult, r)
        return r

    send = inhomogeneous_poisson_times(rate, rps * 3.0, duration, rng)
    cl = comm_latency_many(np.full(send.shape, 200.0), trace, send)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=200.0)
    return batch, _fleet_meta(rps, trace, n0=8)


register(Scenario(
    name="fleet-flash-crowd",
    summary="fleet-scale base load with 3x/2x arrival spikes — joint "
            "(n, c, b) scaling vs a peak-provisioned static fleet",
    build=_build_fleet_flash_crowd, default_rps=120.0,
    default_duration=600.0,
    mean_rate_factor=1.10))   # 1 + 0.03*(3-1) + 0.04*(2-1)


# --------------------------------------------------------------------------
# degrade-under-pressure scenarios (model ladder — ISSUE 9)
# --------------------------------------------------------------------------
def _degrade_meta(rps: float, trace, *, n0: int,
                  accuracy_floor: float = 0.60, events=()) -> dict:
    """Fleet meta plus the model-ladder keys: ``ladder`` is a *spec*
    (resolved by :func:`repro.core.degradation.resolve_ladder` at run
    time, so the meta stays JSON-serializable) and ``accuracy_floor``
    bounds how far the (m, n, c, b) solver may shed.  The 0.60 default
    admits smollm-360m (0.64) but fences off smollm-135m (0.58) — the
    floor does real work in every degrade scenario."""
    meta = _fleet_meta(rps, trace, n0=n0, events=events)
    meta.update(ladder="default", accuracy_floor=accuracy_floor)
    return meta


def _build_degrade_sustained(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    send = poisson_times(rps, duration, rng)
    cl = comm_latency_many(np.full(send.shape, 200.0), trace, send)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=200.0)
    return batch, _degrade_meta(rps, trace, n0=8)


register(Scenario(
    name="degrade-sustained-overload",
    summary="arrivals hold well above the top rung's full-fleet ceiling "
            "for the whole run — the (m, n, c, b) planner must shed "
            "accuracy (never below the floor) to keep the SLO",
    build=_build_degrade_sustained, default_rps=180.0,
    default_duration=600.0))


def _build_degrade_flash(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    start, width, mult = 0.35, 0.15, 3.2     # one long over-capacity spike

    def rate(t):
        s = start * duration
        return np.where((t >= s) & (t < s + width * duration),
                        rps * mult, float(rps))

    send = inhomogeneous_poisson_times(rate, rps * mult, duration, rng)
    cl = comm_latency_many(np.full(send.shape, 200.0), trace, send)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=200.0)
    return batch, _degrade_meta(rps, trace, n0=8)


register(Scenario(
    name="degrade-flash-overload",
    summary="comfortable base load, then a 3.2x flash crowd beyond the "
            "top rung's capacity — shed for the spike, recover "
            "(hysteretic swap-back) once it passes",
    build=_build_degrade_flash, default_rps=55.0,
    default_duration=600.0,
    mean_rate_factor=1.33))    # 1 + 0.15*(3.2-1)


def _build_degrade_fade(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)

    lo, hi, surge = 0.40 * duration, 0.70 * duration, 2.4

    def rate(t):
        return np.where((t >= lo) & (t < hi), rps * surge, float(rps))

    send = inhomogeneous_poisson_times(rate, rps * surge, duration, rng)
    cl = comm_latency_many(np.full(send.shape, 200.0), trace, send)
    # a mid-run network fade stretches comm latency 4x, capped at
    # 0.8 s, while the arrival rate surges 2.4x inside the same
    # window: the surviving 0.2 s compute budget caps the top rung at
    # single-item batches, whose fleet-wide ceiling sits well below
    # the surged rate — only a smaller rung clears both the deadline
    # and the rate at once
    fade = (send >= lo) & (send < hi)
    cl = np.where(fade, np.minimum(cl * 4.0, 0.80), cl)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=200.0)
    return batch, _degrade_meta(rps, trace, n0=8)


register(Scenario(
    name="degrade-fade-overload",
    summary="a network fade stretches comm latency 4x (deadlines "
            "tighten to the top rung's single-item latency) while "
            "the arrival rate surges 2.4x inside the fade window — "
            "overload arrives through the SLO budget and the rate "
            "at once",
    build=_build_degrade_fade, default_rps=55.0,
    default_duration=600.0,
    mean_rate_factor=1.42))    # 1 + 0.3*(2.4-1)


# --------------------------------------------------------------------------
# multi-tenant scenarios (shared core pool — ISSUE 6)
# --------------------------------------------------------------------------
def _whisper_like() -> PerfModel:
    """A heavier fixed-work profile (speech encoder shape): ~2.4x the
    yolov5s per-item work, served under a looser 2 s SLO."""
    return PerfModel(gamma=0.36, eps=0.10, delta=0.055, eta=0.05)


def _rwkv_like() -> PerfModel:
    """A light recurrent profile: cheap per-item work under a tight
    0.8 s SLO — the tenant most sensitive to pool starvation."""
    return PerfModel(gamma=0.08, eps=0.03, delta=0.018, eta=0.02)


def _tenant_batch(send: np.ndarray, trace, slo: float,
                  size_kb: float) -> RequestBatch:
    cl = comm_latency_many(np.full(send.shape, size_kb), trace, send)
    return RequestBatch.from_send(send, cl, slo=slo, size_kb=size_kb)


def _merge_batches(batches) -> RequestBatch:
    """Arrival-sorted concatenation of per-tenant batches (the sanity
    view ``build_scenario`` returns; the engines run the per-tenant
    columns carried in ``meta['tenants']``)."""
    import dataclasses
    cols = {}
    order = np.argsort(np.concatenate([b.arrival for b in batches]),
                       kind="stable")
    for f in dataclasses.fields(RequestBatch):
        vals = [getattr(b, f.name) for b in batches]
        if not isinstance(vals[0], np.ndarray):
            continue                 # object attachments (decode_dist)
        cols[f.name] = np.concatenate(vals)[order]
    return RequestBatch(**cols)


def _zoo_specs(duration, rps, rng, trace, *, spikes=(),
               spike_tenant="rwkv6-1.6b"):
    """The three heterogeneous tenants sharing the 128-core pool:
    ``whisper-large-v3`` (heavy fixed-work, diurnal), ``smollm-135m``
    (a chat LLM priced through the token cost model's fixed-work
    surface, diurnal in antiphase) and ``rwkv6-1.6b`` (light
    fixed-work, tight SLO, steady base).  ``spikes`` overlays flash
    crowds on ``spike_tenant``'s base rate (replacing its diurnal
    shape); tenant names are registry arch ids
    (``repro.configs.registry``)."""
    from repro.core.cost_model import TokenCostModel
    from repro.serving.tenancy import TenantSpec

    def diurnal(peak, phase):
        def rate(t):
            return peak * (0.25 + 0.75 * 0.5 *
                           (1 - np.cos(2 * np.pi * t / duration + phase)))
        return rate

    def steady(base):
        def rate(t):
            return np.full(t.shape, float(base))
        return rate

    def spiked(base):
        def rate(t):
            r = np.full(t.shape, float(base))
            for frac, width, mult in spikes:
                s = frac * duration
                r = np.where((t >= s) & (t < s + width * duration),
                             base * mult, r)
            return r
        return rate

    shares = {"whisper-large-v3": 0.25, "smollm-135m": 0.55,
              "rwkv6-1.6b": 0.20}
    peak_mult = max((m for _, _, m in spikes), default=1.0)
    rates = {}
    for name, share in shares.items():
        base = rps * share
        if name == spike_tenant:
            rates[name] = (spiked(base), base * peak_mult)
        elif name == "whisper-large-v3":
            rates[name] = (diurnal(base, 0.0), base)
        elif name == "smollm-135m":
            rates[name] = (diurnal(base, np.pi), base)
        else:
            rates[name] = (steady(base), base)
    chat_cost = TokenCostModel.smollm_like(mean_prompt=64.0,
                                           mean_decode=24.0)
    shape = {
        "whisper-large-v3": dict(cost=_whisper_like(), slo=2.0,
                                 size_kb=600.0, weight=1.0, priority=1,
                                 n0=2),
        # antiphase diurnal => smollm *starts* at peak rate: deploy-time
        # provisioning (n0) matches, like any operator would
        "smollm-135m": dict(cost=chat_cost, slo=1.2, size_kb=2.0,
                            weight=2.0, priority=0, n0=8),
        "rwkv6-1.6b": dict(cost=_rwkv_like(), slo=0.8, size_kb=50.0,
                           weight=1.0, priority=2, n0=2),
    }
    specs = []
    for name in ("whisper-large-v3", "smollm-135m", "rwkv6-1.6b"):
        rate_fn, rate_max = rates[name]
        send = inhomogeneous_poisson_times(rate_fn, rate_max, duration,
                                           rng)
        sh = shape[name]
        batch = _tenant_batch(send, trace, sh["slo"], sh["size_kb"])
        mean_rate = len(batch) / duration if duration else 0.0
        specs.append(TenantSpec(
            name=name, cost=sh["cost"], batch=batch,
            expected_rps=mean_rate, weight=sh["weight"],
            priority=sh["priority"], n0=sh["n0"]))
    return specs


def _tenant_meta(specs, rps, trace, *, pool_cores: int = 128,
                 tick: float = 0.5) -> dict:
    """Shared meta for multi-tenant scenarios: ``tenants`` routes the
    run through the pool engines (``repro.serving.tenancy``)."""
    return {"slo": min(float(s.batch.slo.min()) for s in specs),
            "expected_rps": sum(s.expected_rps for s in specs),
            "trace": trace, "tenants": tuple(specs),
            "pool_cores": pool_cores, "tick": tick}


def _build_mixed_zoo(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    # sustained flash crowds on the tight-SLO tenant: the 6x crowd
    # (288 rps at the default 240 rps zoo load) exceeds rwkv6's ~262 rps
    # sustainable rate under its 32-core slice, so its capped solver
    # stays λ-infeasible round after round — the frontier prices the
    # extra cores, hysteresis clears, swaps fire.  The 4x crowd fits
    # in-slice: only reaction violations, no swap (the contrast case).
    spikes = ((0.40, 0.07, 6.0), (0.70, 0.05, 4.0))   # on rwkv6
    specs = _zoo_specs(duration, rps, rng, trace, spikes=spikes)
    return _merge_batches([s.batch for s in specs]), \
        _tenant_meta(specs, rps, trace)


register(Scenario(
    name="mixed-zoo",
    summary="whisper + chat LLM + rwkv6 sharing a 128-core pool: "
            "antiphase diurnal cross-traffic with 6x/4x flash crowds "
            "on the tight-SLO tenant — marginal-value core swapping",
    build=_build_mixed_zoo, default_rps=240.0, default_duration=600.0,
    mean_rate_factor=0.80))   # 0.8*0.625 (diurnal) + 0.2*1.50 (spiked)


def _build_mixed_zoo_rush(duration, rps, rng):
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    # staggered flash crowds on the chat tenant: the 5x crowds (660 rps
    # at the default 240 rps zoo load) exceed smollm's ~602 rps
    # sustainable rate under its 64-core slice — the pool must lend the
    # same cores out and claw them back through swap hysteresis three
    # times in one run
    specs = _zoo_specs(duration, rps, rng, trace,
                       spike_tenant="smollm-135m",
                       spikes=((0.30, 0.05, 5.0), (0.55, 0.05, 5.0),
                               (0.80, 0.04, 4.0)))
    return _merge_batches([s.batch for s in specs]), \
        _tenant_meta(specs, rps, trace)


register(Scenario(
    name="mixed-zoo-rush",
    summary="the zoo under staggered flash crowds on the chat tenant — "
            "cores must cycle donor -> receiver -> donor through "
            "swap hysteresis",
    build=_build_mixed_zoo_rush, default_rps=240.0,
    default_duration=600.0,
    mean_rate_factor=1.19))   # 0.25*0.625 + 0.55*1.52 + 0.20*1.0


# --------------------------------------------------------------------------
# online-session scenarios (mid-flight renegotiation — ISSUE 5)
# --------------------------------------------------------------------------
def _build_slo_renegotiation(duration, rps, rng):
    """Live telemetry renegotiates queued budgets as the network moves.

    Each request's deadline is provisioned at send time for the
    response-path latency the link then sustains; shortly after arrival
    a fraction of clients report fresh telemetry (``session_events``)
    and the deadline is re-keyed to ``send + slo - response_latency(t)``
    — a fade *tightens* a queued request's budget, a recovery *relaxes*
    it.  This is the paper's dynamic-SLO mechanism continued past
    submission, driven by the same 4G bandwidth replay."""
    import dataclasses
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    send = poisson_times(rps, duration, rng)
    sizes = np.full(send.shape, 200.0)
    cl = comm_latency_many(sizes, trace, send)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=sizes)
    # provision the response leg (replies are ~4x smaller than request
    # payloads) at send-time bandwidth: the server must finish early
    # enough for the reply to make the end-to-end SLO
    resp_kb = batch.size_kb * 0.25
    resp0 = comm_latency_many(resp_kb, trace,
                              batch.arrival - batch.comm_latency)
    batch = dataclasses.replace(batch, deadline=batch.deadline - resp0)
    n = len(batch)
    pick = rng.uniform(0.0, 1.0, n) < 0.35
    t_ev = batch.arrival + rng.uniform(0.05, 0.45, n)
    resp1 = comm_latency_many(resp_kb, trace, t_ev)
    new_dl = (batch.arrival - batch.comm_latency) + batch.slo - resp1
    events = sorted(
        (float(t_ev[i]), "update", int(i), float(new_dl[i]))
        for i in np.flatnonzero(pick))
    return batch, {"slo": 1.0, "expected_rps": rps, "trace": trace,
                   "session_events": tuple(events), "tick": 0.5}


register(Scenario(
    name="slo-renegotiation",
    summary="network telemetry re-keys queued requests' budgets "
            "mid-flight (35% of clients; fades tighten, recoveries "
            "relax) — the online session API's headline scenario",
    build=_build_slo_renegotiation, default_rps=20.0,
    default_duration=600.0))


def _build_cancel_storm(duration, rps, rng):
    """Overload spikes where clients abandon queued requests en masse.

    Two arrival spikes push the queue past capacity; half the requests
    sent inside a spike cancel shortly after arriving (users giving up
    during the overload).  The cancel-aware λ window must deflate the
    provisioning signal immediately and the EDF queues must excise the
    cancelled entries without stalling dispatch."""
    seed = int(rng.integers(2**31))
    trace = synth_4g_trace(_trace_seconds(duration), seed=seed)
    spikes = ((0.35, 0.04, 4.0), (0.65, 0.03, 4.0))   # (start, len, x-rate)

    def rate(t):
        r = np.full(t.shape, float(rps))
        for frac, width, mult in spikes:
            s = frac * duration
            r = np.where((t >= s) & (t < s + width * duration),
                         rps * mult, r)
        return r

    send = inhomogeneous_poisson_times(rate, rps * 4.0, duration, rng)
    cl = comm_latency_many(np.full(send.shape, 200.0), trace, send)
    batch = RequestBatch.from_send(send, cl, slo=1.0, size_kb=200.0)
    n = len(batch)
    src_send = batch.arrival - batch.comm_latency
    in_spike = np.zeros(n, bool)
    for frac, width, _ in spikes:
        s = frac * duration
        in_spike |= (src_send >= s) & (src_send < s + width * duration)
    pick = in_spike & (rng.uniform(0.0, 1.0, n) < 0.5)
    t_ev = batch.arrival + rng.uniform(0.1, 0.6, n)
    events = sorted((float(t_ev[i]), "cancel", int(i))
                    for i in np.flatnonzero(pick))
    return batch, {"slo": 1.0, "expected_rps": rps, "trace": trace,
                   "session_events": tuple(events), "tick": 0.5}


register(Scenario(
    name="cancel-storm",
    summary="4x overload spikes where half the spike traffic cancels "
            "while queued — exercises EDF excision + cancel-aware λ",
    build=_build_cancel_storm, default_rps=15.0, default_duration=600.0,
    mean_rate_factor=1.21))   # 1 + 0.04*(4-1) + 0.03*(4-1)


# --------------------------------------------------------------------------
# building + running
# --------------------------------------------------------------------------
def build_scenario(name: str, *, duration: Optional[float] = None,
                   rps: Optional[float] = None, seed: int = 0,
                   requests: Optional[int] = None
                   ) -> Tuple[RequestBatch, dict]:
    """Materialize a registered scenario.  ``requests`` (if given)
    overrides ``duration`` with the window expected to produce that many
    arrivals at the scenario's mean rate — the million-request knob."""
    sc = get_scenario(name)
    rps = rps if rps is not None else sc.default_rps
    if requests is not None:
        duration = requests / (rps * sc.mean_rate_factor)
    duration = duration if duration is not None else sc.default_duration
    rng = np.random.default_rng(seed)
    batch, meta = sc.build(duration, rps, rng)
    meta.update(scenario=name, duration=duration, rps=rps, seed=seed)
    return batch, meta


def run_scenario(name: str, *, policy: str = "sponge",
                 engine: str = "fast", duration: Optional[float] = None,
                 rps: Optional[float] = None, seed: int = 0,
                 requests: Optional[int] = None,
                 perf: Optional[PerfModel] = None,
                 c_set=DEFAULT_C, b_set=DEFAULT_B, c0: int = 16,
                 tick: Optional[float] = None,
                 horizon: Optional[float] = None,
                 budget_quantum: float = 0.01, lam_quantum: float = 0.5,
                 replicas: Optional[int] = None,
                 router: Optional[str] = None,
                 mid_flight: bool = True,
                 tenant_policy: Optional[str] = None,
                 pool_cores: Optional[int] = None,
                 admission_quantile: Optional[float] = None,
                 speculative: bool = True,
                 model_ladder=None,
                 accuracy_floor: Optional[float] = None,
                 **policy_kw):
    """Run a registered scenario end to end; returns ``(RunReport,
    stats)`` where ``stats`` carries engine/meta/solver-cache info.

    The fast engine pairs ``FastSimRunner`` with the memoized solver
    (quantized as given); the exact engine goes through
    ``make_sim_server`` with the paper's bruteforce solver.  Fleet
    scenarios (``meta["fleet"]``) run the joint engines instead
    (``replicas`` overrides the deploy-time fleet size, ``router`` the
    arrival router — see ``repro.serving.fleet``).  Session scenarios
    (``meta["session_events"]``: ``slo-renegotiation``,
    ``cancel-storm``) run through the online session API
    (``repro.serving.session``); ``mid_flight=False`` suppresses the
    event stream — the no-renegotiation replay of the same workload,
    the baseline the decision-stream delta is measured against.
    Multi-tenant scenarios (``meta["tenants"]``: ``mixed-zoo`` /
    ``mixed-zoo-rush``) run through the shared-pool engines
    (``repro.serving.tenancy``); ``tenant_policy`` picks the pool's
    reallocation policy, ``pool_cores`` overrides the core budget.

    Token scenarios that declare a decode-length distribution
    (``meta["decode_dist"]``: ``llm-heavy-tail``,
    ``retrieve-then-generate``) run distribution-aware admission
    (``repro.core.uncertainty``): ``admission_quantile`` overrides the
    scenario's planning quantile (``0.0`` disables it entirely — the
    deterministic-cost baseline; ``None`` takes the scenario default),
    ``speculative=False`` turns off over-admission with
    cancel-on-overrun while keeping quantile drag.

    Fleet scenarios additionally accept ``model_ladder`` (a ladder spec
    — see ``repro.core.degradation.resolve_ladder``; overrides
    ``meta["ladder"]``) and ``accuracy_floor``: with a ladder attached,
    ``policy="sponge"`` runs the (m, n, c, b)
    :class:`~repro.serving.fleet.DegradingFleetScaler` and
    ``policy="fixed-<arch>"`` the same machinery pinned to one rung
    (the accuracy-reporting fixed-model baseline).
    """
    import time
    from repro.serving.api import make_policy, make_sim_server
    from repro.serving.fastpath import FastSimRunner
    assert engine in ("fast", "exact", "vector"), engine
    perf = perf if perf is not None else yolov5s_like()
    batch, meta = build_scenario(name, duration=duration, rps=rps,
                                 seed=seed, requests=requests)
    # a scenario with sub-second SLOs recommends its adaptation cadence
    tick = tick if tick is not None else meta.get("tick", 1.0)
    if engine == "vector" and (meta.get("token") or meta.get("tenants")
                               or meta.get("fleet")
                               or meta.get("session_events") is not None):
        raise ValueError(
            "engine='vector' replays plain single-instance scenarios "
            f"only ({name!r} needs the fast or exact engine)")
    if admission_quantile is not None and not meta.get("token"):
        raise ValueError(
            "admission_quantile applies to token scenarios only "
            f"(scenario {name!r} is not token-based)")
    if ((model_ladder is not None or accuracy_floor is not None)
            and not meta.get("fleet")):
        raise ValueError(
            "model_ladder/accuracy_floor apply to fleet scenarios only "
            f"(scenario {name!r} is not fleet-based)")
    if meta.get("token"):
        return _run_token_scenario(batch, meta, policy=policy,
                                   engine=engine, c_set=c_set, b_set=b_set,
                                   c0=c0, tick=tick, horizon=horizon,
                                   budget_quantum=budget_quantum,
                                   lam_quantum=lam_quantum,
                                   admission_quantile=admission_quantile,
                                   speculative=speculative, **policy_kw)
    if meta.get("tenants"):
        return _run_tenant_scenario(meta, policy=policy, engine=engine,
                                    tick=tick, horizon=horizon,
                                    budget_quantum=budget_quantum,
                                    lam_quantum=lam_quantum,
                                    tenant_policy=tenant_policy,
                                    pool_cores=pool_cores, router=router,
                                    **policy_kw)
    if meta.get("fleet"):
        return _run_fleet_scenario(batch, meta, policy=policy,
                                   engine=engine, perf=perf, c_set=c_set,
                                   b_set=b_set, tick=tick, horizon=horizon,
                                   budget_quantum=budget_quantum,
                                   lam_quantum=lam_quantum,
                                   replicas=replicas, router=router,
                                   model_ladder=model_ladder,
                                   accuracy_floor=accuracy_floor,
                                   **policy_kw)
    if meta.get("session_events") is not None:
        return _run_session_scenario(batch, meta, policy=policy,
                                     engine=engine, perf=perf,
                                     c_set=c_set, b_set=b_set, c0=c0,
                                     tick=tick, horizon=horizon,
                                     budget_quantum=budget_quantum,
                                     lam_quantum=lam_quantum,
                                     mid_flight=mid_flight, **policy_kw)
    common = dict(slo=meta["slo"], expected_rps=meta["expected_rps"],
                  adaptation_interval=tick)
    if engine in ("fast", "vector"):
        if policy.startswith("sponge-pred"):
            raise ValueError("sponge-pred inspects Request objects; "
                             "run it with engine='exact'")
        kw = dict(common, **policy_kw)
        if policy == "sponge":
            kw.update(solver="memo", budget_quantum=budget_quantum,
                      lam_quantum=lam_quantum)
        pol = make_policy(policy, perf, c_set=c_set, b_set=b_set, **kw)
        if engine == "vector":
            from repro.serving.vectorpath import VectorSimRunner
            cls = VectorSimRunner
        else:
            cls = FastSimRunner
        runner = cls(pol, perf, c_set, b_set, c0=c0, tick=tick,
                     prior_rps=meta["expected_rps"])
        t0 = time.perf_counter()
        report = runner.run(batch, horizon)
        stats = {"engine": engine, "events": runner.events_processed,
                 "run_wall_s": time.perf_counter() - t0, "meta": meta}
        scaler = getattr(pol, "scaler", None)
        if scaler is not None and hasattr(scaler, "solver_stats"):
            stats["solver"] = scaler.solver_stats()
        return report, stats
    server = make_sim_server(perf, policy, c_set=c_set, b_set=b_set,
                             c0=c0, tick=tick,
                             prior_rps=meta["expected_rps"],
                             **dict(common, **policy_kw))
    reqs = batch.to_requests()
    t0 = time.perf_counter()
    report = server.run(reqs, horizon)
    return report, {"engine": "exact",
                    "events": server.runner.events_processed,
                    "run_wall_s": time.perf_counter() - t0,
                    "meta": meta}


def _run_session_scenario(batch: RequestBatch, meta: dict, *, policy: str,
                          engine: str, perf: PerfModel, c_set, b_set,
                          c0: int, tick: float, horizon,
                          budget_quantum: float, lam_quantum: float,
                          mid_flight: bool = True, **policy_kw):
    """Session-scenario execution: the online serving API end to end.

    The workload is submitted through a live session and the scenario's
    ``session_events`` stream (mid-flight ``update_slo`` / ``cancel``
    ops, time-sorted) is applied between ``step_until`` advances —
    exactly how a network-telemetry feed would drive a real deployment.
    ``engine="fast"`` opens the session on a ``FastSimRunner`` (the
    ≥100k-request path, ``benchmarks/session_bench.py``);
    ``engine="exact"`` on the object-based ``ScenarioRunner``.
    ``mid_flight=False`` replays submits only (the closed-world
    baseline).  ``stats["session"]`` reports applied/no-op counts.
    """
    import time
    from repro.serving.api import make_policy, make_sim_server
    from repro.serving.fastpath import FastSimRunner
    from repro.serving.session import drive_session_events
    if engine not in ("fast", "exact"):
        raise ValueError("session scenarios run on the 'fast' or "
                         f"'exact' engine (got {engine!r})")
    events = meta.get("session_events", ()) if mid_flight else ()
    common = dict(slo=meta["slo"], expected_rps=meta["expected_rps"],
                  adaptation_interval=tick)
    scaler = None
    if engine == "fast":
        if policy.startswith("sponge-pred"):
            raise ValueError("sponge-pred inspects Request objects; "
                             "run it with engine='exact'")
        kw = dict(common, **policy_kw)
        if policy == "sponge":
            kw.update(solver="memo", budget_quantum=budget_quantum,
                      lam_quantum=lam_quantum)
        pol = make_policy(policy, perf, c_set=c_set, b_set=b_set, **kw)
        runner = FastSimRunner(pol, perf, c_set, b_set, c0=c0, tick=tick,
                               prior_rps=meta["expected_rps"])
        sess = runner.session()
        scaler = getattr(pol, "scaler", None)
    else:
        server = make_sim_server(perf, policy, c_set=c_set, b_set=b_set,
                                 c0=c0, tick=tick,
                                 prior_rps=meta["expected_rps"],
                                 **dict(common, **policy_kw))
        sess = server.session()
    t0 = time.perf_counter()
    handles = sess.submit_batch(batch)
    applied = drive_session_events(sess, handles, events)
    report = sess.finish(horizon)
    stats = {"engine": engine, "events": sess.events_processed,
             "run_wall_s": time.perf_counter() - t0, "meta": meta,
             "session": applied}
    if scaler is not None and hasattr(scaler, "solver_stats"):
        stats["solver"] = scaler.solver_stats()
    return report, stats


def _run_fleet_scenario(batch: RequestBatch, meta: dict, *, policy: str,
                        engine: str, perf: PerfModel, c_set, b_set,
                        tick: float, horizon,
                        budget_quantum: float, lam_quantum: float,
                        replicas: Optional[int], router: Optional[str],
                        model_ladder=None,
                        accuracy_floor: Optional[float] = None,
                        **policy_kw):
    """Fleet-scenario execution: the joint horizontal + vertical engines.

    ``engine="fast"`` — :class:`repro.serving.fleet.FleetFastSimRunner`
    (struct-of-arrays, the ≥500k-request path) with the quantized joint
    memoized solver; ``engine="exact"`` — the pre-heaped
    :class:`repro.serving.fleet.FleetExactRunner` gang loop at quanta 0
    (the decision-identity oracle).  ``policy="sponge"`` runs the joint
    :class:`~repro.serving.fleet.FleetSpongeScaler`;
    ``policy="static-<cores>"`` pins a
    :class:`~repro.serving.fleet.StaticFleetPolicy` at the deploy fleet
    size (the ``benchmarks/fleet_bench.py`` baseline).

    With a model ladder attached (``model_ladder`` argument or
    ``meta["ladder"]`` — the degrade-under-pressure family),
    ``policy="sponge"`` runs the (m, n, c, b)
    :class:`~repro.serving.fleet.DegradingFleetScaler` over the full
    ladder and ``policy="fixed-<arch>"`` runs the identical machinery
    over a single-rung ladder — the fixed-model baseline whose report
    still carries accuracy-weighted goodput, so
    ``benchmarks/degrade_bench.py`` compares like with like.
    """
    import time
    from repro.core.degradation import ModelLadder, resolve_ladder
    from repro.serving.fleet import (DegradingFleetScaler, FleetExactRunner,
                                     FleetFastSimRunner, FleetSpongeScaler,
                                     StaticFleetPolicy)
    n0 = int(replicas if replicas is not None else meta.get("n0", 1))
    c0 = int(meta.get("c0", max(c_set)))
    router = router if router is not None else meta.get("router",
                                                        "least-loaded")
    bq, lq = (budget_quantum, lam_quantum) if engine == "fast" else (0.0,
                                                                     0.0)
    spec = model_ladder if model_ladder is not None else meta.get("ladder")
    ladder = resolve_ladder(spec)
    afloor = (float(accuracy_floor) if accuracy_floor is not None
              else float(meta.get("accuracy_floor", 0.0)))
    run_ladder = None
    if ladder is not None and (policy == "sponge"
                               or policy.startswith("fixed-")):
        run_ladder = ladder
        if policy.startswith("fixed-"):
            # one-rung ladder: the same scaler/runner machinery pinned
            # to a single model, so accuracy reporting stays comparable
            run_ladder = ModelLadder([ladder.rung(policy[len("fixed-"):])])
            afloor = 0.0
        pol = DegradingFleetScaler(perf, c_set=tuple(c_set),
                                   b_set=tuple(b_set),
                                   adaptation_interval=tick,
                                   budget_quantum=bq, lam_quantum=lq,
                                   ladder=run_ladder,
                                   accuracy_floor=afloor,
                                   name=policy if policy != "sponge"
                                   else "sponge-degrade",
                                   **policy_kw)
    elif policy == "sponge":
        pol = FleetSpongeScaler(perf, c_set=tuple(c_set),
                                b_set=tuple(b_set),
                                adaptation_interval=tick,
                                budget_quantum=bq, lam_quantum=lq,
                                **policy_kw)
    elif policy == "static" or (policy.startswith("static-")
                                and policy.split("-", 1)[1].isdigit()):
        cores = int(policy.split("-", 1)[1]) if "-" in policy else c0
        pol = StaticFleetPolicy(perf, replicas=n0, cores=cores,
                                b_set=tuple(b_set), interval=tick,
                                budget_quantum=bq, lam_quantum=lq,
                                **policy_kw)
        c0 = cores
    else:
        raise ValueError(
            "fleet scenarios run 'sponge', 'static-<cores>' or (with a "
            f"model ladder) 'fixed-<arch>' policies (got {policy!r})")
    cls = FleetFastSimRunner if engine == "fast" else FleetExactRunner
    lkw = ({} if run_ladder is None
           else dict(ladder=run_ladder, m0=pol.model))
    runner = cls(pol, perf, c_set, b_set, n0=n0, c0=c0, tick=tick,
                 prior_rps=meta["expected_rps"], router=router, **lkw)
    t0 = time.perf_counter()
    report = runner.run(batch, horizon, events=meta.get("fleet_events", ()))
    stats = {"engine": engine, "events": runner.events_processed,
             "run_wall_s": time.perf_counter() - t0, "meta": meta,
             "max_replicas": runner.max_replicas, "router": router,
             "solver": pol.solver_stats()}
    if run_ladder is not None:
        stats["ladder"] = [r.name for r in run_ladder]
        stats["accuracy_floor"] = afloor
    return report, stats


def _run_tenant_scenario(meta: dict, *, policy: str, engine: str,
                         tick: float, horizon,
                         budget_quantum: float, lam_quantum: float,
                         tenant_policy: Optional[str],
                         pool_cores: Optional[int],
                         router: Optional[str], **policy_kw):
    """Multi-tenant-scenario execution: the shared-pool engines.

    ``engine="fast"`` — :class:`repro.serving.tenancy.TenantFastRunner`
    (every tenant's struct-of-arrays stream interleaved in one event
    loop, the ≥200k-request path) with quantized per-tenant joint
    solvers; ``engine="exact"`` — the pre-heaped
    :class:`repro.serving.tenancy.TenantExactRunner` oracle at quanta 0
    (the decision-identity configuration).  ``stats["pool"]`` carries
    the final caps and swap count, ``stats["tenants"]`` the per-tenant
    violation/core-second split (the full per-tenant
    :class:`~repro.serving.api.RunReport` list is on
    ``stats["tenant_reports"]``).
    """
    import time
    from repro.serving.tenancy import TenantExactRunner, TenantFastRunner
    if policy != "sponge":
        raise ValueError("multi-tenant scenarios run the sponge policy "
                         f"per tenant (got {policy!r}); the *pool* "
                         "policy is tenant_policy=...")
    pool_policy = (tenant_policy if tenant_policy is not None
                   else meta.get("pool_policy", "greedy-marginal"))
    budget = int(pool_cores if pool_cores is not None
                 else meta.get("pool_cores", 128))
    router = router if router is not None else meta.get("router",
                                                        "least-loaded")
    bq, lq = (budget_quantum, lam_quantum) if engine == "fast" else (0.0,
                                                                     0.0)
    cls = TenantFastRunner if engine == "fast" else TenantExactRunner
    runner = cls(meta["tenants"], budget=budget, policy=pool_policy,
                 tick=tick, router=router, budget_quantum=bq,
                 lam_quantum=lq, **policy_kw)
    t0 = time.perf_counter()
    report = runner.run(horizon)
    stats = {"engine": engine, "events": runner.events_processed,
             "run_wall_s": time.perf_counter() - t0, "meta": meta,
             "router": router,
             "pool": {"policy": pool_policy, "budget": budget,
                      "caps": tuple(runner.pool.caps),
                      "swaps": len(runner.pool.swaps),
                      "realloc_rounds": len(runner.pool.cap_log)},
             "tenants": {
                 spec.name: {"n_requests": rep.n_requests,
                             "n_violations": rep.n_violations,
                             "violation_rate": rep.violation_rate,
                             "core_seconds": rep.core_seconds}
                 for spec, rep in zip(runner.specs,
                                      runner.tenant_reports)},
             "tenant_reports": runner.tenant_reports}
    return report, stats


def _token_uncertainty(meta: dict, admission_quantile: Optional[float],
                       speculative: bool):
    """Build the run's shared ``UncertaintyConfig`` (or ``None``).

    One instance is shared by the scaler and the engine so the online
    predictor's calibration error feeds back into the solver's slack.
    ``admission_quantile=None`` takes the scenario default
    (``meta["admission_quantile"]``); ``0.0`` disables the uncertainty
    path entirely — the deterministic-cost baseline.  Scenarios without
    a declared ``decode_dist`` always run deterministic.
    """
    from repro.core.uncertainty import UncertaintyConfig
    dist = meta.get("decode_dist")
    if dist is None:
        return None
    q = admission_quantile
    if q is None:
        q = meta.get("admission_quantile", 0.9)
    if q == 0.0:
        return None
    if not 0.0 < q < 1.0:
        raise ValueError("admission_quantile must be in [0, 1) "
                         f"(0 disables), got {q}")
    return UncertaintyConfig(dist=dist, admission_quantile=q,
                             class_quantiles=meta.get("class_quantiles", ()),
                             speculative=speculative)


def _run_token_scenario(batch: RequestBatch, meta: dict, *, policy: str,
                        engine: str, c_set, b_set, c0: int, tick: float,
                        horizon, budget_quantum: float, lam_quantum: float,
                        token_quantum: int = 16,
                        admission_quantile: Optional[float] = None,
                        speculative: bool = True, **policy_kw):
    """Token-scenario execution: the continuous-batching engines.

    ``engine="fast"`` — :class:`repro.serving.fastpath.TokenFastSimRunner`
    (struct-of-arrays decode streams, the >=100k-request path) with the
    quantized :class:`repro.core.solver.TokenMemoizedSolver`;
    ``engine="exact"`` — the object-based ``ScenarioRunner`` over a
    gang-scheduled :class:`repro.serving.api.TokenSimBackend`.  Only the
    ``sponge`` policy understands token compositions; ask for the real
    kernel path via ``launch/serve.py --engine jax``.

    When the scenario declares a decode-length distribution a fresh
    :class:`repro.core.uncertainty.UncertaintyConfig` is built per run
    (shared between scaler and engine — the calibration feedback loop)
    and its summary lands in ``stats["uncertainty"]``.
    """
    import time
    from repro.core.scaler import TokenSpongeScaler
    from repro.serving.api import ScenarioRunner, TokenSimBackend
    from repro.serving.fastpath import TokenFastSimRunner
    if policy != "sponge":
        raise ValueError(
            f"token scenarios run the sponge policy only (got {policy!r}); "
            "fixed-work baselines cannot see token compositions")
    cost: TokenCostModel = meta["cost"]
    unc = _token_uncertainty(meta, admission_quantile, speculative)
    scaler = TokenSpongeScaler(
        cost, c_set=tuple(c_set), b_set=tuple(b_set),
        adaptation_interval=tick, budget_quantum=budget_quantum,
        lam_quantum=lam_quantum, token_quantum=token_quantum,
        uncertainty=unc, **policy_kw)
    if engine == "fast":
        runner = TokenFastSimRunner(scaler, cost, c_set, b_set, c0=c0,
                                    tick=tick,
                                    prior_rps=meta["expected_rps"],
                                    uncertainty=unc)
        t0 = time.perf_counter()
        report = runner.run(batch, horizon)
        stats = {"engine": "fast", "events": runner.events_processed,
                 "run_wall_s": time.perf_counter() - t0, "meta": meta,
                 "solver": scaler.solver_stats()}
        if unc is not None:
            stats["uncertainty"] = dict(
                unc.stats(), overrun_cancels=runner.overrun_cancels)
        return report, stats
    scaler.budget_quantum = 0.0
    scaler.lam_quantum = 0.0
    scaler.token_quantum = 0
    backend = TokenSimBackend(cost, c_set, b_set, c0=c0, uncertainty=unc)
    runner = ScenarioRunner(scaler, backend, tick=tick)
    runner.monitor.rate.prior_rps = meta["expected_rps"]
    reqs = batch.to_requests()
    t0 = time.perf_counter()
    report = runner.run(reqs, horizon)
    stats = {"engine": "exact", "events": runner.events_processed,
             "run_wall_s": time.perf_counter() - t0, "meta": meta}
    if unc is not None:
        stats["uncertainty"] = dict(
            unc.stats(), overrun_cancels=backend.overrun_cancels)
    return report, stats
