from repro.data.pipeline import synthetic_batches, make_batch
