"""Data pipeline: synthetic token streams (+ modality stubs) with device
placement.  Deterministic per (seed, step) so multi-host shards agree.

A real deployment would substitute a tokenized corpus reader here; the
pipeline interface (iterator of batch dicts matching ``input_specs``) is what
the rest of the framework consumes, and the synthetic generator produces a
learnable distribution (Zipfian unigram + short-range repetition structure)
so the train examples show a genuinely decreasing loss.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig
from repro.train.losses import IGNORE


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Zipf-ish unigram draw, cheap and heavy-tailed."""
    u = rng.random(shape)
    ranks = np.floor(np.exp(u * np.log(vocab))).astype(np.int64)
    return np.clip(ranks - 1, 0, vocab - 1).astype(np.int32)


def make_batch(cfg: ModelConfig, batch: int, seq: int, seed: int,
               with_labels: bool = True) -> dict:
    rng = np.random.default_rng(seed)
    s_text = seq - cfg.num_patch_tokens if cfg.num_patch_tokens else seq
    toks = _zipf_tokens(rng, (batch, s_text), cfg.vocab_size)
    # inject copy structure: second half repeats the first half shifted
    half = s_text // 2
    toks[:, half:half * 2] = toks[:, :half]
    out = {"tokens": toks}
    if with_labels:
        labels = np.concatenate(
            [toks[:, 1:], np.full((batch, 1), IGNORE, np.int32)], axis=1)
        out["labels"] = labels
    if cfg.num_patch_tokens:
        p = cfg.num_patch_tokens
        out["prefix_embeds"] = rng.standard_normal(
            (batch, p, cfg.d_model)).astype(np.float32) * 0.02
        pos = np.arange(seq, dtype=np.int32)
        out["mrope_positions"] = np.broadcast_to(pos, (3, batch, seq)).copy()
        # patches: temporal id frozen at 0, h/w walk a sqrt(p) grid
        side = max(int(np.sqrt(p)), 1)
        hh = (np.arange(p) // side).astype(np.int32)
        ww = (np.arange(p) % side).astype(np.int32)
        out["mrope_positions"][0, :, :p] = 0
        out["mrope_positions"][1, :, :p] = hh
        out["mrope_positions"][2, :, :p] = ww
    if cfg.is_encoder_decoder:
        out["enc_embeds"] = rng.standard_normal(
            (batch, cfg.encoder_seq_len, cfg.d_model)).astype(np.float32) * 0.02
    return out


def synthetic_batches(cfg: ModelConfig, batch: int, seq: int, steps: int,
                      seed: int = 0) -> Iterator[dict]:
    for i in range(steps):
        yield make_batch(cfg, batch, seq, seed * 100003 + i)
