"""Pytree checkpointing on np.savez (no external deps).

Layout: one .npz per checkpoint with flattened path->array entries plus a
metadata json.  Restores to the exemplar pytree's structure and dtypes.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.tree import tree_map_with_path

_SEP = "::"


def _flatten(tree: Any) -> dict:
    out = {}
    tree_map_with_path(lambda p, x: out.__setitem__(p.replace("/", _SEP),
                                                    np.asarray(x)), tree)
    return out


def save_checkpoint(path: str, tree: Any, step: int = 0,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(path, exist_ok=True)
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    flat = _flatten(tree)
    # bfloat16 is not a numpy-native dtype: view as uint16 and tag it
    tagged = {}
    bf16_keys = []
    for k, v in flat.items():
        if v.dtype == jax.numpy.bfloat16:
            tagged[k] = v.view(np.uint16)
            bf16_keys.append(k)
        else:
            tagged[k] = v
    np.savez(fname, **tagged)
    meta = dict(metadata or {})
    meta.update({"step": step, "bf16_keys": bf16_keys})
    with open(fname + ".json", "w") as f:
        json.dump(meta, f)
    return fname


def latest_checkpoint(path: str) -> Optional[str]:
    if not os.path.isdir(path):
        return None
    cks = sorted(f for f in os.listdir(path)
                 if re.match(r"ckpt_\d+\.npz$", f))
    return os.path.join(path, cks[-1]) if cks else None


def restore_checkpoint(fname: str, exemplar: Any) -> tuple[Any, dict]:
    """Restore into the structure of ``exemplar`` (shape pytree ok)."""
    with open(fname + ".json") as f:
        meta = json.load(f)
    bf16 = set(meta.get("bf16_keys", []))
    data = np.load(fname)

    def fn(path, x):
        key = path.replace("/", _SEP)
        arr = data[key]
        if key in bf16:
            arr = arr.view(jax.numpy.bfloat16)
        assert arr.shape == tuple(x.shape), (key, arr.shape, x.shape)
        return jax.numpy.asarray(arr, dtype=x.dtype)

    return tree_map_with_path(fn, exemplar), meta
