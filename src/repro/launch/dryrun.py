import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST run before any other import initializes jax (device count locks at
#   first init).  Only dryrun.py gets 512 placeholder devices; tests and
#   benches see the single real CPU device.

# Multi-pod dry-run: lower + compile every (arch x input shape) on the
# production meshes and record memory/cost/roofline.
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
#         --shape train_4k [--multi-pod] [--out experiments/dryrun]
#     PYTHONPATH=src python -m repro.launch.dryrun --all
#
# Decode shapes lower ``serve_step`` (one token against a full-size cache);
# prefill lowers ``prefill``; train lowers ``train_step`` (fwd+bwd+AdamW).
# long_500k runs only for the sub-quadratic archs (DESIGN.md §4 skip list).
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import chips, make_production_mesh, mesh_name
from repro.models import build_model, input_specs
from repro.models.api import init_cache, init_params
from repro.models.sharding import (batch_specs, cache_specs, param_specs,
                                   shardings)
from repro.train.loop import make_train_step
from repro.train.optimizer import OptConfig, adamw_init
from repro.utils import roofline as rf

# long_500k runs only for bounded-state archs (DESIGN.md §4)
LONG_OK = {"zamba2-2.7b", "rwkv6-1.6b", "h2o-danube-1.8b"}
# the MoE giants need bf16 optimizer moments to have any chance of fitting
BF16_MOMENT_ARCHS = {"deepseek-v3-671b", "kimi-k2-1t-a32b"}


def applicable(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k" and arch not in LONG_OK:
        return False
    return True


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    per_tok = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[shape.kind]
    return per_tok * n_active * tokens


def build_step(arch: str, shape: InputShape, mesh, opt: str = "baseline"):
    """Returns (fn, arg_shapes).  opt: baseline | tuned.

    "tuned" applies the beyond-paper optimizations from EXPERIMENTS.md
    §Perf: serving params without FSDP gathers, partial-sum EP for MoE
    decode, batch-parallel attention for small-head archs."""
    import dataclasses as _dc
    cfg = get_config(arch)
    serving_fsdp = True
    if opt == "tuned":
        if shape.kind == "decode":
            serving_fsdp = False
        # small models: ZeRO-3 buys nothing (state fits replicated over
        # data) and costs per-layer gathers — §Perf pair-1 iteration 3
        if shape.kind == "train" and cfg.param_count() < 1e9:
            serving_fsdp = False
        if cfg.uses_moe:
            cfg = _dc.replace(cfg, moe_partial_ep=True)
        if (cfg.num_heads * cfg.head_dim) % 16 != 0 or cfg.num_heads < 16 \
                or cfg.num_kv_heads < 16:
            cfg = _dc.replace(cfg, attn_batch_parallel=True)
        if "rwkv6" in cfg.mixer_kinds:
            cfg = _dc.replace(cfg, rwkv_chunked=True)
    if arch in BF16_MOMENT_ARCHS:
        oc = OptConfig(moment_dtype="bfloat16")
    else:
        oc = OptConfig()
    model = build_model(cfg, mesh=mesh)

    params_shape = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))
    pspecs = param_specs(params_shape, mesh, fsdp=serving_fsdp)
    pshard = shardings(pspecs, mesh)

    batch_shape = input_specs(cfg, shape)
    bspecs = batch_specs(batch_shape, mesh)
    bshard = shardings(bspecs, mesh)

    if shape.kind == "train":
        state_shape = {
            "params": params_shape,
            "opt": jax.eval_shape(lambda p: adamw_init(p, oc), params_shape),
        }
        sspecs = {
            "params": pspecs,
            "opt": {"mu": pspecs, "nu": pspecs,
                    "step": jax.sharding.PartitionSpec()},
        }
        sshard = shardings(sspecs, mesh)
        step = make_train_step(model, oc)
        fn = jax.jit(step, in_shardings=(sshard, bshard),
                     donate_argnums=(0,))
        return fn, (state_shape, batch_shape)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return model.prefill(params, batch)
        fn = jax.jit(prefill_fn, in_shardings=(pshard, bshard))
        return fn, (params_shape, batch_shape)

    # decode: one token against a full-length cache
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    cspecs = cache_specs(cache_shape, mesh, seq_shard=(opt == "tuned"))
    cshard = shardings(cspecs, mesh)

    def serve_step(params, cache, batch):
        mp = batch.get("mrope_positions")
        return model.decode_step(params, cache, batch["token"],
                                 mrope_positions=mp)

    fn = jax.jit(serve_step, in_shardings=(pshard, cshard, bshard),
                 donate_argnums=(1,))
    return fn, (params_shape, cache_shape, batch_shape)


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str | None = None, verbose: bool = True,
            opt: str = "baseline") -> dict:
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mname = mesh_name(mesh) if opt == "baseline" else \
        f"{mesh_name(mesh)}-{opt}"
    t0 = time.perf_counter()
    with mesh:
        fn, args = build_step(arch, shape, mesh, opt=opt)
        lowered = fn.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    cfg = get_config(arch)
    roof = rf.analyze(arch, shape_name, mname, chips(mesh),
                      cost or {}, hlo, model_flops(cfg, shape),
                      memory_analysis=mem)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mname,
        "chips": chips(mesh),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "ok": True,
        "roofline": json.loads(roof.to_json()),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} @ {mname}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory_analysis: {rec['roofline']['memory_analysis']}")
        print(f"  cost_analysis: flops/chip={roof.flops_per_chip:.3e} "
              f"bytes/chip={roof.bytes_per_chip:.3e}")
        print(f"  collectives: {rec['roofline']['collectives']}")
        print(f"  roofline: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"dominant={roof.dominant} useful={roof.useful_ratio:.2f}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = os.path.join(out_dir, f"{arch}_{shape_name}_{mname}.json")
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", default="baseline",
                    choices=("baseline", "tuned"))
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for arch in list_archs():
            for shape in INPUT_SHAPES:
                if applicable(arch, shape):
                    if args.both_meshes:
                        combos.append((arch, shape, False))
                        combos.append((arch, shape, True))
                    else:
                        combos.append((arch, shape, args.multi_pod))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        combos = [(args.arch, args.shape, args.multi_pod)]

    failures = []
    for arch, shape, mp in combos:
        try:
            run_one(arch, shape, mp, out_dir=args.out, opt=args.opt)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, mp, repr(e)))
            print(f"[dryrun] {arch} x {shape} multi_pod={mp}: FAIL {e}")
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print(f"[dryrun] all {len(combos)} combos OK")


if __name__ == "__main__":
    main()
