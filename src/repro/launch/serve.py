"""Serving launcher: Sponge end-to-end.

Two modes:

* ``--mode live`` — real JAX inference (reduced arch) behind the Sponge
  control plane: EDF queue, dynamic batching, IP-solver scaler, executable
  table.  This is the paper's Fig. 2 pipeline with an actual model.
* ``--mode sim``  — the trace-driven discrete-event study (Fig. 4):
  Sponge vs FA2 vs static 8/16 under a 4G bandwidth trace.

    PYTHONPATH=src python -m repro.launch.serve --mode live \
        --arch smollm-135m-reduced --rps 10 --duration 10
    PYTHONPATH=src python -m repro.launch.serve --mode sim --duration 600
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.core.baselines import FA2Policy, SpongePolicy, StaticPolicy
from repro.core.perf_model import PerfModel, yolov5s_like
from repro.core.scaler import SpongeScaler
from repro.core.slo import Request
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.network.traces import synth_4g_trace
from repro.serving.simulator import ClusterSimulator
from repro.serving.workload import WorkloadGenerator


def run_sim(args) -> dict:
    perf = yolov5s_like()
    trace = synth_4g_trace(args.duration, seed=args.seed)
    wl = WorkloadGenerator(rps=args.rps, slo=args.slo, size_kb=args.size_kb)

    def run(policy, c0=1):
        sim = ClusterSimulator(perf, policy, DEFAULT_C, DEFAULT_B, c0=c0)
        sim.monitor.rate.prior_rps = args.rps
        return sim.run(wl.generate(trace))

    out = {}
    out["sponge"] = run(SpongePolicy(SpongeScaler(perf)), c0=16)
    out["fa2"] = run(FA2Policy(perf, slo=args.slo, expected_rps=args.rps))
    out["static-8"] = run(StaticPolicy(perf, cores=8), c0=8)
    out["static-16"] = run(StaticPolicy(perf, cores=16), c0=16)
    for k, v in out.items():
        print(f"{k:10s} violations={v['violation_rate']*100:6.2f}%  "
              f"avg_cores={v['avg_cores']:6.2f}  p99={v['p99']:.3f}s")
    sp, fa = out["sponge"], out["fa2"]
    print(f"SLO-violation reduction vs FA2: "
          f"{fa['violation_rate']/max(sp['violation_rate'],1e-9):.1f}x "
          f"(paper: >15x)")
    print(f"CPU reduction vs static-16: "
          f"{100*(1-sp['avg_cores']/out['static-16']['avg_cores']):.1f}% "
          f"(paper: >20%)")
    return out


def run_live(args) -> dict:
    import jax
    from repro.models import build_model
    from repro.serving.engine import (ServingEngine, build_llm_step_fns,
                                      pad_tokens)

    cfg = get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    prompt = args.prompt_len
    c_set, b_set = (1, 2, 4, 8), (1, 2, 4, 8)
    fns = build_llm_step_fns(model, params, c_set, b_set, prompt,
                             gen_tokens=args.gen_tokens)

    # profile the executable table to calibrate the perf model
    import time as _t
    samples = []
    for (c, b), fn in fns.items():
        x = np.ones((b, prompt), np.int32)
        fn(x)
        t0 = _t.perf_counter()
        jax.block_until_ready(fn(x))
        samples.append((b, c, _t.perf_counter() - t0))
    perf = PerfModel.fit(samples, robust=False)
    print(f"calibrated perf model: r2={perf.r2:.3f} "
          f"l(1,1)={perf.latency(1,1)*1e3:.1f}ms")

    scaler = SpongeScaler(perf, c_set=c_set, b_set=b_set,
                          adaptation_interval=0.5)
    eng = ServingEngine(fns, scaler, pad_tokens, prior_rps=args.rps)
    eng.warmup(np.ones(prompt, np.int32))

    trace = synth_4g_trace(int(args.duration) + 5, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    arrivals = []
    n = int(args.rps * args.duration)
    from repro.network.latency import comm_latency
    for i in range(n):
        ts = i / args.rps
        cl = comm_latency(args.size_kb, trace, ts)
        req = Request.make(arrival=ts + cl, comm_latency=cl, slo=args.slo)
        arrivals.append((req, rng.integers(
            0, cfg.vocab_size, prompt).astype(np.int32)))
    res = eng.run_script(arrivals)
    print(json.dumps(res, indent=1, default=float))
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("sim", "live"), default="sim")
    ap.add_argument("--arch", default="smollm-135m-reduced")
    ap.add_argument("--rps", type=float, default=20.0)
    ap.add_argument("--slo", type=float, default=1.0)
    ap.add_argument("--size-kb", type=float, default=200.0)
    ap.add_argument("--duration", type=float, default=600.0)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args(argv)
    if args.mode == "sim":
        run_sim(args)
    else:
        run_live(args)


if __name__ == "__main__":
    main()
