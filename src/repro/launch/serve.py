"""Serving launcher: Sponge end-to-end through the unified serving API.

Three modes, one control plane (``repro.serving.api.SpongeServer``):

* ``--mode live`` — real JAX inference (reduced arch, resolved through
  ``configs.registry``) behind the Sponge control plane: EDF queue, dynamic
  batching, IP-solver scaler, executable table.  This is the paper's
  Fig. 2 pipeline with an actual model.  ``--policy fa2`` exercises the
  multi-instance live path (horizontal one-core replicas over the same
  executable table).
* ``--mode sim``  — the trace-driven discrete-event study (Fig. 4):
  Sponge vs FA2 vs static 8/16 under a 4G bandwidth trace.
* ``--scenario <name>`` — run a registered workload scenario
  (``repro.serving.scenarios``; see ``docs/scenarios.md``) through the
  million-request fast engine (or ``--engine exact`` for the object-based
  loop).  ``--requests N`` sizes the run by request count instead of
  duration.  Token scenarios (``llm-chat``, ``llm-mixed-len``) run the
  continuous-batching engines and report tokens/s, TTFT p99 and the
  per-token violation rate; ``--engine jax`` serves a slice of them on
  the **real Pallas kernels** (swa_prefill + decode_attention) through
  ``repro.serving.token_backend.TokenJaxBackend``.  Fleet scenarios
  (``replica-failure``, ``rolling-restart``, ``fleet-flash-crowd``) run
  the joint horizontal + vertical engines (``repro.serving.fleet``);
  ``--replicas`` sizes the deploy-time fleet and ``--router`` picks the
  arrival router (``least-loaded`` / ``jsq`` / ``edf-deadline``).
  Degradation scenarios (``degrade-sustained-overload``,
  ``degrade-flash-overload``, ``degrade-fade-overload``) run the
  (m, n, c, b) planner over a model ladder; ``--model-ladder`` attaches
  (or overrides) the ladder, ``--accuracy-floor`` bounds the shed and
  ``--policy fixed-<arch>`` pins one rung (the fixed-model baseline).
  Multi-tenant scenarios (``mixed-zoo``, ``mixed-zoo-rush``) run the
  shared-pool engines (``repro.serving.tenancy``); ``--tenants`` picks
  the pool reallocation policy and ``--pool-cores`` the core budget.

    PYTHONPATH=src python -m repro.launch.serve --mode live \
        --arch smollm-135m-reduced --rps 10 --duration 10
    PYTHONPATH=src python -m repro.launch.serve --mode sim --duration 600
    PYTHONPATH=src python -m repro.launch.serve --scenario flash-crowd
    PYTHONPATH=src python -m repro.launch.serve --scenario steady \
        --requests 1000000
    PYTHONPATH=src python -m repro.launch.serve --scenario llm-chat \
        --requests 100000
    PYTHONPATH=src python -m repro.launch.serve --scenario llm-chat \
        --engine jax --requests 24
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.perf_model import yolov5s_like
from repro.core.slo import Request
from repro.network.latency import comm_latency
from repro.network.traces import synth_4g_trace
from repro.serving.api import make_live_server, make_sim_server
from repro.serving.workload import WorkloadGenerator

SIM_POLICIES = (("sponge", dict(c0=16)),
                ("fa2", dict(c0=1)),
                ("static-8", dict(c0=8)),
                ("static-16", dict(c0=16)))


def run_sim(args) -> dict:
    perf = yolov5s_like()
    trace = synth_4g_trace(args.duration, seed=args.seed)
    wl = WorkloadGenerator(rps=args.rps, slo=args.slo, size_kb=args.size_kb)

    out = {}
    for name, kw in SIM_POLICIES:
        server = make_sim_server(perf, name, prior_rps=args.rps,
                                 slo=args.slo, expected_rps=args.rps, **kw)
        out[name] = server.serve(wl, trace)
    for k, v in out.items():
        print(f"{k:10s} violations={v['violation_rate']*100:6.2f}%  "
              f"avg_cores={v['avg_cores']:6.2f}  p99={v['p99']:.3f}s")
    sp, fa = out["sponge"], out["fa2"]
    print("SLO-violation reduction vs FA2: "
          f"{fa['violation_rate']/max(sp['violation_rate'],1e-9):.1f}x "
          "(paper: >15x)")
    print("CPU reduction vs static-16: "
          f"{100*(1-sp['avg_cores']/out['static-16']['avg_cores']):.1f}% "
          "(paper: >20%)")
    return out


def run_live(args) -> dict:
    c_set, b_set = (1, 2, 4, 8), (1, 2, 4, 8)
    server, cfg = make_live_server(
        args.arch, c_set=c_set, b_set=b_set, prompt_len=args.prompt_len,
        gen_tokens=args.gen_tokens, policy=args.policy,
        adaptation_interval=0.5, prior_rps=args.rps, slo=args.slo,
        expected_rps=args.rps)
    perf = server.backend.perf
    print(f"calibrated perf model: r2={perf.r2:.3f} "
          f"l(1,1)={perf.latency(1,1)*1e3:.1f}ms")
    server.warmup(np.ones(args.prompt_len, np.int32))

    trace = synth_4g_trace(int(args.duration) + 5, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    arrivals = []
    for i in range(int(args.rps * args.duration)):
        ts = i / args.rps
        cl = comm_latency(args.size_kb, trace, ts)
        req = Request.make(arrival=ts + cl, comm_latency=cl, slo=args.slo)
        arrivals.append((req, rng.integers(
            0, cfg.vocab_size, args.prompt_len).astype(np.int32)))
    report = server.run(arrivals, horizon=args.duration + 30)
    res = {"n": report.n_requests, "violations": report.n_violations,
           "violation_rate": report.violation_rate,
           "p50": report.p50, "p99": report.p99,
           "decisions": len(report.decisions or ()),
           "instances": len(server.pool)}
    print(json.dumps(res, indent=1, default=float))
    return res


def run_scenario_mode(args) -> dict:
    q = args.admission_quantile
    if q is not None and not (q == 0.0 or 0.0 < q < 1.0):
        raise SystemExit("--admission-quantile must be in [0, 1) "
                         f"(0 disables the uncertainty path), got {q}")
    if args.engine == "jax":
        if q is not None or args.no_speculative:
            raise SystemExit("--admission-quantile/--no-speculative run "
                             "on the fast/exact token engines, not "
                             "--engine jax")
        from repro.serving.token_backend import run_token_jax_scenario
        if args.policy != "sponge":
            raise SystemExit("--engine jax runs the sponge policy only "
                             f"(got --policy {args.policy!r})")
        if args.duration is not None:
            raise SystemExit("--engine jax sizes the run by --requests, "
                             "not --duration")
        report, stats = run_token_jax_scenario(
            args.scenario, requests=args.requests or 24, seed=args.seed,
            arch=args.arch, prompt_len=args.prompt_len,
            max_decode=args.gen_tokens, rps=args.rps)
    else:
        from repro.serving.scenarios import run_scenario
        report, stats = run_scenario(
            args.scenario, policy=args.policy, engine=args.engine,
            duration=args.duration, rps=args.rps,
            seed=args.seed, requests=args.requests,
            replicas=args.replicas, router=args.router,
            tenant_policy=args.tenants, pool_cores=args.pool_cores,
            mid_flight=not args.no_mid_flight,
            admission_quantile=args.admission_quantile,
            speculative=not args.no_speculative,
            model_ladder=args.model_ladder,
            accuracy_floor=args.accuracy_floor)
    ev = stats["events"]
    dt = stats["run_wall_s"]            # engine time only (no generation)
    out = {"scenario": args.scenario, "engine": stats["engine"],
           "policy": report.policy, "n": report.n_requests,
           "violation_rate": report.violation_rate,
           "p50": report.p50, "p99": report.p99,
           "avg_cores": report.avg_cores,
           "events": ev, "events_per_s": ev / max(dt, 1e-9),
           "wall_s": dt}
    if report.tokens_served:            # token scenarios: the ISSUE-3 bar
        out.update(tokens_served=report.tokens_served,
                   tokens_per_s=report.tokens_per_s,
                   ttft_p50=report.ttft_p50, ttft_p99=report.ttft_p99,
                   tbt_violation_rate=report.tbt_violation_rate)
    if "max_replicas" in stats:         # fleet scenarios: the ISSUE-4 bar
        out.update(max_replicas=stats["max_replicas"],
                   router=stats["router"])
    if "ladder" in stats:               # degradation runs: the ISSUE-9 bar
        out.update(core_seconds=report.core_seconds,
                   ladder=stats["ladder"],
                   accuracy_floor=stats["accuracy_floor"],
                   accuracy_goodput=report.accuracy_goodput,
                   mean_served_accuracy=report.mean_served_accuracy,
                   model_swaps=report.model_swaps)
    if "session" in stats:              # session scenarios: the ISSUE-5 bar
        out.update(n_cancelled=report.n_cancelled, **{
            f"mid_flight_{k}": v for k, v in stats["session"].items()})
    if "pool" in stats:                 # multi-tenant scenarios: ISSUE-6
        p = stats["pool"]
        out.update(pool_policy=p["policy"], pool_cores=p["budget"],
                   pool_caps=list(p["caps"]), pool_swaps=p["swaps"],
                   tenants={name: {"n": t["n_requests"],
                                   "violation_rate": t["violation_rate"],
                                   "core_seconds": t["core_seconds"]}
                            for name, t in stats["tenants"].items()})
    if "uncertainty" in stats:          # distribution-aware runs: ISSUE-7
        u = stats["uncertainty"]
        out.update(n_cancelled=report.n_cancelled,
                   admission_quantile=u["quantile"],
                   slack_factor=u["slack_factor"],
                   calibration_error=u["calibration_error"],
                   overrun_cancels=u["overrun_cancels"])
    if "solver" in stats:
        out["solver_hit_rate"] = stats["solver"].get("hit_rate")
    print(json.dumps(out, indent=1, default=float))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("sim", "live", "scenario"),
                    default="sim")
    try:
        from repro.serving.scenarios import list_scenarios
        # argparse %-formats help text: escape literal percent signs
        scenario_help = "; ".join(f"{k}: {v}"
                                  for k, v in list_scenarios().items()
                                  ).replace("%", "%%")
    except Exception:                               # pragma: no cover
        scenario_help = "registered workload scenario"
    ap.add_argument("--scenario", default=None,
                    help=f"run a registered scenario ({scenario_help})")
    ap.add_argument("--engine", choices=("fast", "exact", "vector", "jax"),
                    default="fast",
                    help="scenario mode: struct-of-arrays fast engine, "
                         "the object-based exact loop, the batched-tick "
                         "vectorpath (plain scenarios; docs/performance.md), "
                         "or (token scenarios) the real-kernel "
                         "TokenJaxBackend")
    ap.add_argument("--requests", type=int, default=None,
                    help="scenario mode: size the run by request count")
    ap.add_argument("--replicas", type=int, default=None,
                    help="fleet scenarios: deploy-time replica count "
                         "(overrides the scenario's n0)")
    ap.add_argument("--router", default=None,
                    choices=("least-loaded", "jsq", "edf-deadline"),
                    help="fleet scenarios: arrival router across replicas")
    ap.add_argument("--tenants", default=None,
                    choices=("priority", "fair-share", "greedy-marginal"),
                    help="multi-tenant scenarios (mixed-zoo*): the pool "
                         "reallocation policy (default greedy-marginal)")
    ap.add_argument("--pool-cores", type=int, default=None,
                    help="multi-tenant scenarios: total core budget of "
                         "the shared pool (default: the scenario's, 128)")
    ap.add_argument("--no-mid-flight", action="store_true",
                    help="session scenarios: suppress the mid-flight "
                         "update_slo/cancel stream (the closed-world "
                         "replay of the same workload)")
    ap.add_argument("--admission-quantile", type=float, default=None,
                    help="token scenarios with a declared decode-length "
                         "distribution: plan admission at this quantile "
                         "(0 disables the uncertainty path — the "
                         "deterministic-cost baseline; default: the "
                         "scenario's own quantile)")
    ap.add_argument("--model-ladder", default=None,
                    help="fleet scenarios: attach a model ladder and run "
                         "the (m, n, c, b) planner — 'default', 'full' or "
                         "a comma-separated registry arch list (degrade-* "
                         "scenarios carry 'default' already); "
                         "--policy fixed-<arch> pins one rung")
    ap.add_argument("--accuracy-floor", type=float, default=None,
                    help="ladder runs: never shed below this accuracy "
                         "score (default: the scenario's own floor, 0.60 "
                         "for the degrade-* family)")
    ap.add_argument("--no-speculative", action="store_true",
                    help="distribution-aware runs: disable speculative "
                         "over-admission with cancel-on-overrun (streams "
                         "run to completion; the solver still plans at "
                         "the admission quantile)")
    ap.add_argument("--arch", default="smollm-135m-reduced")
    ap.add_argument("--policy", default="sponge")
    # None = "use the mode's default" (scenarios carry their own rps /
    # duration defaults; sim/live keep the historical 20 rps / 600 s)
    ap.add_argument("--rps", type=float, default=None)
    ap.add_argument("--slo", type=float, default=1.0)
    ap.add_argument("--size-kb", type=float, default=200.0)
    ap.add_argument("--duration", type=float, default=None)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args(argv)
    if args.scenario or args.mode == "scenario":
        if not args.scenario:
            ap.error("--mode scenario requires --scenario <name>")
        run_scenario_mode(args)
        return
    args.rps = 20.0 if args.rps is None else args.rps
    args.duration = 600.0 if args.duration is None else args.duration
    if args.mode == "sim":
        run_sim(args)
    else:
        run_live(args)


if __name__ == "__main__":
    main()
