"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m-reduced \
        --steps 100 --batch 8 --seq 128 [--ckpt out/ckpt]

Full (non-reduced) archs run on the production mesh via the same code path
(they only fit real hardware; on this CPU container use reduced configs).
"""
from __future__ import annotations

import argparse


from repro.configs import get_config
from repro.data import synthetic_batches
from repro.models import build_model
from repro.train.loop import train_loop
from repro.train.optimizer import OptConfig
from repro.checkpoint import save_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    model = build_model(cfg)
    oc = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                   total_steps=args.steps)
    batches = synthetic_batches(cfg, args.batch, args.seq, args.steps)

    def log(m):
        print(f"step {m['step']:5d} loss {m['loss']:.4f} "
              f"ce {m['ce']:.4f} gnorm {m['grad_norm']:.2f} "
              f"lr {m['lr']:.2e} t {m['wall_s']:.1f}s", flush=True)

    state, history = train_loop(model, batches, oc,
                                log_every=args.log_every, callback=log)
    if args.ckpt:
        f = save_checkpoint(args.ckpt, state["params"], step=args.steps,
                            metadata={"arch": args.arch})
        print("checkpoint:", f)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
