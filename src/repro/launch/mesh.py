"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — dryrun.py must set XLA_FLAGS before any jax
device initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (TPU v5e pod slice); 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_small_mesh(data: int = 2, model: int = 2):
    """Test mesh for CI-scale integration tests (8 fake devices or fewer)."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def chips(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n
