"""Decode-length uncertainty: distributions, quantile admission, and the
online length predictor (ROADMAP open item 4 — Orloj/Vortex).

Sponge's IP formulation assumes a deterministic latency model: every
request declares its decode length and the solver plans slot turnover
from the cost model's mean.  Real LLM traffic does not work like that —
decode lengths are unknown at admission and heavy-tailed, so a
deterministic-cost scheduler either under-provisions (the tail blows
every TBT/TTFT budget) or over-provisions for a worst case that almost
never happens.  This module makes execution time a *distribution*:

* :class:`LengthDistribution` — the protocol (``mean`` / ``quantile`` /
  ``cdf`` / ``sample``), with :class:`PointMass`,
  :class:`EmpiricalLengths`, :class:`LognormalLengths` and
  :class:`MixtureLengths` variants.  Quantiles follow the standard
  inverse-CDF convention: ``quantile(q)`` is the smallest supported
  length ``v`` with ``cdf(v) >= q``, so ``P(X > quantile(q)) <= 1 - q``
  — the conservativeness the admission property test holds us to.
* :class:`LengthPredictor` — an online calibration tracker: the engine
  reports ``(predicted, actual)`` length pairs as streams finish (or
  overrun), and the predictor's running calibration error widens or
  narrows the solver's slack multiplicatively (monotonically — more
  error never shrinks slack).  A prior error keeps early slack wide and
  lets *good* calibration narrow it as evidence accumulates, the same
  prior-blend idiom as ``repro.core.monitor.RateEstimator``.
* :class:`UncertaintyConfig` — the knob bundle one run shares between
  the scaler and the engine: the declared distribution, the admission
  quantile (per SLO class via ``class_quantiles``), the speculation
  switch, and the predictor instance (shared so the engine's
  observations feed the solver's slack — the feedback loop).

**Point-mass reduction.**  A point-mass distribution means lengths are
known exactly — the deterministic world every pre-uncertainty code path
lives in.  Whenever ``UncertaintyConfig.is_point()`` holds (no config,
no distribution, or ``dist.is_point()``), the scaler and both token
engines take their original code paths *verbatim*: same solver inputs,
same admission order, same event stream, bit-identical decisions.  This
is the same guarantee pattern as ``FixedWorkCostModel``'s delegation
and the token columns' 1/0/inf defaults.

Semantics under a real distribution:

* **quantile admission** (solver path): ``TokenSpongeScaler`` plans
  slot-turnover drag at ``dist.quantile(admission_quantile)`` instead
  of the cost model's mean, and widens its TTFT headroom by the
  predictor's slack factor — admit iff the p-quantile completion
  estimate meets the deadline.
* **speculative over-admission + cancel-on-overrun** (engine path):
  streams are admitted greedily (optimistically) but each carries a
  token budget ``ceil(quantile(q_class) * margin * slack)``; a stream
  that exhausts its budget before finishing is cancelled at the step
  boundary through PR 5's cancellation machinery (``Monitor.
  observe_cancel`` on the exact engine, the ``_cxl`` λ-retraction list
  on the fast engine), freeing its decode slot for waiting requests.
  Overrun cancels count in ``RunReport.n_cancelled`` and are excluded
  from every latency/violation aggregate.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Protocol, Tuple, runtime_checkable

import numpy as np

__all__ = [
    "EmpiricalLengths", "LengthDistribution", "LengthPredictor",
    "LognormalLengths", "MixtureLengths", "PointMass",
    "UncertaintyConfig",
]


@runtime_checkable
class LengthDistribution(Protocol):
    """A distribution over decode lengths (positive integer tokens)."""

    def mean(self) -> float: ...

    def cdf(self, x: float) -> float: ...

    def quantile(self, q: float) -> float: ...

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray: ...

    def is_point(self) -> bool: ...


def _check_q(q: float) -> float:
    if not 0.0 < q < 1.0:
        raise ValueError(f"quantile must be in (0, 1), got {q}")
    return float(q)


@dataclass(frozen=True)
class PointMass:
    """Degenerate distribution: the length is known exactly.

    Attaching a point mass is *declaring determinism* — every
    uncertainty-aware code path reduces to the deterministic engine
    verbatim (see the module docstring's point-mass reduction).
    """
    value: float

    def mean(self) -> float:
        return float(self.value)

    def cdf(self, x: float) -> float:
        return 1.0 if x >= self.value else 0.0

    def quantile(self, q: float) -> float:
        _check_q(q)
        return float(self.value)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        return np.full(n, self.value, np.float64)

    def is_point(self) -> bool:
        return True


@dataclass(frozen=True)
class EmpiricalLengths:
    """The empirical distribution of an observed length sample
    (e.g. yesterday's production decode lengths)."""
    samples: Tuple[float, ...]

    def __post_init__(self):
        if not self.samples:
            raise ValueError("EmpiricalLengths needs at least one sample")
        object.__setattr__(self, "samples",
                           tuple(sorted(float(s) for s in self.samples)))

    @classmethod
    def from_array(cls, a) -> "EmpiricalLengths":
        return cls(tuple(np.asarray(a, np.float64).tolist()))

    def mean(self) -> float:
        return float(sum(self.samples) / len(self.samples))

    def cdf(self, x: float) -> float:
        import bisect
        return bisect.bisect_right(self.samples, x) / len(self.samples)

    def quantile(self, q: float) -> float:
        _check_q(q)
        n = len(self.samples)
        # smallest order statistic with cdf >= q
        k = min(max(int(math.ceil(q * n)), 1), n) - 1
        return float(self.samples[k])

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        idx = rng.integers(0, len(self.samples), size=n)
        return np.asarray(self.samples, np.float64)[idx]

    def is_point(self) -> bool:
        return self.samples[0] == self.samples[-1]


@dataclass(frozen=True)
class LognormalLengths:
    """Bounded log-normal lengths — the same parameterization as the
    workload generator's ``lognormal_lengths`` (``median = exp(mu)``,
    samples rounded and clipped to ``[lo, hi]``), so a scenario can
    declare exactly the distribution it draws from."""
    median: float
    sigma: float
    lo: int = 1
    hi: int = 1 << 20

    def __post_init__(self):
        if self.median <= 0 or self.sigma < 0:
            raise ValueError("median must be > 0 and sigma >= 0")
        if self.lo > self.hi:
            raise ValueError("lo must be <= hi")

    def mean(self) -> float:
        if self.sigma == 0:
            return float(min(max(self.median, self.lo), self.hi))
        # clipped mean via sampling-free moment formula would ignore the
        # clip; integrate the clipped variable over the integer support
        # only when the bounds actually bite, else use the closed form
        m = self.median * math.exp(0.5 * self.sigma ** 2)
        if self.cdf(self.hi - 1) > 0.999 and self.lo <= 1:
            return float(m)
        # coarse but deterministic: expectation over the clipped CDF
        xs = np.arange(self.lo, self.hi + 1, dtype=np.float64)
        if xs.size > 200_000:                      # keep it bounded
            xs = np.linspace(self.lo, self.hi, 200_000)
        cdf = self._cdf_arr(xs)
        pmf = np.diff(np.concatenate([[0.0], cdf]))
        pmf[-1] += 1.0 - cdf[-1]
        return float((xs * pmf).sum())

    def _cdf_arr(self, x: np.ndarray) -> np.ndarray:
        z = (np.log(np.maximum(x + 0.5, 1e-300))
             - math.log(self.median)) / max(self.sigma, 1e-12)
        return 0.5 * (1.0 + np.vectorize(math.erf)(z / math.sqrt(2.0)))

    def cdf(self, x: float) -> float:
        # the generator rounds then clips, so mass below lo sits at lo
        # and mass above hi sits at hi
        if x < self.lo:
            return 0.0
        if x >= self.hi:
            return 1.0
        if self.sigma == 0:
            return 1.0 if x >= self.median else 0.0
        z = (math.log(x + 0.5) - math.log(self.median)) / self.sigma
        return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))

    def quantile(self, q: float) -> float:
        _check_q(q)
        lo, hi = int(self.lo), int(self.hi)
        # integer bisection for the smallest v with cdf(v) >= q — exact
        # under the declared (rounded, clipped) sampling scheme
        while lo < hi:
            mid = (lo + hi) // 2
            if self.cdf(mid) >= q:
                hi = mid
            else:
                lo = mid + 1
        return float(lo)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        x = rng.lognormal(mean=math.log(self.median), sigma=self.sigma,
                          size=n)
        return np.clip(np.round(x), self.lo, self.hi).astype(np.float64)

    def is_point(self) -> bool:
        return self.sigma == 0.0 or self.lo == self.hi


@dataclass(frozen=True)
class MixtureLengths:
    """A finite mixture of length distributions (e.g. short chat
    answers + long retrieval-augmented generations)."""
    components: Tuple[LengthDistribution, ...]
    weights: Tuple[float, ...]

    def __post_init__(self):
        if len(self.components) != len(self.weights) or not self.components:
            raise ValueError("components and weights must align (>= 1)")
        w = tuple(float(x) for x in self.weights)
        if any(x < 0 for x in w) or sum(w) <= 0:
            raise ValueError("weights must be non-negative, sum > 0")
        total = sum(w)
        object.__setattr__(self, "weights", tuple(x / total for x in w))

    def mean(self) -> float:
        return float(sum(w * c.mean()
                         for w, c in zip(self.weights, self.components)))

    def cdf(self, x: float) -> float:
        return float(sum(w * c.cdf(x)
                         for w, c in zip(self.weights, self.components)))

    def quantile(self, q: float) -> float:
        _check_q(q)
        # bisect over the integer support spanned by the components
        lo = int(min(c.quantile(1e-9) if not isinstance(c, PointMass)
                     else c.value for c in self.components))
        hi = int(math.ceil(max(c.quantile(1.0 - 1e-12)
                               if not isinstance(c, PointMass)
                               else c.value for c in self.components)))
        lo = max(lo, 0)
        while lo < hi:
            mid = (lo + hi) // 2
            if self.cdf(mid) >= q:
                hi = mid
            else:
                lo = mid + 1
        return float(lo)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        choice = rng.choice(len(self.components), size=n, p=self.weights)
        out = np.empty(n, np.float64)
        for k, c in enumerate(self.components):
            mask = choice == k
            cnt = int(mask.sum())
            if cnt:
                out[mask] = c.sample(rng, cnt)
        return out

    def is_point(self) -> bool:
        if not all(c.is_point() for c in self.components):
            return False
        vals = {c.quantile(0.5) if not isinstance(c, PointMass)
                else c.value for c in self.components}
        return len(vals) == 1


class LengthPredictor:
    """Online quantile-coverage calibration → solver slack.

    The engine calls :meth:`observe` with the length it *planned for*
    (the admission-quantile estimate), the length the stream
    *realized*, and the tail mass the plan promised (``1 - q``), as
    streams finish or overrun.  If the declared distribution is
    correct, the fraction of streams exceeding the planned quantile
    converges to exactly that tail mass — :meth:`calibration_error` is
    the *excess* overrun fraction (``max(0, observed - promised)``)
    over the last ``window`` observations, blended with
    ``prior_error`` while the window fills (the ``RateEstimator``
    prior idiom — early slack stays wide, sustained good calibration
    narrows it toward 1).  A distribution whose tail is *declared too
    thin* overruns more often than promised, the error grows, and
    :meth:`slack_factor` widens the solver's plans; an over-pessimistic
    declaration clips at zero error rather than shrinking plans below
    the declared quantile.  ``slack_factor`` is clipped to ``[floor,
    cap]`` and **monotone non-decreasing in the error** — the property
    ``tests/test_uncertainty.py`` pins.
    """

    def __init__(self, window: int = 256, gain: float = 4.0,
                 prior_error: float = 0.05, floor: float = 1.0,
                 cap: float = 3.0):
        if window < 1:
            raise ValueError("window must be >= 1")
        if not floor <= cap:
            raise ValueError("floor must be <= cap")
        self.window = int(window)
        self.gain = float(gain)
        self.prior_error = float(prior_error)
        self.floor = float(floor)
        self.cap = float(cap)
        self._dev = np.zeros(self.window, np.float64)
        self._idx = 0
        self._count = 0
        self._sum = 0.0

    def observe(self, predicted: float, actual: float,
                tail: float = 0.1) -> None:
        """Record one (planned, realized, promised-tail) triple — O(1).

        The stored deviation is ``1{actual > predicted} - tail``: its
        window mean is the coverage error of the declared quantile.
        """
        e = (1.0 if float(actual) > float(predicted) else 0.0) - \
            float(tail)
        if self._count >= self.window:
            self._sum -= self._dev[self._idx]
        else:
            self._count += 1
        self._dev[self._idx] = e
        self._sum += e
        self._idx = (self._idx + 1) % self.window

    @property
    def n_observed(self) -> int:
        """Observations recorded so far (window-capped memory)."""
        return self._count

    def calibration_error(self) -> float:
        """Prior-blended excess-overrun fraction of the window
        (``max(0, overrun_fraction - promised_tail)``)."""
        if self._count == 0:
            return self.prior_error
        w = min(self._count / self.window, 1.0)
        recent = max(0.0, self._sum / self._count)
        return (1.0 - w) * self.prior_error + w * recent

    def slack_factor(self) -> float:
        """Multiplicative solver slack: ``clip(1 + gain * error)`` —
        monotone non-decreasing in the calibration error."""
        return min(self.cap,
                   max(self.floor, 1.0 + self.gain * self.calibration_error()))


@dataclass
class UncertaintyConfig:
    """One run's uncertainty knobs, shared by scaler and engine.

    * ``dist`` — the declared decode-length distribution (None or a
      point mass ⇒ the deterministic paths run verbatim).
    * ``admission_quantile`` — the solver plans slot turnover at this
      quantile of ``dist`` (paper-facing knob: admit iff the p-quantile
      completion estimate meets the deadline).
    * ``class_quantiles`` — optional per-SLO-class overrides: sorted
      ``(slo_upper_bound, quantile)`` pairs; a request whose TTFT SLO
      is <= the first matching bound uses that quantile (tight classes
      usually want higher quantiles), everything else the default.
    * ``speculative`` — admit greedily with per-stream token budgets
      and cancel-on-overrun; False runs streams to completion (the
      solver still plans at the quantile).
    * ``overrun_margin`` — budget multiplier on top of the quantile
      estimate (>1 tolerates mild overruns before cancelling).
    * ``predictor`` — the shared :class:`LengthPredictor`; its slack
      factor widens both the solver headroom and the token budgets.
    """
    dist: Optional[LengthDistribution] = None
    admission_quantile: float = 0.9
    class_quantiles: Tuple[Tuple[float, float], ...] = ()
    speculative: bool = True
    overrun_margin: float = 1.0
    predictor: LengthPredictor = field(default_factory=LengthPredictor)
    _qcache: dict = field(default_factory=dict, repr=False)

    def __post_init__(self):
        if self.dist is not None:
            _check_q(self.admission_quantile)
        for bound, q in self.class_quantiles:
            _check_q(q)
            if bound <= 0:
                raise ValueError(f"SLO class bound must be > 0: {bound}")
        if self.overrun_margin < 1.0:
            raise ValueError("overrun_margin must be >= 1.0")

    def is_point(self) -> bool:
        """True ⇒ every uncertainty path reduces to the deterministic
        engine verbatim (the bit-identity contract)."""
        return self.dist is None or self.dist.is_point()

    def quantile_for(self, slo: float) -> float:
        """Admission quantile for a request's SLO class."""
        for bound, q in sorted(self.class_quantiles):
            if slo <= bound:
                return q
        return self.admission_quantile

    def _q(self, q: float) -> float:
        """Cached ``dist.quantile`` (the distribution is immutable for
        the run; quantiles are hit once per admitted stream)."""
        v = self._qcache.get(q)
        if v is None:
            v = float(self.dist.quantile(q))
            self._qcache[q] = v
        return v

    def planned_length(self, slo: float) -> float:
        """The decode length admission planned for this SLO class —
        what the predictor scores realized lengths against."""
        return self._q(self.quantile_for(slo))

    def observe(self, predicted: float, actual: float,
                slo: float) -> None:
        """Feed one finished/overrun stream to the predictor, scoring
        the realized length against the planned quantile with the tail
        mass that quantile promised for the request's SLO class."""
        self.predictor.observe(predicted, actual,
                               tail=1.0 - self.quantile_for(slo))

    def budget_tokens(self, slo: float) -> int:
        """The per-stream decode-token budget enforced by
        cancel-on-overrun: quantile estimate × margin × slack."""
        return max(1, int(math.ceil(self.planned_length(slo)
                                    * self.overrun_margin
                                    * self.predictor.slack_factor())))

    def drag_estimate(self) -> float:
        """Slot-turnover drag for the solver: the admission-quantile
        length widened by the predictor's slack."""
        return self._q(self.admission_quantile) * \
            self.predictor.slack_factor()

    def stats(self) -> dict:
        """Telemetry snapshot for run stats / benchmarks."""
        return {"quantile": self.admission_quantile,
                "speculative": self.speculative,
                "point": self.is_point(),
                "calibration_error": self.predictor.calibration_error(),
                "slack_factor": self.predictor.slack_factor(),
                "n_observed": self.predictor.n_observed}
