"""The Sponge scaler (paper §3.1 "Scaler"): every adaptation interval, read
the queue snapshot + lambda estimate, solve the IP, and emit a Decision the
engine applies via in-place vertical scaling.

``solver`` selects the optimizer implementation:

* ``"bruteforce"`` — the paper's Algorithm 1, a Python double loop (the
  reference semantics);
* ``"pruned"``     — the vectorized exact variant;
* ``"memo"``       — a :class:`repro.core.solver.MemoizedSolver`: the
  ``(c, b)`` grid is precomputed once and decisions are cached under a
  quantized ``(budgets, λ, wait)`` signature.  With ``budget_quantum`` and
  ``lam_quantum`` at their 0.0 defaults the cache key is exact and the
  decisions are identical to Algorithm 1; positive quanta trade a bounded,
  conservative coarsening for near-O(1) repeated decisions (the
  million-request scenario-engine configuration).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.core.cost_model import CostModel, TokenCostModel
from repro.core.perf_model import PerfModel
from repro.core.queueing import EDFQueue
from repro.core.slo import Decision
from repro.core.uncertainty import UncertaintyConfig
from repro.core.solver import (DEFAULT_B, DEFAULT_C, MemoizedSolver,
                               TokenMemoizedSolver, solve_bruteforce,
                               solve_pruned, solve_token_bruteforce)


@dataclass
class SpongeScaler:
    """Conforms to ``repro.serving.api.SchedulingPolicy`` — a bare scaler
    can be handed to the ScenarioRunner directly (the live engine does).

    ``perf`` may be a ``PerfModel`` or any fixed-work-capable
    ``repro.core.cost_model.CostModel`` (they share the ``latency(b, c)``
    / ``throughput(b, c)`` surface; the ``FixedWorkCostModel`` adapter is
    decision-identical to its wrapped PerfModel by construction)."""
    perf: Union[PerfModel, CostModel]
    name: str = "sponge"
    c_set: Sequence[int] = DEFAULT_C
    b_set: Sequence[int] = DEFAULT_B
    adaptation_interval: float = 1.0
    solver: str = "bruteforce"          # bruteforce (paper Alg.1) | pruned | memo
    delta_pen: float = 1e-3
    headroom: float = 0.05              # latency safety margin (seconds)
    lam_headroom: float = 1.05          # provision for lam * this factor
    budget_quantum: float = 0.0         # memo solver: budget bucket (s)
    lam_quantum: float = 0.0            # memo solver: lambda bucket (rps)
    decisions: List[tuple[float, Decision]] = field(default_factory=list)
    _next_t: float = 0.0
    _memo: Optional[MemoizedSolver] = field(default=None, repr=False)

    def due(self, now: float) -> bool:
        return now + 1e-12 >= self._next_t

    @property
    def memo(self) -> MemoizedSolver:
        """The lazily built memoized solver (valid for solver="memo")."""
        if self._memo is None:
            self._memo = MemoizedSolver(
                self.perf, self.c_set, self.b_set,
                budget_quantum=self.budget_quantum,
                lam_quantum=self.lam_quantum)
        return self._memo

    def solver_stats(self) -> dict:
        """Cache economics of the memo solver ({} for exact solvers)."""
        if self._memo is None:
            return {}
        return {"hits": self._memo.hits, "misses": self._memo.misses,
                "hit_rate": self._memo.hit_rate}

    def decide(self, now: float, queue: EDFQueue, lam: float,
               initial_wait: float = 0.0,
               extra_budgets: tuple = ()) -> Decision:
        self._next_t = now + self.adaptation_interval
        if hasattr(queue, "remaining_array"):
            snap = queue.remaining_array(now)
        else:
            snap = np.asarray(queue.snapshot_remaining(now), np.float64)
        remaining = np.maximum(snap - self.headroom, 0.0)
        if extra_budgets:
            extra = np.maximum(
                np.asarray(extra_budgets, np.float64) - self.headroom, 0.0)
            remaining = np.sort(np.concatenate([remaining, extra]))
        lam_eff = lam * self.lam_headroom
        if self.solver == "memo":
            d = self.memo.solve(remaining, lam_eff,
                                initial_wait=initial_wait)
        else:
            fn = (solve_bruteforce if self.solver == "bruteforce"
                  else solve_pruned)
            d = fn(list(remaining), lam_eff, self.perf, self.c_set,
                   self.b_set, self.delta_pen, initial_wait=initial_wait)
        self.decisions.append((now, d))
        return d


@dataclass
class TokenSpongeScaler:
    """The Sponge scaler over the token-level cost model (ISSUE 3).

    Same control-loop role as :class:`SpongeScaler` — every adaptation
    interval, read the queue snapshot + λ estimate, solve, emit a
    Decision — but the snapshot is token-aware (per-request TTFT budgets
    + prompt-token counts + the tightest per-token SLO, via
    ``queue.token_snapshot``) and the solve runs the token-composition
    Algorithm 1 (``repro.core.solver.TokenSolverTable`` behind a
    ``TokenMemoizedSolver``; quanta 0 keep it exact).  The Decision's
    ``b`` doubles as the decode-slot cap the continuous-batching engines
    run at; ``predicted_tbt`` carries the solver's sustained decode-step
    latency for telemetry.

    Token-aware runners pass ``active_slots`` (running decode slots) and
    ``tbt_budget`` (tightest per-token budget across queued *and*
    running requests); plain runners may omit both — the scaler then
    derives the TBT bound from the queue alone.
    """
    cost: TokenCostModel
    name: str = "sponge-token"
    c_set: Sequence[int] = DEFAULT_C
    b_set: Sequence[int] = DEFAULT_B
    adaptation_interval: float = 1.0
    solver: str = "memo"                # memo (table+cache) | bruteforce
    headroom: float = 0.05              # TTFT safety margin (seconds)
    tbt_headroom: float = 0.0           # per-token safety margin (seconds)
    lam_headroom: float = 1.05
    budget_quantum: float = 0.0
    lam_quantum: float = 0.0
    token_quantum: int = 0
    # decode-steps of slot-turnover drag per EDF prefill group; None =
    # the cost model's mean decode length (a slot frees when its stream
    # finishes) — see ``repro.core.solver.solve_token_bruteforce``
    drag_steps: Optional[float] = None
    # distribution-aware admission (ISSUE 7): when the config carries a
    # non-point distribution, the solve plans drag at the admission
    # quantile and widens the TTFT headroom by the shared predictor's
    # slack factor; None or a point mass leaves the deterministic solve
    # untouched (bit-identical decisions)
    uncertainty: Optional[UncertaintyConfig] = None
    decisions: List[tuple[float, Decision]] = field(default_factory=list)
    _next_t: float = 0.0
    _memo: Optional[TokenMemoizedSolver] = field(default=None, repr=False)

    def due(self, now: float) -> bool:
        """Adaptation-interval gate (same cadence rule as SpongeScaler)."""
        return now + 1e-12 >= self._next_t

    @property
    def memo(self) -> TokenMemoizedSolver:
        """The lazily built token memoized solver."""
        if self._memo is None:
            self._memo = TokenMemoizedSolver(
                self.cost, self.c_set, self.b_set,
                budget_quantum=self.budget_quantum,
                lam_quantum=self.lam_quantum,
                token_quantum=self.token_quantum)
        return self._memo

    def solver_stats(self) -> dict:
        """Cache economics of the memo solver ({} before first use)."""
        if self._memo is None:
            return {}
        return {"hits": self._memo.hits, "misses": self._memo.misses,
                "hit_rate": self._memo.hit_rate}

    def decide(self, now: float, queue, lam: float,
               initial_wait: float = 0.0, active_slots: int = 0,
               tbt_budget: Optional[float] = None) -> Decision:
        """One adaptation step: snapshot, solve, log, return.

        With a non-point :class:`~repro.core.uncertainty.
        UncertaintyConfig`, the p-quantile completion estimate gates
        admission: slot-turnover drag is planned at
        ``dist.quantile(admission_quantile)`` (not the cost model's
        mean) and the TTFT headroom is multiplied by the predictor's
        running slack factor, so worsening calibration widens the
        safety margin and sustained good calibration narrows it back.
        """
        self._next_t = now + self.adaptation_interval
        headroom, drag = self.headroom, self.drag_steps
        unc = self.uncertainty
        if unc is not None and not unc.is_point():
            headroom = self.headroom * unc.predictor.slack_factor()
            drag = unc.drag_estimate()
        rem, toks, queue_tbt = queue.token_snapshot(now)
        remaining = np.maximum(rem - headroom, 0.0)
        tbt = queue_tbt if tbt_budget is None else min(tbt_budget, queue_tbt)
        if np.isfinite(tbt):
            tbt = max(tbt - self.tbt_headroom, 0.0)
        lam_eff = lam * self.lam_headroom
        if self.solver == "bruteforce":
            d = solve_token_bruteforce(
                remaining, toks, lam_eff, self.cost, self.c_set, self.b_set,
                initial_wait=initial_wait, tbt_budget=tbt,
                active_slots=active_slots, drag_steps=drag)
        else:
            d = self.memo.solve(remaining, toks, lam_eff,
                                initial_wait=initial_wait, tbt_budget=tbt,
                                active_slots=active_slots,
                                drag_steps=drag)
        self.decisions.append((now, d))
        return d
