"""The Sponge scaler (paper §3.1 "Scaler"): every adaptation interval, read
the queue snapshot + lambda estimate, solve the IP, and emit a Decision the
engine applies via in-place vertical scaling."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from repro.core.perf_model import PerfModel
from repro.core.queueing import EDFQueue
from repro.core.slo import Decision
from repro.core.solver import DEFAULT_B, DEFAULT_C, solve_bruteforce, solve_pruned


@dataclass
class SpongeScaler:
    """Conforms to ``repro.serving.api.SchedulingPolicy`` — a bare scaler
    can be handed to the ScenarioRunner directly (the live engine does)."""
    perf: PerfModel
    name: str = "sponge"
    c_set: Sequence[int] = DEFAULT_C
    b_set: Sequence[int] = DEFAULT_B
    adaptation_interval: float = 1.0
    solver: str = "bruteforce"          # bruteforce (paper Alg.1) | pruned
    delta_pen: float = 1e-3
    headroom: float = 0.05              # latency safety margin (seconds)
    lam_headroom: float = 1.05          # provision for lam * this factor
    decisions: List[tuple[float, Decision]] = field(default_factory=list)
    _next_t: float = 0.0

    def due(self, now: float) -> bool:
        return now + 1e-12 >= self._next_t

    def decide(self, now: float, queue: EDFQueue, lam: float,
               initial_wait: float = 0.0,
               extra_budgets: tuple = ()) -> Decision:
        self._next_t = now + self.adaptation_interval
        remaining = [max(r - self.headroom, 0.0)
                     for r in queue.snapshot_remaining(now)]
        remaining += [max(r - self.headroom, 0.0) for r in extra_budgets]
        remaining.sort()
        fn = solve_bruteforce if self.solver == "bruteforce" else solve_pruned
        d = fn(remaining, lam * self.lam_headroom, self.perf, self.c_set,
               self.b_set, self.delta_pen, initial_wait=initial_wait)
        self.decisions.append((now, d))
        return d
