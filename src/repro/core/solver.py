"""The Sponge optimizer: Integer Program (paper Eq. 3) + Algorithm 1.

    minimize   c + delta_pen * b
    s.t.       l(b,c) + q_r(b,c) + cl_max <= SLO   for every request r
               h(b,c) >= lambda
               b, c in Z+

``solve_bruteforce`` is the faithful Algorithm 1: iterate c ascending then b
ascending, simulate the batch queue (batch i waits i*l(b,c)) against the
per-request remaining budgets, return the first feasible configuration —
which is the minimum-c, then minimum-b solution, i.e. the IP optimum for any
delta_pen < 1 because the objective is lexicographic in (c, b) over the
iteration order.

Beyond the paper (recorded in EXPERIMENTS.md §Fig4 notes):

* ``initial_wait`` — the server is mid-batch when the scaler fires; batch 0
  starts after the in-flight work drains.  Algorithm 1 implicitly assumes an
  idle server; without this term the control loop runs the instance at
  utilization ~1 and queueing delay accumulates without bound.
* damage-minimizing fallback — when NO (c, b) satisfies every deadline
  (deep network fade), return the sustainable config that minimizes the
  predicted violation count instead of the paper's implicit "give up"
  (c_max, b_max), which would violate the whole queue.
* ``solve_pruned`` — vectorized exact variant, O(|C||B|) numpy.
* ``SolverTable`` — the ``(c, b)`` grid (latency, throughput, lexicographic
  iteration order) precomputed ONCE per (perf, c_set, b_set), so each solve
  is a handful of vectorized comparisons against ready-made arrays instead
  of a Python double loop over the grid.
* ``MemoizedSolver`` — a quantized decision cache in front of a
  ``SolverTable``: queue budgets / λ / initial wait are bucketed
  conservatively (budgets floored, λ and wait ceiled) and the Decision for
  each bucket signature is computed once; repeated ``decide()`` calls in a
  long scenario become dictionary lookups.  With all quanta at 0 the cache
  key is the exact input and the solver is decision-for-decision identical
  to Algorithm 1 (the contract ``tests/test_fastpath.py`` enforces).
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import numpy as np

from repro.core.perf_model import PerfModel
from repro.core.slo import Decision

DEFAULT_C = tuple(range(1, 17))
DEFAULT_B = tuple(range(1, 17))
# TPU adaptation: feasible submesh degrees are powers of two (DESIGN.md §2)
TPU_C = (1, 2, 4, 8, 16)
TPU_B = (1, 2, 4, 8, 16)


def _predicted_violations(rem: Sequence[float], l: float, b: int,
                          initial_wait: float) -> int:
    """Requests whose batch completes after their remaining budget."""
    n = len(rem)
    v = 0
    for idx in range(n):
        finish = initial_wait + (idx // b + 1) * l
        if finish > rem[idx]:
            v += 1
    return v


def solve_bruteforce(remaining_slos: Sequence[float], lam: float,
                     perf: PerfModel,
                     c_set: Sequence[int] = DEFAULT_C,
                     b_set: Sequence[int] = DEFAULT_B,
                     delta_pen: float = 1e-3,
                     initial_wait: float = 0.0) -> Decision:
    """Faithful Algorithm 1 (+ the fallback described in the module doc).

    remaining_slos: per queued request, the remaining budget SLO - cl_r
    (equivalently deadline - now); the EDF queue hands them over sorted
    ascending.  The binding budget of batch i in EDF order is that of its
    first request, rem[i*b].
    """
    t0 = time.perf_counter()
    rem = sorted(float(x) for x in remaining_slos)
    n = len(rem)
    iters = 0
    best_fallback = None  # (violations, c, b)
    for c in sorted(c_set):
        for b in sorted(b_set):
            iters += 1
            l = float(perf.latency(b, c))
            if lam > 0 and perf.throughput(b, c) < lam:
                continue
            ok = True
            q_r = initial_wait
            for i in range(0, max(n, 1), b):
                budget = rem[i] if n else float("inf")
                if l + q_r > budget:
                    ok = False
                    break
                q_r += l
                if n == 0:
                    break
            if ok:
                return Decision(c=c, b=b, feasible=True, solver_iters=iters,
                                solver_time=time.perf_counter() - t0)
            v = _predicted_violations(rem, l, b, initial_wait)
            # crisis ordering: fewest predicted violations, then fastest
            # drain (max throughput) — arrivals keep coming during a fade
            key = (v, -float(perf.throughput(b, c)))
            if best_fallback is None or key < best_fallback[0]:
                best_fallback = (key, c, b)
    if best_fallback is None:  # nothing sustains lam: max capacity config
        c = max(c_set)
        b = max(b_set, key=lambda bb: perf.throughput(bb, c))
        best_fallback = ((n, 0.0), c, b)
    _, c, b = best_fallback
    return Decision(c=c, b=b, feasible=False, solver_iters=iters,
                    solver_time=time.perf_counter() - t0)


def solve_pruned(remaining_slos: Sequence[float], lam: float,
                 perf: PerfModel,
                 c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B,
                 delta_pen: float = 1e-3,
                 initial_wait: float = 0.0) -> Decision:
    """Vectorized exact solver (same constraint set, explicit argmin)."""
    t0 = time.perf_counter()
    rem = np.sort(np.asarray(list(remaining_slos), np.float64))
    n = len(rem)
    cs = np.asarray(sorted(c_set))
    bs = np.asarray(sorted(b_set))
    bb, cc = np.meshgrid(bs, cs, indexing="ij")       # (B, C)
    lat = perf.latency(bb, cc)
    thr = bb / np.maximum(lat, 1e-12)
    sustain = thr >= (lam if lam > 0 else 0.0)
    feas = sustain.copy()
    viol = np.zeros_like(lat, dtype=np.int64)
    if n:
        idx = np.arange(n)
        for j, b in enumerate(bs):
            batch_mult = idx // int(b) + 1                # (n,)
            finish = initial_wait + batch_mult[None, :] * lat[j][:, None]
            over = finish > rem[None, :] + 1e-12
            viol[j] = over.sum(axis=1)
            feas[j] &= ~over.any(axis=1)
    cost = cc + delta_pen * bb
    cost = np.where(feas, cost, np.inf)
    solver_time = time.perf_counter() - t0
    if np.isfinite(cost).any():
        j, i = np.unravel_index(np.argmin(cost), cost.shape)
        return Decision(c=int(cs[i]), b=int(bs[j]), feasible=True,
                        solver_iters=cost.size, solver_time=solver_time)
    # damage-minimizing fallback among sustainable configs (or all),
    # tie-broken by max throughput (fastest drain during the fade)
    pool = np.where(sustain, viol.astype(np.float64), viol.max() + 1e6 + cc)
    pool = pool - 1e-9 * thr
    j, i = np.unravel_index(np.argmin(pool), pool.shape)
    return Decision(c=int(cs[i]), b=int(bs[j]), feasible=False,
                    solver_iters=cost.size, solver_time=solver_time)


class SolverTable:
    """Precomputed numpy feasibility grids over the ``(c, b)`` space.

    Everything that depends only on (perf, c_set, b_set) — the latency
    grid l(b, c), the throughput grid h(b, c), and the flattened
    Algorithm-1 iteration order (c ascending, then b ascending) — is
    computed once here.  ``solve`` then answers each query with O(|C||B|)
    vectorized comparisons plus an O(n/b) reduction per batch size over
    the EDF batch heads; there is no per-config Python loop.

    The constraint set is exactly Algorithm 1's: batch i (0-indexed, EDF
    order) finishes at ``initial_wait + (i+1)·l(b, c)`` and must meet the
    budget of its head request ``rem[i·b]``; configs with
    ``h(b, c) < λ`` are discarded; the first feasible entry in (c, b)
    lexicographic order is the IP optimum.  The infeasible fallback
    replicates ``solve_bruteforce``: among sustainable configs, fewest
    predicted violations, ties broken by fastest drain.
    """

    def __init__(self, perf: PerfModel, c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B):
        self.perf = perf
        self.cs = np.asarray(sorted(c_set), np.int64)
        self.bs = np.asarray(sorted(b_set), np.int64)
        cc, bb = np.meshgrid(self.cs, self.bs, indexing="ij")   # (C, B)
        self.lat = np.asarray(perf.latency(bb, cc), np.float64)
        self.thr = bb / np.maximum(self.lat, 1e-12)
        self.c_flat = cc.ravel()
        self.b_flat = bb.ravel()
        self.size = self.lat.size

    def solve(self, remaining_slos, lam: float,
              initial_wait: float = 0.0) -> Decision:
        t0 = time.perf_counter()
        rem = np.sort(np.asarray(remaining_slos, np.float64).ravel())
        n = rem.size
        C, B = self.lat.shape
        feas = np.ones((C, B), bool)
        if n:
            for j in range(B):
                b = int(self.bs[j])
                heads = rem[::b]
                k = np.arange(1, heads.size + 1, dtype=np.float64)
                finish = initial_wait + self.lat[:, j, None] * k
                feas[:, j] = (finish <= heads).all(axis=1)
        sustain = (self.thr >= lam) if lam > 0 else np.ones((C, B), bool)
        ok = (feas & sustain).ravel()
        hit = np.flatnonzero(ok)
        if hit.size:
            i = int(hit[0])
            return Decision(c=int(self.c_flat[i]), b=int(self.b_flat[i]),
                            feasible=True, solver_iters=self.size,
                            solver_time=time.perf_counter() - t0)
        # fallback: among sustainable configs, fewest predicted violations,
        # then max throughput, then first in (c, b) order — bruteforce's
        # crisis ordering
        sus_flat = sustain.ravel()
        if sus_flat.any():
            viol = np.zeros((C, B), np.int64)
            if n:
                idx = np.arange(n, dtype=np.int64)
                for j in range(B):
                    b = int(self.bs[j])
                    mult = (idx // b + 1).astype(np.float64)
                    finish = initial_wait + self.lat[:, j, None] * mult
                    viol[:, j] = (finish > rem).sum(axis=1)
            key1 = np.where(sus_flat, viol.ravel().astype(np.float64),
                            np.inf)
            cand = np.flatnonzero(key1 == key1.min())
            thr_c = self.thr.ravel()[cand]
            i = int(cand[np.flatnonzero(thr_c == thr_c.max())[0]])
            c, b = int(self.c_flat[i]), int(self.b_flat[i])
        else:  # nothing sustains lam: max capacity config
            c = int(self.cs[-1])
            j = int(np.argmax(self.thr[-1]))
            b = int(self.bs[j])
        return Decision(c=c, b=b, feasible=False, solver_iters=self.size,
                        solver_time=time.perf_counter() - t0)


class MemoizedSolver:
    """Decision cache in front of a :class:`SolverTable`.

    Inputs are quantized **conservatively** before solving and the result
    is cached under the quantized signature ``(budget buckets, queue
    length, λ bucket, wait bucket)``:

    * remaining budgets are *floored* to ``budget_quantum`` — the cached
      decision never assumes more slack than the live queue has;
    * λ is *ceiled* to ``lam_quantum`` and ``initial_wait`` to
      ``budget_quantum`` — the cached decision never assumes less load.

    A cache hit returns the stored Decision verbatim (its ``solver_time``
    and ``solver_iters`` describe the original miss).  With every quantum
    at 0 the key is the exact input vector, so memoization cannot change
    any decision — only deduplicate identical queue states.  ``hits`` /
    ``misses`` / ``hit_rate`` expose the economics for the throughput
    benchmark.
    """

    def __init__(self, perf: PerfModel, c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B,
                 budget_quantum: float = 0.0, lam_quantum: float = 0.0,
                 max_entries: int = 200_000):
        self.table = SolverTable(perf, c_set, b_set)
        self.budget_quantum = float(budget_quantum)
        self.lam_quantum = float(lam_quantum)
        self.max_entries = max_entries
        self.cache: dict = {}
        self.hits = 0
        self.misses = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(self.hits + self.misses, 1)

    def solve(self, remaining_slos, lam: float,
              initial_wait: float = 0.0) -> Decision:
        rem = np.sort(np.asarray(remaining_slos, np.float64).ravel())
        bq, lq = self.budget_quantum, self.lam_quantum
        if bq > 0:
            rem = np.floor(rem / bq) * bq
            iw = float(np.ceil(initial_wait / bq) * bq)
        else:
            iw = float(initial_wait)
        lam_q = float(np.ceil(lam / lq) * lq) if lq > 0 else float(lam)
        key = (rem.tobytes(), lam_q, iw)
        d = self.cache.get(key)
        if d is not None:
            self.hits += 1
            return d
        self.misses += 1
        d = self.table.solve(rem, lam_q, initial_wait=iw)
        if len(self.cache) >= self.max_entries:
            self.cache.clear()
        self.cache[key] = d
        return d
