"""The Sponge optimizer: Integer Program (paper Eq. 3) + Algorithm 1.

    minimize   c + delta_pen * b
    s.t.       l(b,c) + q_r(b,c) + cl_max <= SLO   for every request r
               h(b,c) >= lambda
               b, c in Z+

``solve_bruteforce`` is the faithful Algorithm 1: iterate c ascending then b
ascending, simulate the batch queue (batch i waits i*l(b,c)) against the
per-request remaining budgets, return the first feasible configuration —
which is the minimum-c, then minimum-b solution, i.e. the IP optimum for any
delta_pen < 1 because the objective is lexicographic in (c, b) over the
iteration order.

Beyond the paper (recorded in EXPERIMENTS.md §Fig4 notes):

* ``initial_wait`` — the server is mid-batch when the scaler fires; batch 0
  starts after the in-flight work drains.  Algorithm 1 implicitly assumes an
  idle server; without this term the control loop runs the instance at
  utilization ~1 and queueing delay accumulates without bound.
* damage-minimizing fallback — when NO (c, b) satisfies every deadline
  (deep network fade), return the sustainable config that minimizes the
  predicted violation count instead of the paper's implicit "give up"
  (c_max, b_max), which would violate the whole queue.
* ``solve_pruned`` — vectorized exact variant, O(|C||B|) numpy.
* ``SolverTable`` — the ``(c, b)`` grid (latency, throughput, lexicographic
  iteration order) precomputed ONCE per (perf, c_set, b_set), so each solve
  is a handful of vectorized comparisons against ready-made arrays instead
  of a Python double loop over the grid.
* ``MemoizedSolver`` — a quantized decision cache in front of a
  ``SolverTable``: queue budgets / λ / initial wait are bucketed
  conservatively (budgets floored, λ and wait ceiled) and the Decision for
  each bucket signature is computed once; repeated ``decide()`` calls in a
  long scenario become dictionary lookups.  With all quanta at 0 the cache
  key is the exact input and the solver is decision-for-decision identical
  to Algorithm 1 (the contract ``tests/test_fastpath.py`` enforces).

Token-level extension (ISSUE 3 — phase-aware autoregressive serving):

Every solver above also accepts any ``repro.core.cost_model.CostModel``
in place of the ``PerfModel`` (all cost models expose the fixed-work
``latency(b, c)`` / ``throughput(b, c)`` surface; the
``FixedWorkCostModel`` adapter delegates to the wrapped PerfModel with
identical float expressions, so decisions cannot drift).  On top of that,
``solve_token_bruteforce`` / ``TokenSolverTable`` / ``TokenMemoizedSolver``
extend the Algorithm-1 feasibility logic to token compositions:

* each queued request carries a **TTFT budget** (the dynamic-SLO
  remaining budget, exactly as before) *and* a prompt-token count; EDF
  groups of b prefill together and group i's prefill must finish inside
  its head request's TTFT budget — the drain simulation is Algorithm 1's,
  with the constant ``l(b, c)`` replaced by the group's
  ``prefill_latency(c, Σ tokens)`` plus one decode-step of interleave
  drag whenever a decode stream is running (continuous batching shares
  the engine between prefill bursts and decode steps);
* a **per-token (TBT) budget** gates the decode stream: a config (c, b)
  is feasible only if ``decode_latency(c, b) <= tbt_budget`` — b is the
  decode-slot cap the engine will run at, so this bounds the steady-state
  gap between consecutive tokens of every running request;
* the λ constraint uses the cost model's full-service throughput
  (prefill + whole decode stream of a mean-shaped request).
"""
from __future__ import annotations

import time
from dataclasses import replace
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.core.cost_model import CostModel, TokenCostModel
from repro.core.perf_model import PerfModel
from repro.core.slo import Decision

DEFAULT_C = tuple(range(1, 17))
DEFAULT_B = tuple(range(1, 17))
# fleet layer (ISSUE 4): feasible replica counts for the joint solver
DEFAULT_N = tuple(range(1, 17))
# TPU adaptation: feasible submesh degrees are powers of two (DESIGN.md §2)
TPU_C = (1, 2, 4, 8, 16)
TPU_B = (1, 2, 4, 8, 16)


def _predicted_violations(rem: Sequence[float], l: float, b: int,
                          initial_wait: float) -> int:
    """Requests whose batch completes after their remaining budget."""
    n = len(rem)
    v = 0
    for idx in range(n):
        finish = initial_wait + (idx // b + 1) * l
        if finish > rem[idx]:
            v += 1
    return v


def solve_bruteforce(remaining_slos: Sequence[float], lam: float,
                     perf: PerfModel,
                     c_set: Sequence[int] = DEFAULT_C,
                     b_set: Sequence[int] = DEFAULT_B,
                     delta_pen: float = 1e-3,
                     initial_wait: float = 0.0) -> Decision:
    """Faithful Algorithm 1 (+ the fallback described in the module doc).

    remaining_slos: per queued request, the remaining budget SLO - cl_r
    (equivalently deadline - now); the EDF queue hands them over sorted
    ascending.  The binding budget of batch i in EDF order is that of its
    first request, rem[i*b].
    """
    t0 = time.perf_counter()
    rem = sorted(float(x) for x in remaining_slos)
    n = len(rem)
    iters = 0
    best_fallback = None  # (violations, c, b)
    for c in sorted(c_set):
        for b in sorted(b_set):
            iters += 1
            l = float(perf.latency(b, c))
            if lam > 0 and perf.throughput(b, c) < lam:
                continue
            ok = True
            q_r = initial_wait
            for i in range(0, max(n, 1), b):
                budget = rem[i] if n else float("inf")
                if l + q_r > budget:
                    ok = False
                    break
                q_r += l
                if n == 0:
                    break
            if ok:
                return Decision(c=c, b=b, feasible=True, solver_iters=iters,
                                solver_time=time.perf_counter() - t0)
            v = _predicted_violations(rem, l, b, initial_wait)
            # crisis ordering: fewest predicted violations, then fastest
            # drain (max throughput) — arrivals keep coming during a fade
            key = (v, -float(perf.throughput(b, c)))
            if best_fallback is None or key < best_fallback[0]:
                best_fallback = (key, c, b)
    if best_fallback is None:  # nothing sustains lam: max capacity config
        c = max(c_set)
        b = max(b_set, key=lambda bb: perf.throughput(bb, c))
        best_fallback = ((n, 0.0), c, b)
    _, c, b = best_fallback
    return Decision(c=c, b=b, feasible=False, solver_iters=iters,
                    solver_time=time.perf_counter() - t0)


def solve_pruned(remaining_slos: Sequence[float], lam: float,
                 perf: PerfModel,
                 c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B,
                 delta_pen: float = 1e-3,
                 initial_wait: float = 0.0) -> Decision:
    """Vectorized exact solver (same constraint set, explicit argmin)."""
    t0 = time.perf_counter()
    rem = np.sort(np.asarray(list(remaining_slos), np.float64))
    n = len(rem)
    cs = np.asarray(sorted(c_set))
    bs = np.asarray(sorted(b_set))
    bb, cc = np.meshgrid(bs, cs, indexing="ij")       # (B, C)
    lat = perf.latency(bb, cc)
    thr = bb / np.maximum(lat, 1e-12)
    sustain = thr >= (lam if lam > 0 else 0.0)
    feas = sustain.copy()
    viol = np.zeros_like(lat, dtype=np.int64)
    if n:
        idx = np.arange(n)
        for j, b in enumerate(bs):
            batch_mult = idx // int(b) + 1                # (n,)
            finish = initial_wait + batch_mult[None, :] * lat[j][:, None]
            over = finish > rem[None, :] + 1e-12
            viol[j] = over.sum(axis=1)
            feas[j] &= ~over.any(axis=1)
    cost = cc + delta_pen * bb
    cost = np.where(feas, cost, np.inf)
    solver_time = time.perf_counter() - t0
    if np.isfinite(cost).any():
        j, i = np.unravel_index(np.argmin(cost), cost.shape)
        return Decision(c=int(cs[i]), b=int(bs[j]), feasible=True,
                        solver_iters=cost.size, solver_time=solver_time)
    # damage-minimizing fallback among sustainable configs (or all),
    # tie-broken by max throughput (fastest drain during the fade)
    pool = np.where(sustain, viol.astype(np.float64), viol.max() + 1e6 + cc)
    pool = pool - 1e-9 * thr
    j, i = np.unravel_index(np.argmin(pool), pool.shape)
    return Decision(c=int(cs[i]), b=int(bs[j]), feasible=False,
                    solver_iters=cost.size, solver_time=solver_time)


class SolverTable:
    """Precomputed numpy feasibility grids over the ``(c, b)`` space.

    Everything that depends only on (perf, c_set, b_set) — the latency
    grid l(b, c), the throughput grid h(b, c), and the flattened
    Algorithm-1 iteration order (c ascending, then b ascending) — is
    computed once here.  ``solve`` then answers each query with O(|C||B|)
    vectorized comparisons plus an O(n/b) reduction per batch size over
    the EDF batch heads; there is no per-config Python loop.

    The constraint set is exactly Algorithm 1's: batch i (0-indexed, EDF
    order) finishes at ``initial_wait + (i+1)·l(b, c)`` and must meet the
    budget of its head request ``rem[i·b]``; configs with
    ``h(b, c) < λ`` are discarded; the first feasible entry in (c, b)
    lexicographic order is the IP optimum.  The infeasible fallback
    replicates ``solve_bruteforce``: among sustainable configs, fewest
    predicted violations, ties broken by fastest drain.
    """

    def __init__(self, perf: Union[PerfModel, CostModel],
                 c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B):
        self.perf = perf        # PerfModel or any CostModel (same surface)
        self.cs = np.asarray(sorted(c_set), np.int64)
        self.bs = np.asarray(sorted(b_set), np.int64)
        cc, bb = np.meshgrid(self.cs, self.bs, indexing="ij")   # (C, B)
        self.lat = np.asarray(perf.latency(bb, cc), np.float64)
        self.thr = bb / np.maximum(self.lat, 1e-12)
        self.c_flat = cc.ravel()
        self.b_flat = bb.ravel()
        self.size = self.lat.size

    def solve(self, remaining_slos, lam: float,
              initial_wait: float = 0.0) -> Decision:
        t0 = time.perf_counter()
        rem = np.sort(np.asarray(remaining_slos, np.float64).ravel())
        n = rem.size
        C, B = self.lat.shape
        feas = np.ones((C, B), bool)
        if n:
            for j in range(B):
                b = int(self.bs[j])
                heads = rem[::b]
                k = np.arange(1, heads.size + 1, dtype=np.float64)
                finish = initial_wait + self.lat[:, j, None] * k
                feas[:, j] = (finish <= heads).all(axis=1)
        sustain = (self.thr >= lam) if lam > 0 else np.ones((C, B), bool)
        ok = (feas & sustain).ravel()
        hit = np.flatnonzero(ok)
        if hit.size:
            i = int(hit[0])
            return Decision(c=int(self.c_flat[i]), b=int(self.b_flat[i]),
                            feasible=True, solver_iters=self.size,
                            solver_time=time.perf_counter() - t0)
        # fallback: among sustainable configs, fewest predicted violations,
        # then max throughput, then first in (c, b) order — bruteforce's
        # crisis ordering
        sus_flat = sustain.ravel()
        if sus_flat.any():
            viol = np.zeros((C, B), np.int64)
            if n:
                idx = np.arange(n, dtype=np.int64)
                for j in range(B):
                    b = int(self.bs[j])
                    mult = (idx // b + 1).astype(np.float64)
                    finish = initial_wait + self.lat[:, j, None] * mult
                    viol[:, j] = (finish > rem).sum(axis=1)
            key1 = np.where(sus_flat, viol.ravel().astype(np.float64),
                            np.inf)
            cand = np.flatnonzero(key1 == key1.min())
            thr_c = self.thr.ravel()[cand]
            i = int(cand[np.flatnonzero(thr_c == thr_c.max())[0]])
            c, b = int(self.c_flat[i]), int(self.b_flat[i])
        else:  # nothing sustains lam: max capacity config
            c = int(self.cs[-1])
            j = int(np.argmax(self.thr[-1]))
            b = int(self.bs[j])
        return Decision(c=c, b=b, feasible=False, solver_iters=self.size,
                        solver_time=time.perf_counter() - t0)


class _QuantizedDecisionCache:
    """The conservative quantize-and-cache shell shared by every
    memoized solver (fixed-work, token, joint fleet).

    The bucketing rule is correctness-critical and lives HERE once: all
    load-like inputs round *against* the caller — remaining budgets are
    **floored** to ``budget_quantum`` (a cached decision never assumes
    more slack than the live queue has), λ and ``initial_wait`` are
    **ceiled** (never less load) — so a cache hit can over-provision but
    can never admit a decision the exact constraint set rejects.  With
    every quantum at 0 the key is the exact input and memoization cannot
    change a decision, only deduplicate identical states.  Cache hits
    return the stored Decision verbatim (``solver_time``/``solver_iters``
    describe the original miss); ``hits``/``misses``/``hit_rate`` expose
    the economics to the benchmarks.  Eviction is clear-on-full at
    ``max_entries``.
    """

    def __init__(self, budget_quantum: float = 0.0,
                 lam_quantum: float = 0.0, max_entries: int = 200_000):
        self.budget_quantum = float(budget_quantum)
        self.lam_quantum = float(lam_quantum)
        self.max_entries = max_entries
        self.cache: dict = {}
        self.hits = 0
        self.misses = 0

    @property
    def hit_rate(self) -> float:
        """Fraction of ``solve`` calls answered from the cache."""
        return self.hits / max(self.hits + self.misses, 1)

    def _quantize(self, rem: np.ndarray, lam: float, initial_wait: float
                  ) -> tuple[np.ndarray, float, float]:
        """Floor budgets, ceil λ/wait to their quanta (0 = exact)."""
        bq, lq = self.budget_quantum, self.lam_quantum
        if bq > 0:
            rem = np.floor(rem / bq) * bq
            iw = float(np.ceil(initial_wait / bq) * bq)
        else:
            iw = float(initial_wait)
        lam_q = float(np.ceil(lam / lq) * lq) if lq > 0 else float(lam)
        return rem, lam_q, iw

    def _cached(self, key, compute) -> Decision:
        """One hit/miss round trip; ``compute`` runs on a miss."""
        d = self.cache.get(key)
        if d is not None:
            self.hits += 1
            return d
        self.misses += 1
        d = compute()
        if len(self.cache) >= self.max_entries:
            self.cache.clear()
        self.cache[key] = d
        return d


class MemoizedSolver(_QuantizedDecisionCache):
    """Decision cache in front of a :class:`SolverTable` — the
    :class:`_QuantizedDecisionCache` bucketing over the fixed-work
    Algorithm 1 (the million-request scenario-engine configuration)."""

    def __init__(self, perf: Union[PerfModel, CostModel],
                 c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B,
                 budget_quantum: float = 0.0, lam_quantum: float = 0.0,
                 max_entries: int = 200_000):
        super().__init__(budget_quantum, lam_quantum, max_entries)
        self.table = SolverTable(perf, c_set, b_set)

    def solve(self, remaining_slos, lam: float,
              initial_wait: float = 0.0) -> Decision:
        """Quantize conservatively, then cache per bucket signature."""
        rem = np.sort(np.asarray(remaining_slos, np.float64).ravel())
        rem, lam_q, iw = self._quantize(rem, lam, initial_wait)
        return self._cached(
            (rem.tobytes(), lam_q, iw),
            lambda: self.table.solve(rem, lam_q, initial_wait=iw))

    def solve_many(self, remaining_slos_seq, lams, initial_waits=None
                   ) -> List[Decision]:
        """Batch decision lookup: quantize every (λ, wait) scalar in two
        vectorized passes and probe the cache per item, falling back to
        the table only on misses.  Elementwise identical to calling
        :meth:`solve` in sequence (the cache is exact per quantized key),
        but amortizes the per-call numpy scalar overhead — the shape the
        vectorized control plane and RL-scale rollout loops batch their
        per-tick lookups in.
        """
        n = len(remaining_slos_seq)
        lams = np.asarray(lams, np.float64)
        iws = (np.zeros(n) if initial_waits is None
               else np.asarray(initial_waits, np.float64))
        lq, bq = self.lam_quantum, self.budget_quantum
        lams_q = (np.ceil(lams / lq) * lq if lq > 0 else lams)
        iws_q = (np.ceil(iws / bq) * bq if bq > 0 else iws)
        out: List[Decision] = []
        for k in range(n):
            rem = np.sort(np.asarray(remaining_slos_seq[k],
                                     np.float64).ravel())
            if bq > 0:
                rem = np.floor(rem / bq) * bq
            lam_q, iw = float(lams_q[k]), float(iws_q[k])
            out.append(self._cached(
                (rem.tobytes(), lam_q, iw),
                lambda: self.table.solve(rem, lam_q, initial_wait=iw)))
        return out


# ---------------------------------------------------------------------------
# joint horizontal + vertical scaling (ISSUE 4 — the fleet layer)
# ---------------------------------------------------------------------------
def joint_candidates(c_set: Sequence[int], b_set: Sequence[int],
                     n_set: Sequence[int], replica_pen: float = 0.0):
    """The joint search order: every ``(n, c, b)`` triple sorted by
    ``(n*c + replica_pen*n, n, b)`` ascending — cheapest total core
    allocation first, fewer replicas on ties (less management churn,
    fewer cold starts), then smallest batch.  Returning the first
    feasible candidate in this order makes the joint solve the
    lexicographic optimum of the fleet IP (minimize total core-seconds
    ``n*c``), exactly as Algorithm 1's (c, b) iteration order does for
    the single-replica IP.

    ``replica_pen`` charges each replica a fixed core-equivalent
    overhead (control plane, weight duplication, cold-start exposure).
    At 0 the objective is pure total cores — which systematically
    prefers wide fleets of 1-core replicas (Amdahl makes low c the most
    core-efficient) whose thin latency margins amplify routing
    imbalance; a fraction of a core per replica restores the paper's
    vertical-first behavior (scale up in place, go horizontal only when
    the vertical axis saturates)."""
    return sorted((n * c + replica_pen * n, n, b, c)
                  for n in sorted(set(int(x) for x in n_set))
                  for c in sorted(set(int(x) for x in c_set))
                  for b in sorted(set(int(x) for x in b_set)))


def solve_joint_bruteforce(remaining_slos: Sequence[float], lam: float,
                           perf: Union[PerfModel, CostModel],
                           c_set: Sequence[int] = DEFAULT_C,
                           b_set: Sequence[int] = DEFAULT_B,
                           n_set: Sequence[int] = DEFAULT_N,
                           initial_wait: float = 0.0,
                           replica_pen: float = 0.0) -> Decision:
    """Algorithm 1 lifted to the fleet: pick ``(n, c, b)`` together.

    A fleet of ``n`` replicas, each vertically scaled to ``c`` cores and
    batching up to ``b``, drains the global EDF queue as a *striped*
    split: the k-th tightest request lands on replica ``k mod n``, so
    the fleet consumes EDF groups of ``n*b`` requests per batch round
    and every round takes one batch latency ``l(b, c)``.  The
    constraint set is therefore exactly Algorithm 1's with the group
    size ``b`` replaced by ``n*b`` and throughput ``n · h(b, c)``:

    * group i (0-indexed) finishes at ``initial_wait + (i+1)·l(b, c)``
      and must meet its head request's remaining budget ``rem[i·n·b]``;
    * sustained throughput ``n·b / l(b, c) >= λ``.

    Candidates are searched in :func:`joint_candidates` order (total
    cores ``n*c`` ascending), so the first feasible triple minimizes the
    fleet's total core allocation.  With ``n_set=(1,)`` this degenerates
    to :func:`solve_bruteforce` decision-for-decision (the reduction
    ``tests/test_fleet.py`` property-checks).  The infeasible fallback
    mirrors ``solve_bruteforce``: among λ-sustaining candidates, fewest
    predicted violations, ties broken by fastest drain.
    """
    t0 = time.perf_counter()
    rem = sorted(float(x) for x in remaining_slos)
    n_req = len(rem)
    iters = 0
    best_fallback = None  # (key, n, c, b)
    for _total, n, b, c in joint_candidates(c_set, b_set, n_set,
                                            replica_pen):
        iters += 1
        l = float(perf.latency(b, c))
        thr = n * float(perf.throughput(b, c))
        if lam > 0 and thr < lam:
            continue
        g = n * b
        ok = True
        q_r = initial_wait
        for i in range(0, max(n_req, 1), g):
            budget = rem[i] if n_req else float("inf")
            if l + q_r > budget:
                ok = False
                break
            q_r += l
            if n_req == 0:
                break
        if ok:
            return Decision(c=c, b=b, n=n, feasible=True,
                            solver_iters=iters,
                            solver_time=time.perf_counter() - t0)
        v = _predicted_violations(rem, l, g, initial_wait)
        key = (v, -thr)
        if best_fallback is None or key < best_fallback[0]:
            best_fallback = (key, n, c, b)
    if best_fallback is None:  # nothing sustains lam: max capacity config
        n = max(n_set)
        c = max(c_set)
        b = max(b_set, key=lambda bb: float(perf.throughput(bb, c)))
        best_fallback = ((n_req, 0.0), n, c, b)
    _, n, c, b = best_fallback
    return Decision(c=c, b=b, n=n, feasible=False, solver_iters=iters,
                    solver_time=time.perf_counter() - t0)


class JointSolverTable:
    """Vectorized joint ``(n, c, b)`` Algorithm 1 over precomputed grids.

    Shares the latency/throughput grids of a :class:`SolverTable` (they
    depend only on ``(perf, c_set, b_set)``) and pre-sorts the joint
    candidate order once (:func:`joint_candidates`).  ``solve`` answers
    each query with one vectorized drain check per ``(n, b)`` pair over
    all core counts at once; constraint set and fallback are exactly
    :func:`solve_joint_bruteforce`'s, term for term, so the two agree
    decision-for-decision (property-tested in ``tests/test_fleet.py``).

    ``only_n`` pins the replica count — the hysteresis re-solve path
    (``repro.serving.fleet.FleetSpongeScaler`` blocks a scale-down until
    the target persists, re-solving ``(c, b)`` at the current fleet
    size in the meantime).  ``max_cores`` caps the total allocation
    ``n*c`` — the multi-tenant pool (``repro.serving.tenancy``) solves
    each tenant under its current core cap, and
    :meth:`min_violations` reads the same feasibility frontier to price
    a core transfer between tenants.
    """

    def __init__(self, perf: Union[PerfModel, CostModel],
                 c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B,
                 n_set: Sequence[int] = DEFAULT_N,
                 replica_pen: float = 0.0):
        self.base = SolverTable(perf, c_set, b_set)
        self.perf = perf
        self.replica_pen = float(replica_pen)
        self.ns = np.asarray(sorted(set(int(x) for x in n_set)), np.int64)
        cands = joint_candidates(c_set, b_set, n_set, replica_pen)
        self.order_n = np.asarray([n for _, n, _, _ in cands], np.int64)
        self.order_b = np.asarray([b for _, _, b, _ in cands], np.int64)
        self.order_c = np.asarray([c for _, _, _, c in cands], np.int64)
        # map each ordered candidate to its (n, c, b) grid cell
        n_pos = {int(n): i for i, n in enumerate(self.ns)}
        c_pos = {int(c): i for i, c in enumerate(self.base.cs)}
        b_pos = {int(b): j for j, b in enumerate(self.base.bs)}
        self._flat = np.asarray(
            [(n_pos[int(n)] * self.base.lat.size
              + c_pos[int(c)] * len(self.base.bs) + b_pos[int(b)])
             for _, n, b, c in cands], np.int64)
        self._total = self.order_n * self.order_c   # cores per candidate
        self.size = len(cands)
        self._max_rate_cache: dict = {}

    def solve(self, remaining_slos, lam: float, initial_wait: float = 0.0,
              only_n: Optional[int] = None,
              max_cores: Optional[int] = None) -> Decision:
        """Joint solve; same inputs/semantics as
        :func:`solve_joint_bruteforce` (plus the ``only_n`` pin and the
        ``max_cores`` total-allocation cap)."""
        t0 = time.perf_counter()
        rem = np.sort(np.asarray(remaining_slos, np.float64).ravel())
        n_req = rem.size
        lat, thr = self.base.lat, self.base.thr          # (C, B)
        C, B = lat.shape
        N = len(self.ns)
        feas = np.ones((N, C, B), bool)
        thr_n = self.ns[:, None, None] * thr[None]       # (N, C, B)
        if lam > 0:
            feas &= thr_n >= lam
        sustain = feas.copy()
        if n_req:
            for i, n in enumerate(self.ns):
                for j in range(B):
                    g = int(n) * int(self.base.bs[j])
                    heads = rem[::g]
                    k = np.arange(1, heads.size + 1, dtype=np.float64)
                    finish = initial_wait + lat[:, j, None] * k
                    feas[i, :, j] &= (finish <= heads).all(axis=1)
        ok = feas.reshape(-1)[self._flat]
        if only_n is not None:
            ok = ok & (self.order_n == only_n)
        if max_cores is not None:
            ok = ok & (self._total <= max_cores)
        hit = np.flatnonzero(ok)
        if hit.size:
            i = int(hit[0])
            return Decision(c=int(self.order_c[i]), b=int(self.order_b[i]),
                            n=int(self.order_n[i]), feasible=True,
                            solver_iters=self.size,
                            solver_time=time.perf_counter() - t0)
        # fallback: among λ-sustaining candidates, fewest predicted
        # violations, then max fleet throughput, then candidate order
        sus = sustain.reshape(-1)[self._flat]
        if only_n is not None:
            sus = sus & (self.order_n == only_n)
        if max_cores is not None:
            sus = sus & (self._total <= max_cores)
        if sus.any():
            viol = np.zeros((N, C, B), np.int64)
            if n_req:
                idx = np.arange(n_req, dtype=np.int64)
                for i, n in enumerate(self.ns):
                    for j in range(B):
                        g = int(n) * int(self.base.bs[j])
                        mult = (idx // g + 1).astype(np.float64)
                        finish = initial_wait + lat[:, j, None] * mult
                        viol[i, :, j] = (finish > rem).sum(axis=1)
            key1 = np.where(sus, viol.reshape(-1)[self._flat]
                            .astype(np.float64), np.inf)
            cand = np.flatnonzero(key1 == key1.min())
            thr_flat = thr_n.reshape(-1)[self._flat][cand]
            i = int(cand[np.flatnonzero(thr_flat == thr_flat.max())[0]])
            n, c, b = (int(self.order_n[i]), int(self.order_c[i]),
                       int(self.order_b[i]))
        elif max_cores is None:   # nothing sustains lam: max capacity
            n = int(only_n if only_n is not None else self.ns[-1])
            c = int(self.base.cs[-1])
            j = int(np.argmax(self.base.thr[-1]))
            b = int(self.base.bs[j])
        else:
            # capped overload: the largest fleet throughput that still
            # fits the core cap (honouring the pin when possible), so a
            # starved tenant saturates its slice rather than claiming
            # cores the pool never granted
            fit = self._total <= max_cores
            if only_n is not None and (fit & (self.order_n == only_n)).any():
                fit = fit & (self.order_n == only_n)
            if fit.any():
                key = np.where(fit, thr_n.reshape(-1)[self._flat]
                               .astype(np.float64), -np.inf)
                cand = np.flatnonzero(key == key.max())
                tot = self._total[cand]
                i = int(cand[np.flatnonzero(tot == tot.min())[0]])
            else:        # cap below every candidate: cheapest config
                i = 0
            n, c, b = (int(self.order_n[i]), int(self.order_c[i]),
                       int(self.order_b[i]))
        return Decision(c=c, b=b, n=n, feasible=False,
                        solver_iters=self.size,
                        solver_time=time.perf_counter() - t0)

    def min_violations(self, remaining_slos, lam: float,
                       initial_wait: float = 0.0,
                       max_cores: Optional[int] = None,
                       sustaining_pool: bool = True) -> int:
        """Fewest predicted EDF violations achievable under ``max_cores``.

        Reads the same frontier as :meth:`solve`: ``0`` when any
        candidate under the cap drains the queue in time, otherwise the
        minimum of the predicted-violation grid among λ-sustaining
        candidates under the cap (falling back to every candidate under
        the cap, then to the whole queue length when the cap excludes
        every candidate).  This is the value function ``V(cap)`` that
        the multi-tenant reallocator (``repro.serving.tenancy``)
        differentiates to price a core transfer between tenants.

        ``sustaining_pool=False`` skips the λ-sustaining preference and
        minimizes over every candidate under the cap — the pool the
        (m, n, c, b) fallback needs, because cross-rung counts are only
        comparable when every rung minimizes over the same grid (a
        sustaining-restricted pool can report *more* violations for a
        strictly faster rung).
        """
        rem = np.sort(np.asarray(remaining_slos, np.float64).ravel())
        n_req = rem.size
        if n_req == 0:
            return 0
        lat, thr = self.base.lat, self.base.thr          # (C, B)
        C, B = lat.shape
        N = len(self.ns)
        feas = np.ones((N, C, B), bool)
        thr_n = self.ns[:, None, None] * thr[None]       # (N, C, B)
        if lam > 0:
            feas &= thr_n >= lam
        sustain = feas.copy()
        for i, n in enumerate(self.ns):
            for j in range(B):
                g = int(n) * int(self.base.bs[j])
                heads = rem[::g]
                k = np.arange(1, heads.size + 1, dtype=np.float64)
                finish = initial_wait + lat[:, j, None] * k
                feas[i, :, j] &= (finish <= heads).all(axis=1)
        fit = (np.ones(self.size, bool) if max_cores is None
               else self._total <= max_cores)
        if (feas.reshape(-1)[self._flat] & fit).any():
            return 0
        viol = np.zeros((N, C, B), np.int64)
        idx = np.arange(n_req, dtype=np.int64)
        for i, n in enumerate(self.ns):
            for j in range(B):
                g = int(n) * int(self.base.bs[j])
                mult = (idx // g + 1).astype(np.float64)
                finish = initial_wait + lat[:, j, None] * mult
                viol[i, :, j] = (finish > rem).sum(axis=1)
        sus = sustain.reshape(-1)[self._flat] & fit
        pool = sus if (sustaining_pool and sus.any()) else fit
        if not pool.any():
            return n_req
        return int(viol.reshape(-1)[self._flat][pool].min())

    def max_rate(self, max_cores: Optional[int] = None) -> float:
        """Highest arrival rate any candidate under ``max_cores``
        sustains — the fleet throughput ceiling of the capped frontier
        (the same ``n·thr`` surface :meth:`solve` tests ``λ`` against).
        Arrivals beyond this rate are un-servable at the cap no matter
        the backlog, which is what lets the multi-tenant reallocator
        price a core transfer *before* the queue melts down.  Cached per
        cap (the grid never changes)."""
        key = -1 if max_cores is None else int(max_cores)
        hit = self._max_rate_cache.get(key)
        if hit is not None:
            return hit
        thr_n = (self.ns[:, None, None] *
                 self.base.thr[None]).reshape(-1)[self._flat]
        fit = (np.ones(self.size, bool) if max_cores is None
               else self._total <= max_cores)
        val = float(thr_n[fit].max()) if fit.any() else 0.0
        self._max_rate_cache[key] = val
        return val


class JointMemoizedSolver(_QuantizedDecisionCache):
    """Quantized decision cache in front of a :class:`JointSolverTable`
    — the shared :class:`_QuantizedDecisionCache` bucketing with the
    replica pin ``only_n`` folded into the cache key."""

    def __init__(self, perf: Union[PerfModel, CostModel],
                 c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B,
                 n_set: Sequence[int] = DEFAULT_N,
                 budget_quantum: float = 0.0, lam_quantum: float = 0.0,
                 replica_pen: float = 0.0, max_entries: int = 200_000):
        super().__init__(budget_quantum, lam_quantum, max_entries)
        self.table = JointSolverTable(perf, c_set, b_set, n_set,
                                      replica_pen)

    def solve(self, remaining_slos, lam: float, initial_wait: float = 0.0,
              only_n: Optional[int] = None,
              max_cores: Optional[int] = None) -> Decision:
        """Quantize conservatively, then cache per bucket signature."""
        rem = np.sort(np.asarray(remaining_slos, np.float64).ravel())
        rem, lam_q, iw = self._quantize(rem, lam, initial_wait)
        return self._cached(
            (rem.tobytes(), lam_q, iw, only_n, max_cores),
            lambda: self.table.solve(rem, lam_q, initial_wait=iw,
                                     only_n=only_n, max_cores=max_cores))


# ---------------------------------------------------------------------------
# (m, n, c, b): the model-size axis (ISSUE 9 — accuracy degradation)
# ---------------------------------------------------------------------------
def _joint_min_violations_bruteforce(rem, lam: float, perf, c_set, b_set,
                                     n_set, initial_wait: float,
                                     max_cores: Optional[int] = None,
                                     sustaining_pool: bool = True) -> int:
    """Loop-and-count reference for ``JointSolverTable.min_violations``
    (same tier structure: 0 if any candidate drains in time, else the
    minimum over λ-sustaining candidates, else over all candidates,
    else the whole queue; ``sustaining_pool=False`` minimizes over all
    candidates directly — the cross-rung-comparable pool)."""
    rem = sorted(float(x) for x in rem)
    n_req = len(rem)
    if n_req == 0:
        return 0
    best_sus = None
    best_any = None
    for _total, n, b, c in joint_candidates(c_set, b_set, n_set):
        if max_cores is not None and n * c > max_cores:
            continue
        l = float(perf.latency(b, c))
        v = _predicted_violations(rem, l, n * b, initial_wait)
        sustains = lam <= 0 or n * float(perf.throughput(b, c)) >= lam
        if sustains and (best_sus is None or v < best_sus):
            best_sus = v
        if best_any is None or v < best_any:
            best_any = v
    if sustaining_pool and best_sus is not None:
        return best_sus
    if best_any is not None:
        return best_any
    return n_req


def solve_multimodel_bruteforce(remaining_slos, lam: float, ladder,
                                c_set: Sequence[int] = DEFAULT_C,
                                b_set: Sequence[int] = DEFAULT_B,
                                n_set: Sequence[int] = DEFAULT_N,
                                initial_wait: float = 0.0,
                                replica_pen: float = 0.0,
                                accuracy_floor: float = 0.0,
                                m_set: Optional[Sequence[str]] = None,
                                current_m: Optional[str] = None,
                                ) -> Decision:
    """The (m, n, c, b) reference solver: Algorithm 1 lifted to the
    fleet *and* the model ladder.

    Rungs are searched in accuracy-descending order (the
    ``ModelLadder`` iteration order), each via the joint (n, c, b)
    solve on the rung's own cost surface; the first rung with any
    feasible allocation wins.  Accuracy is therefore **shed only when
    no (n, c, b) at every higher rung is feasible** — the candidate
    order prefers higher-accuracy models unconditionally, making the
    shed provable rather than a weighted trade-off.

    ``accuracy_floor`` removes rungs below the SLO's quality floor
    from the search entirely; ``m_set`` pins the admissible rungs (a
    single-name pin reduces to :func:`solve_joint_bruteforce` on that
    rung, decision-for-decision).  ``current_m`` makes the search
    swap-cost-aware: any rung other than the currently loaded model
    charges its weights-load time on top of ``initial_wait`` (the
    fleet cannot serve on a rung before its weights arrive), so a
    degradation must be worth its own swap.

    When no admissible rung has a feasible allocation, the fallback
    compares rungs by (1) fewest predicted queued violations, counted
    over *every* (n, c, b) candidate (the only pool in which a strictly
    faster rung can never report more violations), then (2) the largest
    capacity-accuracy product ``min(lam, ceiling) * accuracy`` — the
    sustainable accuracy-weighted serve rate, which hands the win to
    the highest-accuracy rung that absorbs ``lam`` and degrades
    smoothly to throughput damage control when nothing does — then
    (3) higher accuracy (earlier in the ladder), and returns that
    rung's damage-minimizing joint fallback.
    """
    t0 = time.perf_counter()
    rungs = ladder.admissible(accuracy_floor, m_set)
    iters = 0
    best = None          # ((violations, -capacity*acc), rung, decision)
    for rung in rungs:
        iw = initial_wait
        if current_m is not None and rung.name != current_m:
            iw = initial_wait + float(rung.swap_cost)
        d = solve_joint_bruteforce(remaining_slos, lam, rung.cost,
                                   c_set, b_set, n_set,
                                   initial_wait=iw,
                                   replica_pen=replica_pen)
        iters += d.solver_iters
        if d.feasible:
            return replace(d, m=rung.name, solver_iters=iters,
                           solver_time=time.perf_counter() - t0)
        v = _joint_min_violations_bruteforce(
            remaining_slos, lam, rung.cost, c_set, b_set, n_set, iw,
            sustaining_pool=False)
        ceiling = max(n * float(rung.cost.throughput(b, c))
                      for _t, n, b, c in joint_candidates(c_set, b_set,
                                                          n_set))
        key = (v, -min(max(lam, 0.0), ceiling) * rung.accuracy)
        if best is None or key < best[0]:
            best = (key, rung, d)
    _, rung, d = best
    return replace(d, m=rung.name, solver_iters=iters,
                   solver_time=time.perf_counter() - t0)


class MultiModelSolverTable:
    """The (m, n, c, b) solver: one :class:`JointSolverTable` per
    ladder rung, searched in accuracy-descending order.

    Semantics are exactly :func:`solve_multimodel_bruteforce`'s, rung
    for rung: accuracy is shed only when every (n, c, b) at every
    higher admissible rung is infeasible, ``accuracy_floor`` bounds
    the shed, ``current_m`` charges non-resident rungs their
    weights-load time, and the all-infeasible fallback returns the
    damage-minimizing decision of the best rung under the ordering
    (fewest predicted violations over the all-candidate pool, largest
    capacity-accuracy product under the core cap, higher accuracy).

    **Pinned-m reduction**: with ``m_set=(rung,)`` and no swap charge
    (``current_m`` absent or equal to the pin) the solve is a single
    delegation to that rung's :class:`JointSolverTable` — bit-identical
    to the PR 4 joint solver by construction, with only the ``m`` tag
    added (property-tested in ``tests/test_degradation.py``).
    """

    def __init__(self, ladder, c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B,
                 n_set: Sequence[int] = DEFAULT_N,
                 replica_pen: float = 0.0):
        self.ladder = ladder
        self.tables = {
            rung.name: JointSolverTable(rung.cost, c_set, b_set, n_set,
                                        replica_pen)
            for rung in ladder}
        self.size = sum(t.size for t in self.tables.values())

    def _rung_wait(self, rung, initial_wait: float,
                   current_m: Optional[str]) -> float:
        if current_m is not None and rung.name != current_m:
            return initial_wait + float(rung.swap_cost)
        return initial_wait

    def solve(self, remaining_slos, lam: float, initial_wait: float = 0.0,
              only_n: Optional[int] = None,
              max_cores: Optional[int] = None,
              accuracy_floor: float = 0.0,
              m_set: Optional[Sequence[str]] = None,
              current_m: Optional[str] = None) -> Decision:
        t0 = time.perf_counter()
        rungs = self.ladder.admissible(accuracy_floor, m_set)
        if len(rungs) == 1:
            # the pinned-m reduction: pure delegation (bit-identical
            # to JointSolverTable.solve on that rung, m tag aside)
            rung = rungs[0]
            d = self.tables[rung.name].solve(
                remaining_slos, lam,
                initial_wait=self._rung_wait(rung, initial_wait,
                                             current_m),
                only_n=only_n, max_cores=max_cores)
            return replace(d, m=rung.name)
        iters = 0
        best = None          # ((violations, -capacity*acc), rung, decision)
        for rung in rungs:
            iw = self._rung_wait(rung, initial_wait, current_m)
            table = self.tables[rung.name]
            d = table.solve(remaining_slos, lam, initial_wait=iw,
                            only_n=only_n, max_cores=max_cores)
            iters += d.solver_iters
            if d.feasible:
                return replace(d, m=rung.name, solver_iters=iters,
                               solver_time=time.perf_counter() - t0)
            # violations counted over the all-candidate pool — the only
            # pool in which a strictly faster rung can never report
            # more violations — then the capacity-accuracy product
            # min(lam, ceiling)*acc: the sustainable accuracy-weighted
            # serve rate (blind queued counts cannot see that a rung
            # which absorbs lam stops the backlog growing)
            v = table.min_violations(remaining_slos, lam, initial_wait=iw,
                                     max_cores=max_cores,
                                     sustaining_pool=False)
            cap_acc = (min(max(lam, 0.0), table.max_rate(max_cores))
                       * rung.accuracy)
            key = (v, -cap_acc)
            if best is None or key < best[0]:
                best = (key, rung, d)
        _, rung, d = best
        return replace(d, m=rung.name, solver_iters=iters,
                       solver_time=time.perf_counter() - t0)


class MultiModelMemoizedSolver(_QuantizedDecisionCache):
    """Quantized decision cache in front of a
    :class:`MultiModelSolverTable` — the shared conservative bucketing
    with the degradation knobs (floor, rung pin, resident model)
    folded into the cache key."""

    def __init__(self, ladder, c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B,
                 n_set: Sequence[int] = DEFAULT_N,
                 budget_quantum: float = 0.0, lam_quantum: float = 0.0,
                 replica_pen: float = 0.0, max_entries: int = 200_000):
        super().__init__(budget_quantum, lam_quantum, max_entries)
        self.table = MultiModelSolverTable(ladder, c_set, b_set, n_set,
                                           replica_pen)

    def solve(self, remaining_slos, lam: float, initial_wait: float = 0.0,
              only_n: Optional[int] = None,
              max_cores: Optional[int] = None,
              accuracy_floor: float = 0.0,
              m_set: Optional[Sequence[str]] = None,
              current_m: Optional[str] = None) -> Decision:
        rem = np.sort(np.asarray(remaining_slos, np.float64).ravel())
        rem, lam_q, iw = self._quantize(rem, lam, initial_wait)
        pins = None if m_set is None else tuple(m_set)
        return self._cached(
            (rem.tobytes(), lam_q, iw, only_n, max_cores,
             round(float(accuracy_floor), 12), pins, current_m),
            lambda: self.table.solve(rem, lam_q, initial_wait=iw,
                                     only_n=only_n, max_cores=max_cores,
                                     accuracy_floor=accuracy_floor,
                                     m_set=pins, current_m=current_m))


# ---------------------------------------------------------------------------
# token-level Algorithm 1 (phase-aware autoregressive serving)
# ---------------------------------------------------------------------------
def _token_edf_order(ttft_budgets, prompt_tokens):
    """Sort (budget, tokens) pairs by budget ascending (EDF), stably."""
    rem = np.asarray(ttft_budgets, np.float64).ravel()
    toks = np.asarray(prompt_tokens, np.float64).ravel()
    assert rem.shape == toks.shape, (rem.shape, toks.shape)
    order = np.argsort(rem, kind="stable")
    return rem[order], toks[order]


def _group_token_sums(toks: np.ndarray, b: int) -> np.ndarray:
    """Total prompt tokens of each EDF group of b (last group ragged)."""
    n = toks.size
    g = (n + b - 1) // b
    padded = np.zeros(g * b, np.float64)
    padded[:n] = toks
    return padded.reshape(g, b).sum(axis=1)


def solve_token_bruteforce(ttft_budgets, prompt_tokens, lam: float,
                           cost: TokenCostModel,
                           c_set: Sequence[int] = DEFAULT_C,
                           b_set: Sequence[int] = DEFAULT_B,
                           initial_wait: float = 0.0,
                           tbt_budget: float = float("inf"),
                           active_slots: int = 0,
                           mean_decode: Optional[float] = None,
                           drag_steps: Optional[float] = None) -> Decision:
    """Algorithm 1 extended to token compositions — reference semantics.

    Iterate c ascending then b ascending and return the first (c, b)
    that satisfies all three constraint families (the lexicographic IP
    optimum, exactly as in the fixed-work solver):

    * **TBT**: ``decode_latency(c, b) <= tbt_budget`` whenever a decode
      stream exists (``active_slots > 0`` or the workload decodes at
      all) — b is the decode-slot cap the engine runs at;
    * **λ**: full-service throughput ``cost.throughput(b, c) >= lam``;
    * **TTFT**: EDF groups of b prefill in order; group i finishes at
      ``initial_wait + Σ_{j<=i} (prefill_latency(c, T_j) + drag)`` and
      must meet its head request's remaining TTFT budget.  ``drag`` is
      ``drag_steps`` decode steps at concurrency b when a decode stream
      exists — the time a full group of slots takes to turn over before
      the next group's prompts can join (default: the mean decode
      length, i.e. a slot frees when its stream finishes), else 0.

    The infeasible fallback mirrors ``solve_bruteforce``: fewest
    predicted TTFT violations among λ-sustaining configs, ties broken by
    fastest drain.
    """
    t0 = time.perf_counter()
    rem, toks = _token_edf_order(ttft_budgets, prompt_tokens)
    n = rem.size
    md = cost.mean_decode if mean_decode is None else mean_decode
    decode_present = active_slots > 0 or md > 0
    dsteps = md if drag_steps is None else drag_steps
    iters = 0
    best_fallback = None
    for c in sorted(c_set):
        for b in sorted(b_set):
            iters += 1
            l_d = float(cost.decode_latency(c, b))
            if decode_present and l_d > tbt_budget:
                continue
            if lam > 0 and float(cost.throughput(b, c)) < lam:
                continue
            drag = l_d * dsteps if decode_present else 0.0
            ok = True
            viol = 0
            q_r = initial_wait
            if n:
                sums = _group_token_sums(toks, b)
                for i, T in enumerate(sums):
                    step = float(cost.prefill_latency(c, T)) + drag
                    finish = q_r + step
                    head = rem[i * b]
                    if finish > head:
                        ok = False
                        viol += int((finish
                                     > rem[i * b:(i + 1) * b]).sum())
                    elif not ok:
                        viol += int((finish
                                     > rem[i * b:(i + 1) * b]).sum())
                    q_r = finish
            if ok:
                return Decision(c=c, b=b, feasible=True, solver_iters=iters,
                                solver_time=time.perf_counter() - t0,
                                predicted_tbt=l_d)
            key = (viol, -float(cost.throughput(b, c)))
            if best_fallback is None or key < best_fallback[0]:
                best_fallback = (key, c, b, l_d)
    if best_fallback is None:       # nothing passes TBT+λ: max capacity
        c = max(c_set)
        b = max(b_set, key=lambda bb: float(cost.throughput(bb, c)))
        best_fallback = ((n, 0.0), c, b, float(cost.decode_latency(c, b)))
    _, c, b, l_d = best_fallback
    return Decision(c=c, b=b, feasible=False, solver_iters=iters,
                    solver_time=time.perf_counter() - t0, predicted_tbt=l_d)


class TokenSolverTable:
    """Vectorized token-level Algorithm 1 over precomputed (c, b) grids.

    The decode-step latency grid, full-service throughput grid and the
    (c, b) lexicographic iteration order depend only on
    (cost, c_set, b_set) and are computed once; ``solve`` answers each
    query with one vectorized pass per batch size (prefill latencies of
    the EDF token groups, a cumulative drain, comparisons against the
    group heads).  Constraint set and fallback are exactly
    :func:`solve_token_bruteforce`'s — the float expressions are shared
    term for term (including the sequential accumulation order of the
    drain), so the two agree decision-for-decision (property-tested in
    ``tests/test_token_serving.py``).
    """

    def __init__(self, cost: TokenCostModel,
                 c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B):
        self.cost = cost
        self.cs = np.asarray(sorted(c_set), np.int64)
        self.bs = np.asarray(sorted(b_set), np.int64)
        cc, bb = np.meshgrid(self.cs, self.bs, indexing="ij")     # (C, B)
        self.dec = np.asarray(cost.decode_latency(cc.astype(np.float64), bb),
                              np.float64)
        self.thr = np.asarray(cost.throughput(bb, cc), np.float64)
        self.c_flat = cc.ravel()
        self.b_flat = bb.ravel()
        self.size = self.dec.size

    def solve(self, ttft_budgets, prompt_tokens, lam: float,
              initial_wait: float = 0.0,
              tbt_budget: float = float("inf"),
              active_slots: int = 0,
              mean_decode: Optional[float] = None,
              drag_steps: Optional[float] = None) -> Decision:
        """Token-composition solve; same inputs and semantics as
        :func:`solve_token_bruteforce`."""
        t0 = time.perf_counter()
        rem, toks = _token_edf_order(ttft_budgets, prompt_tokens)
        n = rem.size
        md = self.cost.mean_decode if mean_decode is None else mean_decode
        decode_present = active_slots > 0 or md > 0
        dsteps = md if drag_steps is None else drag_steps
        C, B = self.dec.shape
        tbt_ok = (self.dec <= tbt_budget) if decode_present \
            else np.ones((C, B), bool)
        sustain = (self.thr >= lam) if lam > 0 else np.ones((C, B), bool)
        feas = tbt_ok & sustain
        viol = np.zeros((C, B), np.int64)
        cf = self.cs.astype(np.float64)
        if n:
            for j in range(B):
                b = int(self.bs[j])
                sums = _group_token_sums(toks, b)               # (g,)
                lp = np.asarray(self.cost.prefill_latency(
                    cf[:, None], sums[None, :]), np.float64)    # (C, g)
                drag = (self.dec[:, j, None] * dsteps
                        if decode_present else 0.0)
                steps = lp + drag
                # fold initial_wait into the first step so the cumulative
                # sum reproduces the bruteforce's sequential additions
                # ((iw + s0) + s1 ...) bit for bit
                steps[:, 0] += initial_wait
                finish = np.cumsum(steps, axis=1)               # (C, g)
                heads = rem[::b]                                # (g,)
                feas[:, j] &= (finish <= heads[None, :]).all(axis=1)
                per_req = np.repeat(finish, b, axis=1)[:, :n]   # (C, n)
                viol[:, j] = (per_req > rem[None, :]).sum(axis=1)
        ok = feas.ravel()
        hit = np.flatnonzero(ok)
        if hit.size:
            i = int(hit[0])
            return Decision(c=int(self.c_flat[i]), b=int(self.b_flat[i]),
                            feasible=True, solver_iters=self.size,
                            solver_time=time.perf_counter() - t0,
                            predicted_tbt=float(self.dec.ravel()[i]))
        pool = tbt_ok & sustain
        pool_flat = pool.ravel()
        if pool_flat.any():
            key1 = np.where(pool_flat, viol.ravel().astype(np.float64),
                            np.inf)
            cand = np.flatnonzero(key1 == key1.min())
            thr_c = self.thr.ravel()[cand]
            i = int(cand[np.flatnonzero(thr_c == thr_c.max())[0]])
            c, b = int(self.c_flat[i]), int(self.b_flat[i])
            l_d = float(self.dec.ravel()[i])
        else:                   # nothing passes TBT+λ: max capacity
            c = int(self.cs[-1])
            j = int(np.argmax(self.thr[-1]))
            b = int(self.bs[j])
            l_d = float(self.dec[-1, j])
        return Decision(c=c, b=b, feasible=False, solver_iters=self.size,
                        solver_time=time.perf_counter() - t0,
                        predicted_tbt=l_d)


class TokenMemoizedSolver(_QuantizedDecisionCache):
    """Quantized decision cache in front of a :class:`TokenSolverTable`.

    The shared :class:`_QuantizedDecisionCache` bucketing, extended to
    the token inputs with the same conservative direction:

    * the TBT budget is *floored* to ``budget_quantum`` — cached
      decisions never assume more per-token slack;
    * prompt-token counts are *ceiled* to ``token_quantum`` tokens —
      never less work.

    ``hits`` / ``misses`` / ``hit_rate`` feed
    ``benchmarks/token_serving_bench.py``.
    """

    def __init__(self, cost: TokenCostModel,
                 c_set: Sequence[int] = DEFAULT_C,
                 b_set: Sequence[int] = DEFAULT_B,
                 budget_quantum: float = 0.0, lam_quantum: float = 0.0,
                 token_quantum: int = 0, max_entries: int = 200_000):
        super().__init__(budget_quantum, lam_quantum, max_entries)
        self.table = TokenSolverTable(cost, c_set, b_set)
        self.token_quantum = int(token_quantum)

    def solve(self, ttft_budgets, prompt_tokens, lam: float,
              initial_wait: float = 0.0,
              tbt_budget: float = float("inf"),
              active_slots: int = 0,
              mean_decode: Optional[float] = None,
              drag_steps: Optional[float] = None) -> Decision:
        """Quantize conservatively, then cache per bucket signature."""
        rem, toks = _token_edf_order(ttft_budgets, prompt_tokens)
        rem, lam_q, iw = self._quantize(rem, lam, initial_wait)
        bq, tq = self.budget_quantum, self.token_quantum
        tbt = (float(np.floor(tbt_budget / bq) * bq)
               if bq > 0 and np.isfinite(tbt_budget) else float(tbt_budget))
        if tq > 0:
            toks = np.ceil(toks / tq) * tq
        md = self.table.cost.mean_decode if mean_decode is None \
            else mean_decode
        decode_present = active_slots > 0 or md > 0
        return self._cached(
            (rem.tobytes(), toks.tobytes(), lam_q, iw, tbt,
             decode_present, drag_steps, md),
            lambda: self.table.solve(
                rem, toks, lam_q, initial_wait=iw, tbt_budget=tbt,
                active_slots=1 if decode_present else 0,
                mean_decode=md, drag_steps=drag_steps))
