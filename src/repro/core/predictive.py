"""Beyond-paper extension: predictive scaling.

Sponge is reactive — it sees shrunken budgets only when requests *arrive*
(after the network delay), so the first ~1 adaptation interval of every
bandwidth fade is served under a stale allocation.  This scaler forecasts
the near-future communication latency with double exponential smoothing
(Holt, damped trend) over observed per-request comm latencies and tightens
the solver's budgets by the predicted *increase*, scaling up BEFORE the
slow requests land.  Evaluated in benchmarks/predictive_bench.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.queueing import EDFQueue
from repro.core.scaler import SpongeScaler
from repro.core.slo import Decision


class HoltForecaster:
    """Double exponential smoothing with trend damping."""

    def __init__(self, alpha: float = 0.4, beta: float = 0.2,
                 phi: float = 0.9):
        self.alpha, self.beta, self.phi = alpha, beta, phi
        self.level: Optional[float] = None
        self.trend: float = 0.0

    def observe(self, x: float) -> None:
        if self.level is None:
            self.level = x
            return
        prev = self.level
        self.level = (self.alpha * x
                      + (1 - self.alpha) * (self.level + self.phi * self.trend))
        self.trend = (self.beta * (self.level - prev)
                      + (1 - self.beta) * self.phi * self.trend)

    def forecast(self, steps: float = 1.0) -> float:
        if self.level is None:
            return 0.0
        return self.level + self.phi * self.trend * steps


@dataclass
class PredictiveSpongeScaler(SpongeScaler):
    """SpongeScaler + comm-latency forecast folded into the budgets."""
    horizon_s: float = 1.0
    forecaster: HoltForecaster = field(default_factory=HoltForecaster)

    def observe_comm_latency(self, cl: float) -> None:
        self.forecaster.observe(cl)

    def forecast_increase(self) -> float:
        lvl = self.forecaster.level or 0.0
        return max(self.forecaster.forecast(self.horizon_s) - lvl, 0.0)

    def decide(self, now: float, queue: EDFQueue, lam: float,
               initial_wait: float = 0.0) -> Decision:
        saved = self.headroom
        self.headroom = saved + self.forecast_increase()
        try:
            return super().decide(now, queue, lam, initial_wait)
        finally:
            self.headroom = saved


@dataclass
class PredictivePolicy:
    """Policy wrapping the predictive scaler: feeds each observed request's
    comm latency to the forecaster exactly once (in arrival order — the
    signal a real gateway has).  Overrides ``on_tick`` only to feed the
    forecaster before the standard drive path runs."""
    scaler: PredictiveSpongeScaler
    name: str = "sponge-pred"
    _seen: set = field(default_factory=set)

    def _feed(self, sim) -> None:
        # Read the live-entry snapshot, never the raw heap: after a
        # deadline re-key the heap holds stale duplicates (double-feed)
        # and after a cancel it still holds the dead tuple (a request
        # that will never be served polluting the forecast).
        pending = [req for req in sim.queue.live_requests()
                   if req.id not in self._seen]
        done = [r for r in sim.monitor.completed if r.id not in self._seen]
        for r in sorted(pending + done, key=lambda r: r.arrival):
            self.scaler.observe_comm_latency(r.comm_latency)
            self._seen.add(r.id)

    def due(self, now: float) -> bool:
        return self.scaler.due(now)

    def decide(self, now: float, queue: EDFQueue, lam: float,
               initial_wait: float = 0.0) -> Decision:
        return self.scaler.decide(now, queue, lam, initial_wait=initial_wait)

    @property
    def decisions(self):
        return self.scaler.decisions

    def on_tick(self, now: float, sim) -> None:
        self._feed(sim)
        sim.drive(self, now)


@dataclass
class TelemetryPolicy:
    """Bandwidth-telemetry predictive scaling (beyond-paper, second attempt
    after the Holt-forecast hypothesis was refuted — see EXPERIMENTS.md).

    The serving gateway KNOWS the instantaneous link bandwidth (it is its
    own link).  Requests currently in flight were sent under the *current*
    bandwidth and will arrive with budget ~ SLO - cl(bw_now); during a fade
    that is less than every queued request's budget, so the reactive solver
    under-provisions for one round-trip.  This policy injects the expected
    in-flight requests (count ~ lam * cl_now) as synthetic budget entries.
    """
    scaler: SpongeScaler
    trace: object               # BandwidthTrace
    size_kb: float = 200.0
    slo: float = 1.0
    name: str = "sponge-telem"

    def due(self, now: float) -> bool:
        return self.scaler.due(now)

    def decide(self, now: float, queue: EDFQueue, lam: float,
               initial_wait: float = 0.0) -> Decision:
        from repro.network.latency import comm_latency
        cl_now = comm_latency(self.size_kb, self.trace, now)
        n_inflight = int(lam * cl_now)
        extra = tuple(max(self.slo - cl_now, 0.0) + i / max(lam, 1e-6)
                      for i in range(n_inflight))
        return self.scaler.decide(now, queue, lam, initial_wait=initial_wait,
                                  extra_budgets=extra)

    @property
    def decisions(self):
        return self.scaler.decisions

    def on_tick(self, now: float, sim) -> None:
        sim.drive(self, now)
