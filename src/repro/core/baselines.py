"""Baseline autoscalers the paper compares against (§4 "Baseline").

* ``FA2Policy`` — an FA2-style horizontal autoscaler: fixed one-core
  instances, batch chosen for max throughput under the *static* SLO
  (it does not see per-request network latency — exactly its failure mode),
  reconfiguration every ~10 s, new instances pay a cold start.
* ``StaticPolicy`` — statically assigned c (8 or 16 cores), dynamic batching
  via the same solver with c pinned.
* ``SpongePolicy`` — the paper's system: single instance, in-place vertical
  scaling + EDF + dynamic batching via the IP solver.

All of them implement the one ``SchedulingPolicy`` protocol
(``repro.serving.api``): ``decide(now, queue, lam, initial_wait)`` returns
a ``Decision`` — including a replica target ``n`` for horizontal policies —
which the runner applies to whichever ExecutionBackend is plugged in.
``Policy.on_tick`` remains as the driver entry point; policies that need
direct pool access (e.g. ``MultiDimPolicy``) may still override it.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Sequence

from repro.core.perf_model import PerfModel
from repro.core.queueing import EDFQueue
from repro.core.scaler import SpongeScaler
from repro.core.slo import Decision
from repro.core.solver import DEFAULT_B, solve_bruteforce


class Policy:
    """Base scheduling policy: subclasses implement ``decide``; the
    default ``on_tick`` routes through the runner's single drive path."""

    name = "base"

    def due(self, now: float) -> bool:
        return True

    def decide(self, now: float, queue: EDFQueue, lam: float,
               initial_wait: float = 0.0) -> Decision:  # pragma: no cover
        raise NotImplementedError

    def on_tick(self, now: float, sim) -> None:
        sim.drive(self, now)


@dataclass
class SpongePolicy(Policy):
    scaler: SpongeScaler
    name: str = "sponge"

    def due(self, now: float) -> bool:
        return self.scaler.due(now)

    def decide(self, now: float, queue: EDFQueue, lam: float,
               initial_wait: float = 0.0) -> Decision:
        return self.scaler.decide(now, queue, lam,
                                  initial_wait=initial_wait)

    @property
    def decisions(self):
        return self.scaler.decisions


@dataclass
class StaticPolicy(Policy):
    perf: PerfModel
    cores: int = 16
    b_set: Sequence[int] = DEFAULT_B
    interval: float = 1.0
    name: str = "static"
    decisions: List[tuple] = field(default_factory=list)
    _next_t: float = 0.0

    def __post_init__(self):
        self.name = f"static-{self.cores}"

    def due(self, now: float) -> bool:
        return now + 1e-12 >= self._next_t

    def decide(self, now: float, queue: EDFQueue, lam: float,
               initial_wait: float = 0.0) -> Decision:
        self._next_t = now + self.interval
        rem = queue.snapshot_remaining(now)
        d = solve_bruteforce(rem, lam, self.perf, (self.cores,), self.b_set,
                             initial_wait=initial_wait)
        self.decisions.append((now, d))
        return d


@dataclass
class FA2Policy(Policy):
    """Horizontal autoscaling with one-core instances (paper §2.1).

    Chooses b* = argmax_b h(b, 1) s.t. l(b,1) <= slo_budget (FA2 plans with
    the nominal SLO; it cannot see per-request comm latency), targets
    n = ceil(lambda / h(b*, 1)) instances.  Scale-ups pay ``cold_start``
    seconds before the instance serves; reconfiguration happens every
    ``reconfig_interval`` (~10 s to find + adjust + stabilize per the
    paper).  The first decision is the deploy-time warm start (sized to
    ``expected_rps``, no cold start — deployed pre-stabilized, as in the
    paper).
    """
    perf: PerfModel
    slo: float = 1.0
    instance_cores: int = 1
    b_set: Sequence[int] = DEFAULT_B
    reconfig_interval: float = 10.0
    cold_start: float = 10.0
    slo_budget_frac: float = 0.7        # FA2 plans within the NOMINAL SLO (it
                                        # cannot see per-request comm latency)
    max_instances: int = 32
    expected_rps: float = 0.0
    drain_horizon: float = 10.0         # drain backlog within this window
    name: str = "fa2"
    decisions: List[tuple] = field(default_factory=list)
    _next_t: float = 0.0
    _warmed: bool = False

    def best_batch(self) -> int:
        budget = self.slo * self.slo_budget_frac
        best_b, best_h = 1, -1.0
        for b in sorted(self.b_set):
            l = float(self.perf.latency(b, self.instance_cores))
            if l > budget:
                continue
            h = b / l
            if h > best_h:
                best_b, best_h = b, h
        return best_b

    def due(self, now: float) -> bool:
        return (not self._warmed) or now + 1e-12 >= self._next_t

    def decide(self, now: float, queue: EDFQueue, lam: float,
               initial_wait: float = 0.0) -> Decision:
        self._next_t = now + self.reconfig_interval
        b = self.best_batch()
        h = float(self.perf.throughput(b, self.instance_cores))
        if not self._warmed:
            self._warmed = True
            if self.expected_rps > 0:
                n = max(1, math.ceil(self.expected_rps / max(h, 1e-9)))
                d = Decision(c=self.instance_cores, b=b, n=n)
                self.decisions.append((now, d))
                return d
        # backlog-aware target: serve the arrival rate AND drain the queue
        # within the reconfiguration horizon
        lam_eff = lam + len(queue) / self.drain_horizon
        n = max(1, min(self.max_instances,
                       math.ceil(lam_eff / max(h, 1e-9)) if lam_eff > 0
                       else 1))
        d = Decision(c=self.instance_cores, b=b, n=n,
                     scale_up_delay=self.cold_start)
        self.decisions.append((now, d))
        return d
