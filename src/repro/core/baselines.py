"""Baseline autoscalers the paper compares against (§4 "Baseline").

* ``FA2Policy`` — an FA2-style horizontal autoscaler: fixed one-core
  instances, batch chosen for max throughput under the *static* SLO
  (it does not see per-request network latency — exactly its failure mode),
  reconfiguration every ~10 s, new instances pay a cold start.
* ``StaticPolicy`` — statically assigned c (8 or 16 cores), dynamic batching
  via the same solver with c pinned.
* ``SpongePolicy`` — the paper's system: single instance, in-place vertical
  scaling + EDF + dynamic batching via the IP solver.

All three implement ``on_tick(now, sim)`` against the discrete-event
simulator in ``repro.serving.simulator``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Sequence

from repro.core.perf_model import PerfModel
from repro.core.scaler import SpongeScaler
from repro.core.slo import Decision
from repro.core.solver import DEFAULT_B, DEFAULT_C, solve_bruteforce


class Policy:
    name = "base"
    def on_tick(self, now: float, sim) -> None:  # pragma: no cover
        raise NotImplementedError


@dataclass
class SpongePolicy(Policy):
    scaler: SpongeScaler
    name: str = "sponge"

    def on_tick(self, now: float, sim) -> None:
        if not self.scaler.due(now):
            return
        lam = sim.monitor.rate.rate(now)
        srv = sim.pool[0]
        wait0 = max(srv.busy_until - now, 0.0)
        d = self.scaler.decide(now, sim.queue, lam, initial_wait=wait0)
        sim.set_batch(d.b)
        penalty = srv.instance.resize(d.c, now)
        if penalty:
            srv.busy_until = max(srv.busy_until, now) + penalty


@dataclass
class StaticPolicy(Policy):
    perf: PerfModel
    cores: int = 16
    b_set: Sequence[int] = DEFAULT_B
    interval: float = 1.0
    name: str = "static"
    _next_t: float = 0.0

    def __post_init__(self):
        self.name = f"static-{self.cores}"

    def on_tick(self, now: float, sim) -> None:
        if now + 1e-12 < self._next_t:
            return
        self._next_t = now + self.interval
        lam = sim.monitor.rate.rate(now)
        rem = sim.queue.snapshot_remaining(now)
        wait0 = max(sim.pool[0].busy_until - now, 0.0)
        d = solve_bruteforce(rem, lam, self.perf, (self.cores,), self.b_set,
                             initial_wait=wait0)
        sim.set_batch(d.b)


@dataclass
class FA2Policy(Policy):
    """Horizontal autoscaling with one-core instances (paper §2.1).

    Chooses b* = argmax_b h(b, 1) s.t. l(b,1) <= slo_budget (FA2 plans with
    the nominal SLO; it cannot see per-request comm latency), targets
    n = ceil(lambda / h(b*, 1)) instances.  Scale-ups pay ``cold_start``
    seconds before the instance serves; reconfiguration happens every
    ``reconfig_interval`` (~10 s to find + adjust + stabilize per the paper).
    """
    perf: PerfModel
    slo: float = 1.0
    instance_cores: int = 1
    b_set: Sequence[int] = DEFAULT_B
    reconfig_interval: float = 10.0
    cold_start: float = 10.0
    slo_budget_frac: float = 0.7        # FA2 plans within the NOMINAL SLO (it
                                        # cannot see per-request comm latency)
    max_instances: int = 32
    expected_rps: float = 0.0           # warm-start provisioning (deployed
                                        # pre-stabilized, as in the paper)
    drain_horizon: float = 10.0         # drain backlog within this window
    name: str = "fa2"
    _next_t: float = 0.0
    _warmed: bool = False

    def best_batch(self) -> int:
        budget = self.slo * self.slo_budget_frac
        best_b, best_h = 1, -1.0
        for b in sorted(self.b_set):
            l = float(self.perf.latency(b, self.instance_cores))
            if l > budget:
                continue
            h = b / l
            if h > best_h:
                best_b, best_h = b, h
        return best_b

    def on_tick(self, now: float, sim) -> None:
        b = self.best_batch()
        h = float(self.perf.throughput(b, self.instance_cores))
        if not self._warmed:
            self._warmed = True
            if self.expected_rps > 0:
                n0 = max(1, math.ceil(self.expected_rps / max(h, 1e-9)))
                sim.set_batch(b)
                for _ in range(n0 - len(sim.pool)):
                    sim.add_server(self.instance_cores, ready_at=now)
        if now + 1e-12 < self._next_t:
            return
        self._next_t = now + self.reconfig_interval
        lam = sim.monitor.rate.rate(now)
        # backlog-aware target: serve the arrival rate AND drain the queue
        # within the reconfiguration horizon
        lam_eff = lam + len(sim.queue) / self.drain_horizon
        n = max(1, min(self.max_instances,
                       math.ceil(lam_eff / max(h, 1e-9)) if lam_eff > 0 else 1))
        sim.set_batch(b)
        cur = len(sim.pool)
        if n > cur:
            for _ in range(n - cur):
                sim.add_server(self.instance_cores,
                               ready_at=now + self.cold_start)
        elif n < cur:
            sim.remove_servers(cur - n, now)
