"""Monitoring component (paper §3.1): arrival-rate estimation, SLO-violation
accounting, perf-model residual tracking (the Prometheus stand-in)."""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional

from repro.core.slo import Request


def array_window_rate(arr, ai: int, w0: int, now: float,
                      window_s: float, prior_rps: float
                      ) -> tuple[float, int]:
    """:class:`RateEstimator`'s estimate over a bare arrival array — the
    ONE sliding-window λ shared by every struct-of-arrays engine
    (``serving.fastpath`` and both ``serving.fleet`` runners resolve
    through this helper, so the estimate cannot drift between engines).

    ``arr`` is the (sorted) arrival-time column, ``ai`` the count of
    arrivals observed so far, ``w0`` the caller-held left window pointer.
    Returns ``(lambda, new_w0)``.  Semantics match ``RateEstimator``
    exactly: the single-arrival guard (a lone arrival at the first tick
    after an idle gap gives a ~zero-length window; dividing by it would
    report a million-rps spike and over-provision) and the deploy-prior
    blend that fades ``prior_rps`` out as the window fills.
    """
    lo = now - window_s
    while w0 < ai and arr[w0] < lo:
        w0 += 1
    if ai == w0:
        obs = 0.0
    elif ai - w0 == 1:
        obs = 1.0 / window_s
    else:
        span = min(window_s, max(now - arr[w0], 1e-6))
        obs = (ai - w0) / span
    if prior_rps <= 0:
        return obs, w0
    seen = max(now - arr[0], 0.0) if ai > 0 else 0.0
    w = min(seen / window_s, 1.0)
    return obs * w + prior_rps * (1.0 - w), w0


class RateEstimator:
    """Sliding-window arrival-rate (lambda) estimate in requests/second.

    ``prior_rps`` is the deployment-time expected rate; it is blended out as
    the observation window fills (prevents the t=0 scale-to-zero artifact —
    the serving analogue of FA2's pre-stabilized start)."""

    def __init__(self, window_s: float = 5.0, prior_rps: float = 0.0):
        self.window_s = window_s
        self.prior_rps = prior_rps
        self._t0: float | None = None
        self._arrivals: Deque[float] = deque()

    def observe(self, t: float) -> None:
        if self._t0 is None:
            self._t0 = t
        self._arrivals.append(t)

    def rate(self, now: float) -> float:
        while self._arrivals and self._arrivals[0] < now - self.window_s:
            self._arrivals.popleft()
        if not self._arrivals:
            obs = 0.0
        elif len(self._arrivals) == 1:
            # single-arrival guard: the observed span collapses to ~0 at
            # the first tick after an idle gap (the lone arrival may sit
            # exactly at ``now``), so count/span would report a huge
            # spurious rate; one arrival in the window is 1/window_s
            obs = 1.0 / self.window_s
        else:
            span = min(self.window_s, max(now - self._arrivals[0], 1e-6))
            obs = len(self._arrivals) / span
        if self.prior_rps <= 0:
            return obs
        seen = 0.0 if self._t0 is None else max(now - self._t0, 0.0)
        w = min(seen / self.window_s, 1.0)
        return obs * w + self.prior_rps * (1.0 - w)


@dataclass
class Monitor:
    rate: RateEstimator = field(default_factory=RateEstimator)
    completed: List[Request] = field(default_factory=list)
    dropped: List[Request] = field(default_factory=list)
    perf_residuals: List[float] = field(default_factory=list)

    def observe_arrival(self, req: Request) -> None:
        self.rate.observe(req.arrival)

    def observe_completion(self, req: Request) -> None:
        self.completed.append(req)

    def observe_drop(self, req: Request) -> None:
        self.dropped.append(req)

    def observe_perf_residual(self, predicted: float, measured: float) -> None:
        self.perf_residuals.append(measured - predicted)

    # -- aggregate metrics -------------------------------------------------
    @property
    def n_total(self) -> int:
        return len(self.completed) + len(self.dropped)

    @property
    def n_violations(self) -> int:
        return (sum(1 for r in self.completed if r.violated)
                + len(self.dropped))

    @property
    def violation_rate(self) -> float:
        return self.n_violations / max(self.n_total, 1)

    def e2e_latencies(self) -> List[float]:
        return [r.finish - (r.arrival - r.comm_latency)
                for r in self.completed if r.finish is not None]

    def p(self, q: float) -> float:
        ls = sorted(self.e2e_latencies())
        if not ls:
            return float("nan")
        return ls[min(int(q * len(ls)), len(ls) - 1)]
