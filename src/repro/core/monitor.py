"""Monitoring component (paper §3.1): arrival-rate estimation, SLO-violation
accounting, perf-model residual tracking (the Prometheus stand-in).

Renegotiation-aware accounting (ISSUE 5): the online session API lets a
client *cancel* a queued request mid-flight.  A cancelled request is no
longer demand — a cancel storm must deflate the provisioning signal
immediately, not after the window rolls over — so both λ estimators
support retracting an observed arrival: ``RateEstimator.retract`` on
the object path and the ``cancels``/``cw0`` two-pointer arguments of
:func:`array_window_rate_cancel_aware` on the struct-of-arrays path.
Both subtract retracted arrivals from the window *count* while keeping
the window *span* anchored at the oldest observed arrival (cancelled or
not), so the two estimators remain float-identical to each other — and
bit-identical to the historical estimate whenever nothing is
retracted.  Cancelled requests are likewise excluded from the
violation/latency aggregates (``Monitor.observe_cancel``)."""
from __future__ import annotations

from bisect import insort
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List

import numpy as np

from repro.core.slo import Request


def array_window_rate(arr, ai: int, w0: int, now: float,
                      window_s: float, prior_rps: float
                      ) -> tuple[float, int]:
    """:class:`RateEstimator`'s estimate over a bare arrival array — the
    ONE sliding-window λ shared by every struct-of-arrays engine
    (``serving.fastpath`` and both ``serving.fleet`` runners resolve
    through this helper, so the estimate cannot drift between engines).

    ``arr`` is the (sorted) arrival-time column, ``ai`` the count of
    arrivals observed so far, ``w0`` the caller-held left window pointer.
    Returns ``(lambda, new_w0)``.  Semantics match ``RateEstimator``
    exactly: the single-arrival guard (a lone arrival at the first tick
    after an idle gap gives a ~zero-length window; dividing by it would
    report a million-rps spike and over-provision) and the deploy-prior
    blend that fades ``prior_rps`` out as the window fills.
    """
    lo = now - window_s
    while w0 < ai and arr[w0] < lo:
        w0 += 1
    if ai == w0:
        obs = 0.0
    elif ai - w0 == 1:
        obs = 1.0 / window_s
    else:
        span = min(window_s, max(now - arr[w0], 1e-6))
        obs = (ai - w0) / span
    if prior_rps <= 0:
        return obs, w0
    seen = max(now - arr[0], 0.0) if ai > 0 else 0.0
    w = min(seen / window_s, 1.0)
    return obs * w + prior_rps * (1.0 - w), w0


def tick_window_rate(arr, w0: int, now: float, window_s: float,
                     prior_rps: float) -> tuple[float, int]:
    """Tick-granular :func:`array_window_rate`: derive the observed-count
    pointer ``ai`` from the arrival column itself instead of having the
    event loop advance a counter per arrival.

    Valid whenever the caller asks for λ only at times by which every
    arrival ``<= now`` has been observed — exactly the adaptation-tick
    contract of every closed-world engine (the canonical event order
    processes arrivals at time T *before* the tick at T), so
    ``ai = searchsorted(arr, now, side="right")`` equals the count the
    per-arrival increment would have reached, and the estimate is
    bit-identical.  ``arr`` must be a sorted numpy array (the workload's
    arrival column).  Returns ``(lambda, new_w0)``.
    """
    ai = int(np.searchsorted(arr, now, side="right"))
    return array_window_rate(arr, ai, w0, now, window_s, prior_rps)


def array_window_rate_cancel_aware(arr, ai: int, w0: int, now: float,
                                   window_s: float, prior_rps: float,
                                   cancels, cw0: int
                                   ) -> tuple[float, int, int]:
    """:func:`array_window_rate` with cancelled arrivals retracted.

    ``cancels`` is a sorted (ascending) sequence of the *arrival times*
    of requests cancelled while queued, ``cw0`` the caller-held left
    pointer into it.  The in-window cancel count is subtracted from the
    in-window arrival count before the rate formula; the span still
    anchors at the oldest in-window arrival (cancelled or not), exactly
    like :meth:`RateEstimator.retract` on the object path, so the two
    estimators stay float-identical.  With no cancels in the window the
    formula collapses to :func:`array_window_rate` bit-for-bit.
    Returns ``(lambda, new_w0, new_cw0)``.
    """
    lo = now - window_s
    while w0 < ai and arr[w0] < lo:
        w0 += 1
    nc = len(cancels)
    while cw0 < nc and cancels[cw0] < lo:
        cw0 += 1
    count = (ai - w0) - (nc - cw0)
    if count <= 0:
        obs = 0.0
    elif count == 1:
        obs = 1.0 / window_s
    else:
        span = min(window_s, max(now - arr[w0], 1e-6))
        obs = count / span
    if prior_rps <= 0:
        return obs, w0, cw0
    seen = max(now - arr[0], 0.0) if ai > 0 else 0.0
    w = min(seen / window_s, 1.0)
    return obs * w + prior_rps * (1.0 - w), w0, cw0


class RateEstimator:
    """Sliding-window arrival-rate (lambda) estimate in requests/second.

    ``prior_rps`` is the deployment-time expected rate; it is blended out as
    the observation window fills (prevents the t=0 scale-to-zero artifact —
    the serving analogue of FA2's pre-stabilized start).

    ``retract(t)`` removes one previously observed arrival from the
    window *count* (mid-flight cancellation); the window *span* stays
    anchored at the oldest observed arrival, cancelled or not, so the
    estimate matches :func:`array_window_rate_cancel_aware` float for
    float."""

    def __init__(self, window_s: float = 5.0, prior_rps: float = 0.0):
        self.window_s = window_s
        self.prior_rps = prior_rps
        self._t0: float | None = None
        self._arrivals: Deque[float] = deque()
        self._retracted: List[float] = []    # sorted arrival times

    def observe(self, t: float) -> None:
        if self._t0 is None:
            self._t0 = t
        self._arrivals.append(t)

    def retract(self, t: float) -> None:
        """Retract one observed arrival (the request was cancelled while
        queued) so it stops counting toward the provisioning signal."""
        insort(self._retracted, t)

    def rate(self, now: float) -> float:
        while self._arrivals and self._arrivals[0] < now - self.window_s:
            self._arrivals.popleft()
        lo = now - self.window_s
        if self._retracted:
            k = 0
            while k < len(self._retracted) and self._retracted[k] < lo:
                k += 1
            if k:
                del self._retracted[:k]
        count = len(self._arrivals) - len(self._retracted)
        if count <= 0:
            obs = 0.0
        elif count == 1:
            # single-arrival guard: the observed span collapses to ~0 at
            # the first tick after an idle gap (the lone arrival may sit
            # exactly at ``now``), so count/span would report a huge
            # spurious rate; one arrival in the window is 1/window_s
            obs = 1.0 / self.window_s
        else:
            span = min(self.window_s, max(now - self._arrivals[0], 1e-6))
            obs = count / span
        if self.prior_rps <= 0:
            return obs
        seen = 0.0 if self._t0 is None else max(now - self._t0, 0.0)
        w = min(seen / self.window_s, 1.0)
        return obs * w + self.prior_rps * (1.0 - w)


@dataclass
class Monitor:
    rate: RateEstimator = field(default_factory=RateEstimator)
    completed: List[Request] = field(default_factory=list)
    dropped: List[Request] = field(default_factory=list)
    cancelled: List[Request] = field(default_factory=list)
    perf_residuals: List[float] = field(default_factory=list)

    def observe_arrival(self, req: Request) -> None:
        self.rate.observe(req.arrival)

    def observe_completion(self, req: Request) -> None:
        self.completed.append(req)

    def observe_drop(self, req: Request) -> None:
        self.dropped.append(req)

    def observe_cancel(self, req: Request) -> None:
        """A queued request was cancelled mid-flight: retract its
        arrival from the λ window and exclude it from every served /
        violation aggregate (it is reported separately)."""
        self.cancelled.append(req)
        self.rate.retract(req.arrival)

    def observe_perf_residual(self, predicted: float, measured: float) -> None:
        self.perf_residuals.append(measured - predicted)

    # -- aggregate metrics -------------------------------------------------
    @property
    def n_total(self) -> int:
        return len(self.completed) + len(self.dropped)

    @property
    def n_cancelled(self) -> int:
        return len(self.cancelled)

    @property
    def n_violations(self) -> int:
        return (sum(1 for r in self.completed if r.violated)
                + len(self.dropped))

    @property
    def violation_rate(self) -> float:
        return self.n_violations / max(self.n_total, 1)

    def e2e_latencies(self) -> List[float]:
        return [r.finish - (r.arrival - r.comm_latency)
                for r in self.completed if r.finish is not None]

    def p(self, q: float) -> float:
        ls = sorted(self.e2e_latencies())
        if not ls:
            return float("nan")
        return ls[min(int(q * len(ls)), len(ls) - 1)]


def accuracy_weighted_goodput(finish, deadline, model_log, horizon: float
                              ) -> tuple[float, float]:
    """Accuracy-weighted goodput over a closed run (ISSUE 9).

    ``finish`` / ``deadline`` are parallel per-request arrays (NaN
    finish = never served); ``model_log`` is the fleet's resident-model
    timeline ``[(t, rung_name, accuracy), ...]`` (time-ascending, first
    entry at t=0).  Each served request is weighted by the accuracy of
    the model resident at its *finish* time — the rung that actually
    produced the answer (a batch dispatched on a rung completes before
    any swap away from it takes effect, because swaps drain in-flight
    work first).

    Returns ``(goodput, mean_served_accuracy)``: the accuracy sum over
    requests served within deadline divided by the horizon (Orloj's
    objective — degraded-but-in-time counts, but for less), and the
    mean accuracy over all served requests (degradation depth).
    """
    finish = np.asarray(finish, np.float64)
    deadline = np.asarray(deadline, np.float64)
    ts = np.asarray([t for t, _, _ in model_log], np.float64)
    accs = np.asarray([a for _, _, a in model_log], np.float64)
    served = ~np.isnan(finish)
    if not served.any():
        return 0.0, float("nan")
    seg = np.clip(np.searchsorted(ts, finish[served], side="right") - 1,
                  0, len(accs) - 1)
    acc_req = accs[seg]
    in_time = finish[served] <= deadline[served] + 1e-9
    return (float(acc_req[in_time].sum()) / max(horizon, 1e-12),
            float(acc_req.mean()))
