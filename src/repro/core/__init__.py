from repro.core.slo import Request, Decision
from repro.core.perf_model import PerfModel
from repro.core.solver import solve_bruteforce, solve_pruned
from repro.core.queueing import EDFQueue, DynamicBatcher
from repro.core.scaler import SpongeScaler
from repro.core.vertical import VerticalScaledInstance
