"""Token-level cost model: the work/cost abstraction behind the solver.

Sponge's IP (paper Eq. 3) treats a request as one fixed unit of work with
latency ``l(b, c)``.  Autoregressive serving breaks that assumption: a
request is a *prefill* burst (cost ~ prompt tokens) followed by a *decode
stream* (one token per engine step, cost ~ concurrent decode slots), so
the latency of an engine step depends on batch **composition**, not just
batch size.  This module generalizes :class:`repro.core.perf_model.PerfModel`
to a :class:`CostModel` protocol over compositions:

* :class:`Composition` — ``(prefill_tokens, decode_slots)``: the work one
  continuous-batching engine step performs (prefill the prompts of newly
  admitted requests + one decode token for every running slot).
* :class:`FixedWorkCostModel` — the existing fixed-work model as a
  **provably decision-identical** special case: a request is a one-shot
  prefill of one token and zero decode, and every latency surface
  delegates to the wrapped ``PerfModel`` with the *same float
  expressions*, so any solver/scaler/runner built on it reproduces the
  PerfModel decisions bit for bit (the contract ``tests/test_fastpath.py``
  enforces).
* :class:`TokenCostModel` — the autoregressive surface: affine prefill
  cost in total prompt tokens, affine decode-step cost in concurrent
  slots, both with Amdahl scaling in the core count ``c`` (the same
  γ/c + δ shape as paper Eq. 1, applied per token / per slot).

Both concrete models also quack like a ``PerfModel`` (``latency(b, c)`` /
``throughput(b, c)``): for the fixed-work adapter that is the wrapped
model verbatim; for the token model it is the *full-service* latency of a
batch of ``b`` mean-shaped requests (prefill + the whole decode stream),
which lets SLO-blind baselines (static, FA2) plan on token workloads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Protocol, Sequence, Union, runtime_checkable

import numpy as np

from repro.core.perf_model import PerfModel


@dataclass(frozen=True)
class Composition:
    """The work of one continuous-batching engine step.

    ``prefill_tokens`` — total prompt tokens prefilled this step (the
    newly admitted requests' prompts, summed); ``decode_slots`` — running
    sequences that take one decode step.  A fixed-work request batch of
    size b is ``Composition(prefill_tokens=b, decode_slots=0)`` under the
    one-token-per-request convention of :class:`FixedWorkCostModel`.
    """
    prefill_tokens: int
    decode_slots: int


@runtime_checkable
class CostModel(Protocol):
    """What the solver/control-plane layers need from a cost surface.

    ``batch_latency(b, c)`` is the fixed-work view (one dispatch of b
    requests); ``prefill_latency`` / ``decode_latency`` /
    ``step_latency`` expose the token-level decomposition.  Implementors
    must keep all four consistent (``step_latency`` of a pure-prefill
    composition equals ``prefill_latency`` of its tokens).
    """

    def batch_latency(self, b, c): ...

    def prefill_latency(self, c, tokens): ...

    def decode_latency(self, c, slots): ...

    def step_latency(self, c, comp: Composition) -> float: ...

    def throughput(self, b, c): ...


@dataclass(frozen=True)
class FixedWorkCostModel:
    """The paper's fixed-work model expressed as a :class:`CostModel`.

    One request == a one-shot prefill of exactly one token and an empty
    decode stream, so ``prefill_latency(c, tokens=b)``,
    ``batch_latency(b, c)`` and ``latency(b, c)`` are all the wrapped
    ``perf.latency(b, c)`` — *the same float expression*, which is what
    makes every decision made through this adapter bit-identical to one
    made on the bare ``PerfModel`` (no re-derived coefficients, no
    alternate evaluation order).
    """
    perf: PerfModel

    # -- PerfModel-compatible surface (drop-in for solver/scaler/backends)
    def latency(self, b, c):
        """Fixed-work batch latency — ``perf.latency`` verbatim."""
        return self.perf.latency(b, c)

    def throughput(self, b, c):
        """Fixed-work batch throughput — ``perf.throughput`` verbatim."""
        return self.perf.throughput(b, c)

    # -- CostModel surface -------------------------------------------------
    def batch_latency(self, b, c):
        """One dispatch of b requests: ``perf.latency(b, c)`` verbatim."""
        return self.perf.latency(b, c)

    def prefill_latency(self, c, tokens):
        """tokens one-token requests prefilled together: l(tokens, c)."""
        return self.perf.latency(tokens, c)

    def decode_latency(self, c, slots):
        """Fixed work has no decode stream: a decode step is free (and
        the solver's TBT constraint is vacuous)."""
        return np.zeros_like(np.asarray(slots, np.float64)
                             * np.asarray(c, np.float64))

    def step_latency(self, c, comp: Composition) -> float:
        """Pure-prefill step cost; decode slots contribute nothing."""
        if comp.prefill_tokens <= 0:
            return 0.0
        return float(self.perf.latency(comp.prefill_tokens, c))


def as_cost_model(perf_or_cost: Union[PerfModel, CostModel]) -> CostModel:
    """Adapt a ``PerfModel`` to the :class:`CostModel` protocol (wrap it
    in :class:`FixedWorkCostModel`); pass an existing cost model through
    untouched."""
    if isinstance(perf_or_cost, PerfModel):
        return FixedWorkCostModel(perf_or_cost)
    return perf_or_cost


@dataclass(frozen=True)
class TokenCostModel:
    """Affine token-level cost surface with Amdahl scaling in ``c``.

        prefill:  l_p(T, c) = γ_p·T/c + δ_p·T + ε/c + η
        decode:   l_d(S, c) = γ_d·S/c + δ_d·S + ε/c + η
        step:     l(c, (T, S)) = (γ_p·T + γ_d·S + ε)/c + δ_p·T + δ_d·S + η

    T = prefill tokens, S = concurrent decode slots.  γ are the
    parallelizable per-token/per-slot costs, δ the serial ones (the
    GrandSLAm-style linear relation per token instead of per request),
    ε/η the per-step dispatch overheads.  ``mean_prompt`` /
    ``mean_decode`` describe the workload's average request shape and
    back the fixed-work quack surface (``latency``/``throughput``/
    ``batch_latency``): the full-service latency of b mean-shaped
    requests — prefill of ``b·mean_prompt`` tokens plus ``mean_decode``
    decode steps at concurrency b.
    """
    gamma_p: float          # parallel cost per prefill token (s·cores)
    delta_p: float          # serial cost per prefill token (s)
    gamma_d: float          # parallel cost per decode slot-step (s·cores)
    delta_d: float          # serial cost per decode slot-step (s)
    eps: float              # parallel per-step overhead (s·cores)
    eta: float              # serial per-step overhead (s)
    mean_prompt: float = 64.0
    mean_decode: float = 16.0
    r2_prefill: float = float("nan")
    r2_decode: float = float("nan")

    # -- token-level surface ----------------------------------------------
    def prefill_latency(self, c, tokens):
        """Latency of prefilling ``tokens`` prompt tokens at allocation c."""
        t = np.asarray(tokens, np.float64)
        c = np.asarray(c, np.float64)
        return (self.gamma_p * t + self.eps) / c + self.delta_p * t + self.eta

    def decode_latency(self, c, slots):
        """Latency of one decode step over ``slots`` running sequences."""
        s = np.asarray(slots, np.float64)
        c = np.asarray(c, np.float64)
        return (self.gamma_d * s + self.eps) / c + self.delta_d * s + self.eta

    def step_latency(self, c, comp: Composition) -> float:
        """One mixed engine step: admitted prompts + one token per slot.
        Shares a single per-step overhead (ε/c + η)."""
        t, s = float(comp.prefill_tokens), float(comp.decode_slots)
        if t <= 0 and s <= 0:
            return 0.0
        return float((self.gamma_p * t + self.gamma_d * s + self.eps) / c
                     + self.delta_p * t + self.delta_d * s + self.eta)

    # -- fixed-work quack surface (lets baselines plan on token work) -----
    def batch_latency(self, b, c):
        """Full-service latency of b mean-shaped requests: one prefill
        burst of ``b·mean_prompt`` tokens + ``mean_decode`` decode steps
        at concurrency b."""
        b = np.asarray(b, np.float64)
        return (self.prefill_latency(c, b * self.mean_prompt)
                + self.mean_decode * self.decode_latency(c, b))

    def latency(self, b, c):
        """PerfModel-compatible alias of :meth:`batch_latency`."""
        return self.batch_latency(b, c)

    def throughput(self, b, c):
        """Requests/second at full concurrency b (full-service view)."""
        return (np.asarray(b, np.float64)
                / np.maximum(self.batch_latency(b, c), 1e-12))

    def tokens_per_second(self, c, slots) -> float:
        """Steady-state decode token throughput at a given concurrency."""
        return float(slots) / max(float(self.decode_latency(c, slots)), 1e-12)

    def prefill_token_allowance(self, c, slots: int, budget: float) -> float:
        """Max prefill tokens one step can absorb while keeping its
        latency within ``budget`` given ``slots`` running decoders — the
        chunked-admission bound the continuous-batching engine uses to
        keep a large joining prompt from stalling running streams past
        their per-token SLO.  ``inf`` when the budget is infinite."""
        if not np.isfinite(budget):
            return float("inf")
        base = float(self.decode_latency(c, slots))
        per_tok = self.gamma_p / float(c) + self.delta_p
        return (budget - base) / max(per_tok, 1e-12)

    # ------------------------------------------------------------------ fit
    @staticmethod
    def _fit_axis(samples: np.ndarray):
        """Least-squares fit of (x/c, x, 1/c, 1) -> latency.
        samples: rows of (x, c, latency)."""
        x, c, y = samples.T
        X = np.stack([x / c, x, 1.0 / c, np.ones_like(x)], axis=-1)
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        pred = X @ coef
        ss_res = float(np.sum((y - pred) ** 2))
        ss_tot = float(np.sum((y - y.mean()) ** 2))
        return coef, 1.0 - ss_res / max(ss_tot, 1e-12)

    @classmethod
    def fit(cls, prefill_samples: Iterable[tuple[float, float, float]],
            decode_samples: Iterable[tuple[float, float, float]],
            mean_prompt: float = 64.0,
            mean_decode: float = 16.0) -> "TokenCostModel":
        """Fit from profiled samples.

        ``prefill_samples``: rows of (prompt_tokens, c, latency_s);
        ``decode_samples``: rows of (decode_slots, c, latency_s) — e.g.
        from timing the jitted (c, b) prefill/decode executables
        (``repro.serving.token_backend.calibrate_token_fns``).  The two
        fits share no parameters; ε/η are averaged across the axes so the
        shared per-step overhead stays one number.
        """
        ps = np.asarray(list(prefill_samples), np.float64)
        ds = np.asarray(list(decode_samples), np.float64)
        assert ps.ndim == 2 and ps.shape[1] == 3 and len(ps) >= 4, \
            "need >=4 (tokens, c, latency) prefill samples"
        assert ds.ndim == 2 and ds.shape[1] == 3 and len(ds) >= 4, \
            "need >=4 (slots, c, latency) decode samples"
        (gp, dp, ep, hp), r2p = cls._fit_axis(ps)
        (gd, dd, ed, hd), r2d = cls._fit_axis(ds)
        return cls(gamma_p=float(max(gp, 0.0)), delta_p=float(max(dp, 0.0)),
                   gamma_d=float(max(gd, 0.0)), delta_d=float(max(dd, 0.0)),
                   eps=float(max((ep + ed) / 2.0, 0.0)),
                   eta=float(max((hp + hd) / 2.0, 0.0)),
                   mean_prompt=mean_prompt, mean_decode=mean_decode,
                   r2_prefill=r2p, r2_decode=r2d)

    @classmethod
    def smollm_like(cls, mean_prompt: float = 64.0,
                    mean_decode: float = 24.0) -> "TokenCostModel":
        """Synthetic calibration in the SmolLM-135M-on-CPU-class regime:
        ~5 ms to prefill a 64-token prompt at c=8; ~5 ms per decode step
        at 8 concurrent slots and c=8; a 16-slot step at c=1 costs ~55 ms
        (so a 50 ms TBT SLO forces vertical scale-up under load)."""
        return cls(gamma_p=2.0e-4, delta_p=2.0e-6,
                   gamma_d=2.5e-3, delta_d=5.0e-5,
                   eps=1.0e-2, eta=2.0e-3,
                   mean_prompt=mean_prompt, mean_decode=mean_decode)

    def sample_profile(self, token_counts: Sequence[int],
                       slot_counts: Sequence[int], cs: Sequence[int],
                       noise: float = 0.02, seed: int = 0):
        """Noisy (prefill_samples, decode_samples) drawn from this model
        — the token-level counterpart of ``PerfModel.sample_profile``."""
        rng = np.random.default_rng(seed)
        pre, dec = [], []
        for c in cs:
            for t in token_counts:
                l = float(self.prefill_latency(c, t))
                pre.append((float(t), float(c),
                            max(l * (1 + rng.normal(0, noise)), 1e-6)))
            for s in slot_counts:
                l = float(self.decode_latency(c, s))
                dec.append((float(s), float(c),
                            max(l * (1 + rng.normal(0, noise)), 1e-6)))
        return pre, dec
