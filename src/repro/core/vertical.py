"""In-place vertical scaling — the paper's core mechanism, TPU-adapted.

K8s in-place pod resize changes a container's CPU cores without restart.
The TPU analogue (DESIGN.md §2): a serving instance holds an *executable
table* over (c, b) — c the model-parallel submesh degree, b the batch
bucket — all lowered/compiled at deploy time.  ``resize`` flips the active
entry: no recompilation, no weight reload, no cold start; the one-off cost
is a weight re-gather onto the target submesh, modeled as ``resize_penalty``
seconds (the analogue of the pod-resize syscall, NOT of a cold start).

Two concrete executor substrates:

* ``TimedExecutor`` — wall-clock execution of real jitted JAX functions,
  batch-bucketed (used by the live serving engine / examples).
* simulation — the discrete-event simulator calls ``latency(b)`` from the
  calibrated PerfModel instead of executing (used for the Fig. 4 study).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence

from repro.core.perf_model import PerfModel


@dataclass
class ResizeEvent:
    t: float
    c_from: int
    c_to: int
    penalty: float


class VerticalScaledInstance:
    """A single servable model instance with in-place vertical scaling."""

    def __init__(self, c_set: Sequence[int], b_set: Sequence[int],
                 perf: PerfModel, c0: Optional[int] = None,
                 resize_penalty: float = 0.005,
                 weight_bytes: float = 0.0, ici_bw: float = 50e9):
        self.c_set = tuple(sorted(c_set))
        self.b_set = tuple(sorted(b_set))
        self.perf = perf
        self.c = c0 or self.c_set[0]
        assert self.c in self.c_set
        # resize penalty: explicit, or estimated re-gather time of the
        # weight shard over ICI (beyond-cold-start but not free)
        self.resize_penalty = (weight_bytes / ici_bw
                               if weight_bytes else resize_penalty)
        self.resizes: list[ResizeEvent] = []
        self.core_seconds = 0.0
        self._last_t: Optional[float] = None

    # -- the in-place resize (the paper's mechanism) ----------------------
    def resize(self, c: int, now: float = 0.0) -> float:
        """Returns the penalty (seconds) to charge; 0 if no change."""
        assert c in self.c_set, (c, self.c_set)
        self.account(now)
        if c == self.c:
            return 0.0
        self.resizes.append(ResizeEvent(now, self.c, c, self.resize_penalty))
        self.c = c
        return self.resize_penalty

    def account(self, now: float) -> None:
        """Integrate allocated core-seconds up to ``now``."""
        if self._last_t is None:
            self._last_t = now
            return
        if now > self._last_t:
            self.core_seconds += self.c * (now - self._last_t)
            self._last_t = now
        self._last_t = now

    def bucket_b(self, b: int) -> int:
        for bb in self.b_set:
            if bb >= b:
                return bb
        return self.b_set[-1]

    def latency(self, b: int) -> float:
        """Processing latency of a batch of b at the current allocation."""
        return float(self.perf.latency(self.bucket_b(b), self.c))

    def throughput(self) -> float:
        return max(float(self.perf.throughput(b, self.c))
                   for b in self.b_set)


class TimedExecutor:
    """Executable table of real jitted functions keyed by (c, b) buckets.

    ``fns[(c, b)]`` must be ready-to-call (pre-compiled at deploy — that is
    what makes the resize in-place).  Measures wall latency per call.
    """

    def __init__(self, fns: Dict[tuple[int, int], Callable]):
        self.fns = dict(fns)
        self.calls: list[tuple[float, int, int, float]] = []

    def warmup(self, args_for: Callable[[int, int], tuple]) -> None:
        for (c, b), fn in self.fns.items():
            fn(*args_for(c, b))  # compile

    def __call__(self, c: int, b: int, *args) -> Any:
        t0 = time.perf_counter()
        out = self.fns[(c, b)](*args)
        out = jax_block(out)
        dt = time.perf_counter() - t0
        self.calls.append((t0, c, b, dt))
        return out


def jax_block(x):
    try:
        import jax
        return jax.block_until_ready(x)
    except Exception:
        return x
