"""Request / decision dataclasses shared by the Sponge control plane.

Times are seconds (floats, absolute simulation/wall clock).  A request's
end-to-end SLO covers communication + queuing + processing (paper §3.3):

    deadline = send_time + SLO = arrival - cl + SLO

so the *remaining* budget when the request reaches the server is SLO - cl —
the dynamic-SLO quantity that varies with network bandwidth.

Autoregressive extension (ISSUE 3): a request may additionally carry a
token shape — ``prompt_tokens`` to prefill and ``decode_tokens`` to
stream out — plus a per-token SLO ``tbt_slo`` (max gap between
consecutive generated tokens).  For such requests ``deadline`` is the
**TTFT** deadline (the dynamic-SLO budget gates the *first* token; the
decode stream is gated per token), and the lifecycle gains
``first_token`` / ``tbt_violations``.  The defaults (1 prompt token,
0 decode tokens, infinite TBT) reproduce the paper's fixed-work request
exactly, which is what keeps every pre-token code path bit-identical.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

_ids = itertools.count()


@dataclass(order=True)
class Request:
    deadline: float                      # absolute; sort key for EDF
    id: int = field(compare=False, default_factory=lambda: next(_ids))
    arrival: float = field(compare=False, default=0.0)   # at server
    comm_latency: float = field(compare=False, default=0.0)
    slo: float = field(compare=False, default=1.0)
    size_kb: float = field(compare=False, default=200.0)
    # token shape (fixed-work defaults: one-shot prefill, no decode)
    prompt_tokens: int = field(compare=False, default=1)
    decode_tokens: int = field(compare=False, default=0)
    tbt_slo: float = field(compare=False, default=float("inf"))
    # decode-length uncertainty (ISSUE 7): the declared distribution of
    # ``decode_tokens`` (``repro.core.uncertainty.LengthDistribution``);
    # None or a point mass means the length is known exactly and every
    # pre-uncertainty code path runs verbatim
    decode_dist: Optional[object] = field(compare=False, default=None,
                                          repr=False)
    # lifecycle (filled by the system)
    start_proc: Optional[float] = field(compare=False, default=None)
    first_token: Optional[float] = field(compare=False, default=None)
    finish: Optional[float] = field(compare=False, default=None)
    tbt_violations: int = field(compare=False, default=0)
    # cancel-on-overrun: set by a speculative engine when the stream
    # exhausted its token budget and was cancelled mid-decode (counted
    # in n_cancelled, excluded from latency/violation aggregates)
    cancelled: bool = field(compare=False, default=False)

    @classmethod
    def make(cls, arrival: float, comm_latency: float, slo: float,
             size_kb: float = 200.0, prompt_tokens: int = 1,
             decode_tokens: int = 0,
             tbt_slo: float = float("inf")) -> "Request":
        return cls(deadline=arrival - comm_latency + slo, arrival=arrival,
                   comm_latency=comm_latency, slo=slo, size_kb=size_kb,
                   prompt_tokens=prompt_tokens, decode_tokens=decode_tokens,
                   tbt_slo=tbt_slo)

    def remaining(self, now: float) -> float:
        return self.deadline - now

    @property
    def is_autoregressive(self) -> bool:
        return self.decode_tokens > 0

    @property
    def violated(self) -> bool:
        """Deadline miss: for fixed work the completion deadline; for an
        autoregressive request the TTFT deadline (first token late) or
        any per-token gap beyond ``tbt_slo``."""
        if self.is_autoregressive:
            late_first = (self.first_token is not None
                          and self.first_token > self.deadline + 1e-9)
            return late_first or self.tbt_violations > 0
        return self.finish is not None and self.finish > self.deadline + 1e-9


@dataclass(frozen=True)
class Decision:
    """Scaler output: in-place vertical scale to c, batch size b.

    Horizontal policies (FA2-style, multidimensional scaling) additionally
    set a replica target ``n``; newly added replicas become ready after
    ``scale_up_delay`` seconds (the cold start — only ever paid on the
    horizontal axis).  Vertical-only policies leave both at the defaults.

    Fields:

    * ``c`` — per-replica core count (TPU adaptation: submesh degree);
      backends round *up* to the nearest available entry, never down.
    * ``b`` — batch size the dispatcher fills toward before releasing.
    * ``feasible`` — False when no (c, b) met every deadline and the
      solver fell back to the damage-minimizing drain configuration.
    * ``solver_iters`` / ``solver_time`` — search cost telemetry; a
      memoized-solver cache hit reports the original miss's numbers.
    * ``n`` — replica target (1 for vertical-only policies).
    * ``scale_up_delay`` — seconds before *newly added* replicas serve.
    * ``predicted_tbt`` — token-aware solvers only: the decode-step
      latency the chosen (c, b) is predicted to sustain (b doubles as
      the decode-slot cap on the continuous-batching engines); 0.0 for
      fixed-work decisions.
    * ``m`` — model rung the allocation is planned for (the (m, n, c, b)
      degradation solver's third axis — ``repro.core.degradation``);
      ``None`` for single-model decisions, which keeps every pre-ladder
      code path bit-identical.
    """
    c: int
    b: int
    feasible: bool = True
    solver_iters: int = 0
    solver_time: float = 0.0
    n: int = 1
    scale_up_delay: float = 0.0
    predicted_tbt: float = 0.0
    m: Optional[str] = None

    @property
    def cost(self) -> float:
        return float(self.c) * max(self.n, 1)
