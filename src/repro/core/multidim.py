"""Beyond-paper: joint vertical + horizontal scaling.

The paper's §6 "Multidimensional scaling" future work: vertical scaling
saturates at c_max on one node; when the workload exceeds a single
instance's max throughput, horizontal replicas must join — each of which is
itself vertically scaled.

.. deprecated::
    The ``rem_all[::k]`` share-splitting heuristic here is superseded by
    the joint (n, c, b) solver (``repro.core.solver.JointSolverTable`` /
    ``JointMemoizedSolver`` driving ``repro.serving.fleet``), which
    searches replica count, cores and batch jointly instead of slicing a
    fixed share per instance.  Importing this module emits a
    ``DeprecationWarning``; see the migration note in docs/api.md.

Policy:

* target replica count n = ceil(lambda_eff / h_max(c_max)) (backlog-aware);
  scale-ups pay the cold start (new instances ARE new pods — the paper's
  point is that the cold start is only paid on the horizontal axis);
* each tick, run the Sponge IP with the per-instance share lambda/n and the
  global queue snapshot interleaved n-ways (EDF order is preserved per
  instance because the simulator pool shares one EDF queue);
* all live instances resize in place to the solved c.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass

from repro.core.scaler import SpongeScaler

warnings.warn(
    "repro.core.multidim is deprecated: the per-instance share-splitting "
    "heuristic is superseded by the joint (n, c, b) solver — use "
    "repro.core.solver.JointSolverTable / JointMemoizedSolver with "
    "repro.serving.fleet; see the migration note in docs/api.md",
    DeprecationWarning, stacklevel=2)


@dataclass
class MultiDimPolicy:
    scaler: SpongeScaler
    cold_start: float = 10.0
    max_instances: int = 8
    drain_horizon: float = 5.0
    name: str = "sponge-multidim"

    def h_max(self) -> float:
        c = max(self.scaler.c_set)
        return max(float(self.scaler.perf.throughput(b, c))
                   for b in self.scaler.b_set)

    def on_tick(self, now: float, sim) -> None:
        if not self.scaler.due(now):
            return
        lam = sim.monitor.rate.rate(now)
        lam_eff = lam + len(sim.queue) / self.drain_horizon
        n = max(1, min(self.max_instances,
                       math.ceil(lam_eff / max(self.h_max(), 1e-9))))
        cur = len(sim.pool)
        if n > cur:
            for _ in range(n - cur):
                sim.add_server(max(self.scaler.c_set),
                               ready_at=now + self.cold_start)
        elif n < cur:
            sim.remove_servers(cur - n, now)
        ready = [s for s in sim.pool if s.ready_at <= now] or sim.pool
        # per-instance share: every k-th queued budget, lambda/k arrivals
        k = len(ready)
        rem_all = sim.queue.snapshot_remaining(now)
        wait0 = min(max(s.busy_until - now, 0.0) for s in ready)
        d = _decide_shared(self.scaler, now, rem_all[::k], lam / k,
                           initial_wait=wait0)
        sim.set_batch(d.b)
        for srv in ready:
            penalty = srv.instance.resize(d.c, now)
            if penalty:
                srv.busy_until = max(srv.busy_until, now) + penalty


def _decide_shared(self, now, remaining, lam, initial_wait=0.0):
    """``SpongeScaler.decide`` on a pre-sliced budget list (module-local
    helper — this used to be monkey-patched onto ``SpongeScaler`` at
    import time, mutating the class for every other consumer)."""
    from repro.core.solver import solve_bruteforce, solve_pruned
    self._next_t = now + self.adaptation_interval
    rem = sorted(max(r - self.headroom, 0.0) for r in remaining)
    fn = solve_bruteforce if self.solver == "bruteforce" else solve_pruned
    d = fn(rem, lam * self.lam_headroom, self.perf, self.c_set, self.b_set,
           self.delta_pen, initial_wait=initial_wait)
    self.decisions.append((now, d))
    return d

