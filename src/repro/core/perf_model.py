"""Sponge performance model (paper Eq. 1–2).

    l(b, c) = (γ/c + δ)·b + ε/c + η  =  γ·b/c + ε/c + δ·b + η
    h(b, c) = b / l(b, c)

combining GrandSLAm's linear batch→latency relation with Amdahl's law in the
core count c.  Fit with RANSAC-style robust regression (the paper cites
Fischler–Bolles [13]) over profiled (b, c, latency) samples.

On the TPU adaptation, "c" is the model-parallel submesh degree and the
profiling samples come either from measured jitted forwards (CPU container)
or from the dry-run roofline estimate per (c, b) executable — see
``repro.core.profiling``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Sequence

import numpy as np


@dataclass(frozen=True)
class PerfModel:
    gamma: float   # b/c coefficient
    eps: float     # 1/c coefficient
    delta: float   # b coefficient
    eta: float     # constant
    r2: float = float("nan")
    rmse: float = float("nan")

    # ----------------------------------------------------------------- eval
    def latency(self, b, c):
        b = np.asarray(b, np.float64)
        c = np.asarray(c, np.float64)
        return self.gamma * b / c + self.eps / c + self.delta * b + self.eta

    def throughput(self, b, c):
        return np.asarray(b, np.float64) / np.maximum(self.latency(b, c), 1e-12)

    def latency_table(self, bs: Sequence[int], cs: Sequence[int]) -> np.ndarray:
        bb, cc = np.meshgrid(bs, cs, indexing="ij")
        return self.latency(bb, cc)

    # ------------------------------------------------------------------ fit
    @staticmethod
    def _design(b, c):
        b = np.asarray(b, np.float64)
        c = np.asarray(c, np.float64)
        return np.stack([b / c, 1.0 / c, b, np.ones_like(b)], axis=-1)

    @classmethod
    def fit(cls, samples: Iterable[tuple[float, float, float]],
            robust: bool = True, n_iters: int = 200,
            inlier_frac: float = 2.0, seed: int = 0) -> "PerfModel":
        """samples: (b, c, latency_seconds).  RANSAC when robust=True:
        repeatedly fit on minimal subsets, keep the consensus set whose
        residuals are within ``inlier_frac`` x the median residual scale."""
        data = np.asarray(list(samples), np.float64)
        assert data.ndim == 2 and data.shape[1] == 3 and len(data) >= 4, \
            "need >=4 (b,c,latency) samples"
        b, c, y = data.T
        X = cls._design(b, c)

        def lstsq(idx):
            coef, *_ = np.linalg.lstsq(X[idx], y[idx], rcond=None)
            return coef

        best_idx = np.arange(len(y))
        if robust and len(y) >= 8:
            rng = np.random.default_rng(seed)
            best_inliers = -1
            best_scale = np.inf
            for _ in range(n_iters):
                idx = rng.choice(len(y), size=4, replace=False)
                try:
                    coef = lstsq(idx)
                except np.linalg.LinAlgError:
                    continue
                resid = np.abs(X @ coef - y)
                scale = max(np.median(resid), 1e-9)
                inliers = resid <= inlier_frac * scale
                if (inliers.sum(), -scale) > (best_inliers, -best_scale):
                    best_inliers = int(inliers.sum())
                    best_scale = scale
                    best_idx = np.where(inliers)[0]
            # trimmed refinement: refit on the consensus set, re-trim twice
            for _ in range(2):
                coef = lstsq(best_idx)
                resid = np.abs(X @ coef - y)
                scale = max(np.median(resid), 1e-9)
                keep = np.where(resid <= inlier_frac * scale)[0]
                if len(keep) >= 4:
                    best_idx = keep
        coef = lstsq(best_idx)
        pred = X @ coef
        ss_res = float(np.sum((y - pred) ** 2))
        ss_tot = float(np.sum((y - y.mean()) ** 2))
        r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
        rmse = float(np.sqrt(ss_res / len(y)))
        return cls(gamma=float(coef[0]), eps=float(coef[1]),
                   delta=float(coef[2]), eta=float(coef[3]), r2=r2, rmse=rmse)

    # ------------------------------------------------------- synthetic gen
    @classmethod
    def synthetic(cls, gamma=0.040, eps=0.012, delta=0.0008, eta=0.003
                  ) -> "PerfModel":
        """Defaults roughly calibrated to the paper's Table 1 (ResNet human
        detector): l(1,1)=55ms, l(2,1)=97ms, l(4,8)~37ms, l(8,8)~62ms."""
        return cls(gamma=gamma, eps=eps, delta=delta, eta=eta)

    def sample_profile(self, bs, cs, noise: float = 0.02,
                       outlier_frac: float = 0.0, seed: int = 0):
        """Generate noisy profiling samples from this model (for tests and
        the Fig. 3 benchmark)."""
        rng = np.random.default_rng(seed)
        out = []
        for b in bs:
            for c in cs:
                l = float(self.latency(b, c))
                l *= 1.0 + rng.normal(0, noise)
                if outlier_frac and rng.random() < outlier_frac:
                    l *= rng.uniform(2.0, 5.0)
                out.append((float(b), float(c), max(l, 1e-6)))
        return out


def yolov5s_like() -> PerfModel:
    """YOLOv5s-on-CPU-class model for the Fig. 4 study, calibrated so the
    paper's qualitative regime holds:

    * static-16 sustains 20 RPS (h(16,16) ~ 23) with no violations;
    * static-8 is slightly under-provisioned (h(16,8) ~ 18.8 < 20) so its
      queue builds and it "violates after a few seconds" (paper §4);
    * FA2's one-core instances are per-core efficient (large per-item serial
      cost delta — YOLO NMS-style postprocessing — favors horizontal
      scaling in steady state, h(2,1) ~ 4.6 so ~5 instances carry 20 RPS)
      but have no feasible config when the network budget dips — and pay a
      ~10 s cold start when they must scale;
    * Sponge floats at ~10-14 cores (>20% below static-16)."""
    return PerfModel(gamma=0.15, eps=0.04, delta=0.032, eta=0.032)


# Paper Table 1 measured points (ResNet human detector, P99 ms):
TABLE1_SAMPLES = [
    # (batch, cores, latency_s)
    (1, 1, 0.055),
    (2, 1, 0.097),
    (4, 2, 0.094),
    (8, 4, 0.092),
    (4, 8, 0.037),
    (8, 8, 0.062),
]


def fit_table1() -> PerfModel:
    return PerfModel.fit(TABLE1_SAMPLES, robust=False)
