"""Model ladder for accuracy degradation — the third scaling axis.

Sponge scales cores (vertical, Algorithm 1) and replicas (horizontal,
the joint (n, c, b) solver).  When *no* (n, c, b) is feasible the paper
simply violates; "Dynamic Network Adaptation at Inference" (PAPERS.md)
supplies the missing axis: scale the **model**, trading accuracy for
latency only when the SLO is otherwise unreachable.  This module holds
the ladder the (m, n, c, b) solver (``repro.core.solver.
MultiModelSolverTable``) searches over:

* a :class:`ModelRung` per registry entry — the arch id, its registry
  accuracy score (``repro.configs.registry.MODEL_ACCURACY``), a
  **fitted** cost surface (a :class:`~repro.core.perf_model.PerfModel`
  RANSAC-fitted over a profiled (b, c, latency) sweep of the rung), and
  the weights-load time a fleet pays to swap onto the rung;
* a :class:`ModelLadder` — rungs ordered accuracy-descending, which IS
  the solver's candidate preference: the solver sheds accuracy only
  when every (n, c, b) at a higher rung is infeasible.

Cost surfaces scale from a calibrated base model (the Fig. 4
``yolov5s_like`` surface by default) by the cube root of the rung's
active-parameter ratio — the sublinear serving-latency growth a
batch-amortized accelerator shows — and the weights-load time scales
with *total* parameters over a load bandwidth (bigger weights, longer
swap).  Both knobs are explicit so studies can pin their own surfaces.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

from repro.core.cost_model import CostModel
from repro.core.perf_model import PerfModel, yolov5s_like

# the registry's natural ladder, ascending capability (ISSUE 9): the
# serving-study default uses the small end where swaps are cheap
DEFAULT_LADDER_ARCHS: Tuple[str, ...] = (
    "smollm-135m", "smollm-360m", "gemma-2b", "rwkv6-1.6b")
FULL_LADDER_ARCHS: Tuple[str, ...] = (
    "smollm-135m", "smollm-360m", "gemma-2b", "zamba2-2.7b",
    "rwkv6-1.6b", "deepseek-v3-671b", "kimi-k2-1t-a32b")


@dataclass(frozen=True)
class ModelRung:
    """One ladder entry: a servable model size.

    ``accuracy`` is the registry quality score in (0, 1]; ``cost`` is
    the rung's fitted latency surface (anything satisfying the
    :class:`~repro.core.cost_model.CostModel` protocol — the solver and
    both fleet engines only ever call ``latency``/``throughput``);
    ``swap_cost`` is the weights-load time (seconds) a replica pays
    before serving its first batch on this rung — the model-swap
    analogue of the horizontal axis's cold start.
    """
    name: str
    accuracy: float
    cost: Union[PerfModel, CostModel]
    swap_cost: float = 0.0


class ModelLadder:
    """Accuracy-ordered rung collection (best rung first).

    The iteration order is the (m, n, c, b) solver's candidate
    preference, so construction sorts rungs accuracy-descending and
    rejects duplicate names or duplicate accuracies (ties would make
    the shed order ambiguous across runs).
    """

    def __init__(self, rungs: Sequence[ModelRung]):
        if not rungs:
            raise ValueError("a ModelLadder needs at least one rung")
        names = [r.name for r in rungs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate rung names: {names}")
        accs = [r.accuracy for r in rungs]
        if len(set(accs)) != len(accs):
            raise ValueError(f"duplicate rung accuracies: {accs}")
        for r in rungs:
            if not (0.0 < r.accuracy <= 1.0):
                raise ValueError(
                    f"rung {r.name!r}: accuracy {r.accuracy} not in (0, 1]")
        self.rungs: List[ModelRung] = sorted(
            rungs, key=lambda r: -r.accuracy)
        self._by_name = {r.name: r for r in self.rungs}

    def __len__(self) -> int:
        return len(self.rungs)

    def __iter__(self):
        return iter(self.rungs)

    def __getitem__(self, i: int) -> ModelRung:
        return self.rungs[i]

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def rung(self, name: str) -> ModelRung:
        if name not in self._by_name:
            raise KeyError(f"unknown rung {name!r}; ladder: "
                           f"{[r.name for r in self.rungs]}")
        return self._by_name[name]

    def accuracy(self, name: str) -> float:
        return self.rung(name).accuracy

    def cost(self, name: str) -> Union[PerfModel, CostModel]:
        return self.rung(name).cost

    def swap_cost(self, name: str) -> float:
        return self.rung(name).swap_cost

    def best(self, accuracy_floor: float = 0.0) -> ModelRung:
        """The highest-accuracy rung at or above the floor."""
        for r in self.rungs:
            if r.accuracy >= accuracy_floor - 1e-12:
                return r
        raise ValueError(
            f"no rung clears accuracy floor {accuracy_floor} "
            f"(best available: {self.rungs[0].accuracy})")

    def admissible(self, accuracy_floor: float = 0.0,
                   m_set: Optional[Sequence[str]] = None
                   ) -> List[ModelRung]:
        """Rungs the solver may consider, preference order preserved:
        optionally restricted to ``m_set`` (a pin), always restricted
        to accuracies at or above ``accuracy_floor``."""
        allow = None if m_set is None else set(m_set)
        out = [r for r in self.rungs
               if (allow is None or r.name in allow)
               and r.accuracy >= accuracy_floor - 1e-12]
        if not out:
            raise ValueError(
                f"no admissible rung (floor={accuracy_floor}, "
                f"m_set={m_set}, ladder={[r.name for r in self.rungs]})")
        return out


def _scaled(base: PerfModel, s: float) -> PerfModel:
    """The base surface with every coefficient scaled by ``s`` — a
    model ``s``x slower at every (b, c)."""
    return PerfModel(gamma=base.gamma * s, eps=base.eps * s,
                     delta=base.delta * s, eta=base.eta * s)


def fit_rung_cost(base: PerfModel, scale: float, *,
                  bs: Sequence[int] = tuple(range(1, 17)),
                  cs: Sequence[int] = tuple(range(1, 17)),
                  noise: float = 0.01, seed: int = 0) -> PerfModel:
    """A rung's **fitted** cost surface: profile the scaled model over
    the (b, c) grid (noisy samples, as a real profiling sweep would
    give) and RANSAC-fit a fresh :class:`PerfModel` to the sweep —
    the same calibration path the paper's Table 1 surface went
    through, per rung."""
    truth = _scaled(base, scale)
    return PerfModel.fit(truth.sample_profile(bs, cs, noise=noise,
                                              seed=seed))


def resolve_ladder(spec, **kw) -> Optional["ModelLadder"]:
    """Resolve a ladder *spec* as it appears in scenario meta or a CLI
    flag: ``None`` (no ladder) and :class:`ModelLadder` instances pass
    through; ``"default"`` / ``"full"`` name the stock arch tuples; any
    other string is a comma-separated arch-id list; any sequence is an
    arch-id tuple.  Keeping specs as strings keeps scenario meta
    JSON-serializable."""
    if spec is None or isinstance(spec, ModelLadder):
        return spec
    if isinstance(spec, str):
        if spec == "default":
            return default_ladder(**kw)
        if spec == "full":
            return default_ladder(FULL_LADDER_ARCHS, **kw)
        return default_ladder(tuple(s.strip() for s in spec.split(",")),
                              **kw)
    return default_ladder(tuple(spec), **kw)


def default_ladder(archs: Sequence[str] = DEFAULT_LADDER_ARCHS, *,
                   base: Optional[PerfModel] = None,
                   load_gb_per_s: float = 40.0,
                   noise: float = 0.01) -> ModelLadder:
    """The registry-derived ladder: one rung per arch id.

    * accuracy — ``repro.configs.registry.MODEL_ACCURACY``;
    * cost — :func:`fit_rung_cost` of ``base`` (default: the Fig. 4
      ``yolov5s_like`` surface) scaled by ``(active_params /
      active_params_smallest) ** (1/3)``, the sublinear latency growth
      of batch-amortized serving;
    * swap_cost — bf16 weight bytes over ``load_gb_per_s`` (weights
      streamed from local cache at swap time).

    Deterministic: the profiling seed is derived from the arch index,
    so the same ``archs`` tuple always fits the same surfaces (the
    decision-identity tests depend on this).
    """
    from repro.configs.registry import get_config, model_accuracy
    if base is None:
        base = yolov5s_like()
    cfgs = {a: get_config(a) for a in archs}
    active = {a: float(cfgs[a].active_param_count()) for a in archs}
    a0 = min(active.values())
    rungs = []
    for i, a in enumerate(archs):
        scale = (active[a] / a0) ** (1.0 / 3.0)
        cost = fit_rung_cost(base, scale, noise=noise, seed=1000 + i)
        swap = 2.0 * float(cfgs[a].param_count()) / (load_gb_per_s * 1e9)
        rungs.append(ModelRung(name=a, accuracy=model_accuracy(a),
                               cost=cost, swap_cost=swap))
    return ModelLadder(rungs)
