"""EDF queue + dynamic batcher (paper §3.1 "Queuing").

Requests are reordered by remaining SLO (earliest absolute deadline first);
the batcher emits batches of the solver's current b.
"""
from __future__ import annotations

import heapq
from typing import Iterable, List, Optional

from repro.core.slo import Request


class EDFQueue:
    def __init__(self):
        self._heap: list[tuple[float, int, Request]] = []

    def __len__(self):
        return len(self._heap)

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (req.deadline, req.id, req))

    def extend(self, reqs: Iterable[Request]) -> None:
        for r in reqs:
            self.push(r)

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Optional[Request]:
        return self._heap[0][2] if self._heap else None

    def pop_batch(self, b: int) -> List[Request]:
        return [self.pop() for _ in range(min(b, len(self._heap)))]

    def snapshot_remaining(self, now: float) -> List[float]:
        """Remaining budgets (sorted ascending) — the solver's input."""
        return sorted(r.deadline - now for _, _, r in self._heap)

    def drop_expired(self, now: float) -> List[Request]:
        """Remove requests whose deadline already passed (counted as
        violations by the caller)."""
        dropped = []
        keep = []
        for item in self._heap:
            if item[0] < now:
                dropped.append(item[2])
            else:
                keep.append(item)
        if dropped:
            self._heap = keep
            heapq.heapify(self._heap)
        return dropped


class DynamicBatcher:
    """Forms batches of the scaler's current b from the EDF queue."""

    def __init__(self, queue: EDFQueue, b: int = 1):
        self.queue = queue
        self.b = b

    def set_batch_size(self, b: int) -> None:
        assert b >= 1
        self.b = b

    def next_batch(self) -> List[Request]:
        return self.queue.pop_batch(self.b)

    def has_work(self) -> bool:
        return len(self.queue) > 0
