"""EDF queue + dynamic batcher (paper §3.1 "Queuing").

Requests are reordered by remaining SLO (earliest absolute deadline first);
the batcher emits batches of the solver's current b.

Two queue substrates share the EDF discipline:

* ``EDFQueue``     — heap of ``Request`` objects (the live/exact path);
* ``FastEDFQueue`` — heap of bare ``(deadline, index)`` pairs into a
  struct-of-arrays request batch, used by the million-request fast path
  (``repro.serving.fastpath``).  No per-request Python objects exist;
  the solver snapshot is a single vectorized ``np.sort``.
"""
from __future__ import annotations

import heapq
from typing import Iterable, List, Optional

import numpy as np

from repro.core.slo import Request


def _remaining_array(heap: list, now: float) -> np.ndarray:
    """Sorted remaining budgets from a deadline-first heap (item[0] is the
    absolute deadline on both queue substrates) — one vectorized pass."""
    dl = np.fromiter((item[0] for item in heap), np.float64, len(heap))
    return np.sort(dl - now)


class EDFQueue:
    def __init__(self):
        self._heap: list[tuple[float, int, Request]] = []

    def __len__(self):
        return len(self._heap)

    def push(self, req: Request) -> None:
        heapq.heappush(self._heap, (req.deadline, req.id, req))

    def extend(self, reqs: Iterable[Request]) -> None:
        for r in reqs:
            self.push(r)

    def pop(self) -> Request:
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Optional[Request]:
        return self._heap[0][2] if self._heap else None

    def pop_batch(self, b: int) -> List[Request]:
        return [self.pop() for _ in range(min(b, len(self._heap)))]

    def snapshot_remaining(self, now: float) -> List[float]:
        """Remaining budgets (sorted ascending) — the solver's input."""
        return sorted(r.deadline - now for _, _, r in self._heap)

    def remaining_array(self, now: float) -> np.ndarray:
        """Vectorized ``snapshot_remaining``: sorted np.float64 budgets."""
        return _remaining_array(self._heap, now)

    def token_snapshot(self, now: float):
        """Token-aware solver input: ``(ttft_budgets, prompt_tokens,
        tbt_min)`` with budgets EDF-sorted ascending, token counts
        aligned to that order, and the tightest per-token SLO queued
        (``inf`` when empty or all-fixed-work)."""
        if not self._heap:
            return (np.empty(0, np.float64), np.empty(0, np.float64),
                    float("inf"))
        dl = np.fromiter((item[0] for item in self._heap), np.float64,
                         len(self._heap))
        toks = np.fromiter((item[2].prompt_tokens for item in self._heap),
                           np.float64, len(self._heap))
        tbt = min(item[2].tbt_slo for item in self._heap)
        order = np.argsort(dl, kind="stable")
        return dl[order] - now, toks[order], float(tbt)

    def drop_expired(self, now: float) -> List[Request]:
        """Remove requests whose deadline already passed (counted as
        violations by the caller)."""
        dropped = []
        keep = []
        for item in self._heap:
            if item[0] < now:
                dropped.append(item[2])
            else:
                keep.append(item)
        if dropped:
            self._heap = keep
            heapq.heapify(self._heap)
        return dropped


class FastEDFQueue:
    """EDF queue over request *indices* — the fast-path substrate.

    Entries are bare ``(deadline, index)`` tuples pointing into a
    struct-of-arrays workload (``repro.serving.workload.RequestBatch``),
    so a million queued requests cost two machine words each and no
    object allocation.  Presents the same read surface the scheduling
    policies use (``__len__`` / ``snapshot_remaining`` /
    ``remaining_array`` / ``peek_deadline``), which lets any
    decide-protocol ``SchedulingPolicy`` run unmodified on the fast path.
    """

    def __init__(self):
        self._heap: list[tuple[float, int]] = []

    def __len__(self):
        return len(self._heap)

    def push(self, deadline: float, idx: int) -> None:
        heapq.heappush(self._heap, (deadline, idx))

    def peek_deadline(self) -> float:
        return self._heap[0][0]

    def pop_batch(self, b: int) -> List[int]:
        """Pop the ≤b earliest-deadline request indices (EDF order)."""
        pop = heapq.heappop
        h = self._heap
        return [pop(h)[1] for _ in range(min(b, len(h)))]

    def remaining_array(self, now: float) -> np.ndarray:
        """Sorted remaining budgets — one vectorized pass over the heap."""
        return _remaining_array(self._heap, now)

    def snapshot_remaining(self, now: float) -> List[float]:
        return self.remaining_array(now).tolist()


class TokenFastEDFQueue(FastEDFQueue):
    """Fast-path EDF queue bound to a struct-of-arrays token workload.

    ``bind`` attaches the workload's per-request ``prompt_tokens`` and
    ``tbt_slo`` columns once; ``token_snapshot`` then assembles the
    token-aware solver input (EDF-sorted budgets, aligned token counts,
    tightest queued TBT) from the bare ``(deadline, index)`` heap with
    three vectorized passes — the same no-objects discipline as
    :class:`FastEDFQueue`.
    """

    def __init__(self):
        super().__init__()
        self._prompt_tokens: Optional[np.ndarray] = None
        self._tbt: Optional[np.ndarray] = None

    def bind(self, prompt_tokens: np.ndarray, tbt_slo: np.ndarray) -> None:
        """Attach the workload columns the snapshots index into."""
        self._prompt_tokens = np.asarray(prompt_tokens, np.float64)
        self._tbt = np.asarray(tbt_slo, np.float64)

    def token_snapshot(self, now: float):
        """Same contract as ``EDFQueue.token_snapshot``."""
        if not self._heap:
            return (np.empty(0, np.float64), np.empty(0, np.float64),
                    float("inf"))
        assert self._prompt_tokens is not None, "bind() the workload first"
        dl = np.fromiter((item[0] for item in self._heap), np.float64,
                         len(self._heap))
        idx = np.fromiter((item[1] for item in self._heap), np.int64,
                          len(self._heap))
        order = np.argsort(dl, kind="stable")
        toks = self._prompt_tokens[idx[order]]
        tbt = float(self._tbt[idx].min())
        return dl[order] - now, toks, tbt


class DynamicBatcher:
    """Forms batches of the scaler's current b from the EDF queue."""

    def __init__(self, queue: EDFQueue, b: int = 1):
        self.queue = queue
        self.b = b

    def set_batch_size(self, b: int) -> None:
        assert b >= 1
        self.b = b

    def next_batch(self) -> List[Request]:
        return self.queue.pop_batch(self.b)

    def has_work(self) -> bool:
        return len(self.queue) > 0
