"""EDF queue + dynamic batcher (paper §3.1 "Queuing").

Requests are reordered by remaining SLO (earliest absolute deadline first);
the batcher emits batches of the solver's current b.

Two queue substrates share the EDF discipline:

* ``EDFQueue``     — heap of ``Request`` objects (the live/exact path);
* ``FastEDFQueue`` — heap of bare ``(deadline, index)`` pairs into a
  struct-of-arrays request batch, used by the million-request fast path
  (``repro.serving.fastpath``).  No per-request Python objects exist;
  the solver snapshot is a single vectorized ``np.sort``.

**Mid-flight renegotiation (ISSUE 5).**  Both substrates support
re-keying a queued entry's deadline (``update_deadline``) and removing
a queued entry outright (``cancel``) — the primitives the online
session API (``repro.serving.session``) builds on.  The mechanism is
*lazy invalidation with live-entry re-push*: a ``_live`` map (key →
current deadline / request) is the source of truth, an update pushes a
fresh heap entry under the new key and leaves the old tuple behind as
garbage, and pops discard any tuple whose key no longer matches the
live map.  After every mutation the **top-live invariant** is
restored — the heap's root is always a live entry — so the O(1) head
reads the hot dispatch loops rely on (``peek_deadline`` /
``_heap[0][0]``) stay exact without scanning.  When no renegotiation
ever happens, no stale entry ever exists and every operation performs
the same heap work as before, which is what keeps the session replay
paths decision-identical to the historical closed-world loops.
"""
from __future__ import annotations

import heapq
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.slo import Request


class EDFQueue:
    """EDF heap of ``Request`` objects with mid-flight re-keying.

    ``_live`` maps ``req.id`` to the queued ``Request``; a heap tuple
    ``(deadline, id, req)`` is live iff the id is still mapped to that
    object *and* the tuple's deadline matches ``req.deadline`` (updates
    mutate the request's deadline and re-push, so superseded tuples
    fail the second check).
    """

    def __init__(self):
        self._heap: list[tuple[float, int, Request]] = []
        self._live: Dict[int, Request] = {}

    @staticmethod
    def _key(req: Request) -> float:
        """Heap ordering key.  EDF orders by absolute deadline;
        subclasses may reorder (e.g. the FIFO ablation keys by arrival)
        — the live/stale machinery follows the hook."""
        return req.deadline

    def __len__(self):
        return len(self._live)

    def push(self, req: Request) -> None:
        self._live[req.id] = req
        heapq.heappush(self._heap, (self._key(req), req.id, req))

    def extend(self, reqs: Iterable[Request]) -> None:
        for r in reqs:
            self.push(r)

    def _fix_top(self) -> None:
        """Restore the top-live invariant (drop stale root tuples)."""
        h, live = self._heap, self._live
        while h:
            key, rid, req = h[0]
            if live.get(rid) is req and self._key(req) == key:
                return
            heapq.heappop(h)

    def __contains__(self, rid: int) -> bool:
        return rid in self._live

    def update_deadline(self, rid: int, new_deadline: float) -> bool:
        """Re-key a queued request to ``new_deadline`` (mid-flight SLO
        renegotiation).  Lazy invalidation: the request object's
        deadline is rewritten and — when the ordering key moved — a
        fresh heap entry pushed; the stale tuple is discarded when it
        surfaces.  Returns False when the id is not queued (already
        dispatched / cancelled / unknown)."""
        req = self._live.get(rid)
        if req is None:
            return False
        if req.deadline == new_deadline:
            return True
        old_key = self._key(req)
        req.deadline = new_deadline
        if self._key(req) != old_key:
            heapq.heappush(self._heap, (self._key(req), rid, req))
            self._fix_top()
        return True

    def cancel(self, rid: int) -> Optional[Request]:
        """Remove a queued request (client abandoned it).  Returns the
        request, or None when it is not queued (double-cancel safe)."""
        req = self._live.pop(rid, None)
        if req is not None:
            self._fix_top()
        return req

    def pop(self) -> Request:
        h, live = self._heap, self._live
        while True:
            key, rid, req = heapq.heappop(h)
            if live.get(rid) is req and self._key(req) == key:
                del live[rid]
                self._fix_top()
                return req

    def peek(self) -> Optional[Request]:
        return self._heap[0][2] if self._heap else None

    def pop_batch(self, b: int) -> List[Request]:
        return [self.pop() for _ in range(min(b, len(self._live)))]

    def live_requests(self) -> List[Request]:
        """The live-entry snapshot: every queued request exactly once.

        This — never ``_heap`` — is the observer-facing view.  After an
        ``update_deadline`` the heap holds stale duplicates of the re-keyed
        request, and after a ``cancel`` it still holds the dead tuple;
        only ``_live`` reflects the queue's true contents.
        """
        return list(self._live.values())

    def snapshot_remaining(self, now: float) -> List[float]:
        """Remaining budgets (sorted ascending) — the solver's input."""
        return sorted(r.deadline - now for r in self._live.values())

    def remaining_array(self, now: float) -> np.ndarray:
        """Vectorized ``snapshot_remaining``: sorted np.float64 budgets."""
        dl = np.fromiter((r.deadline for r in self._live.values()),
                         np.float64, len(self._live))
        return np.sort(dl - now)

    def token_snapshot(self, now: float):
        """Token-aware solver input: ``(ttft_budgets, prompt_tokens,
        tbt_min)`` with budgets EDF-sorted ascending, token counts
        aligned to that order, and the tightest per-token SLO queued
        (``inf`` when empty or all-fixed-work)."""
        if not self._live:
            return (np.empty(0, np.float64), np.empty(0, np.float64),
                    float("inf"))
        reqs = list(self._live.values())
        dl = np.fromiter((r.deadline for r in reqs), np.float64, len(reqs))
        toks = np.fromiter((r.prompt_tokens for r in reqs), np.float64,
                           len(reqs))
        tbt = min(r.tbt_slo for r in reqs)
        order = np.argsort(dl, kind="stable")
        return dl[order] - now, toks[order], float(tbt)

    def drop_expired(self, now: float) -> List[Request]:
        """Remove requests whose deadline already passed (counted as
        violations by the caller)."""
        dropped = [r for r in self._live.values() if r.deadline < now]
        if dropped:
            for r in dropped:
                del self._live[r.id]
            self._heap = [(self._key(r), r.id, r)
                          for r in self._live.values()]
            heapq.heapify(self._heap)
        return dropped


class FastEDFQueue:
    """EDF queue over request *indices* — the fast-path substrate.

    Entries are bare ``(deadline, index)`` tuples pointing into a
    struct-of-arrays workload (``repro.serving.workload.RequestBatch``),
    so a million queued requests cost two machine words each and no
    object allocation.  Presents the same read surface the scheduling
    policies use (``__len__`` / ``snapshot_remaining`` /
    ``remaining_array`` / ``peek_deadline``), which lets any
    decide-protocol ``SchedulingPolicy`` run unmodified on the fast path.

    ``_live`` (index → current deadline) carries the renegotiation
    state: ``update_deadline`` re-pushes under the new key,  ``cancel``
    drops the mapping, and pops skip tuples whose deadline no longer
    matches.  The top-live invariant holds after every mutation, so the
    inlined dispatch loops may keep reading ``_heap[0][0]`` (head
    deadline) and ``bool(_heap)`` (emptiness) directly; live *counts*
    must come from ``len(queue)`` / ``_live``.
    """

    def __init__(self):
        self._heap: list[tuple[float, int]] = []
        self._live: Dict[int, float] = {}

    def __len__(self):
        return len(self._live)

    def __contains__(self, idx: int) -> bool:
        return idx in self._live

    def push(self, deadline: float, idx: int) -> None:
        self._live[idx] = deadline
        heapq.heappush(self._heap, (deadline, idx))

    def _fix_top(self) -> None:
        """Restore the top-live invariant (drop stale root tuples)."""
        h, live = self._heap, self._live
        while h and live.get(h[0][1]) != h[0][0]:
            heapq.heappop(h)

    def push_many(self, deadlines, idxs) -> None:
        """Bulk push of aligned ``(deadline, index)`` columns — one
        extend + heapify instead of n sift-ups.  Order-identical to
        sequential :meth:`push` calls: the heap is a set ordered by
        ``(deadline, index)`` at pop time, so how entries entered it
        cannot change any pop sequence (``tests/test_queueing.py``
        proves this against interleaved re-keys and cancels)."""
        live = self._live
        h = self._heap
        pairs = list(zip(np.asarray(deadlines, np.float64).tolist(),
                         np.asarray(idxs, np.int64).tolist()))
        if not pairs:
            return
        for dl, i in pairs:
            live[i] = dl
        if not h:
            # an already-(deadline, idx)-sorted block is a valid heap
            if all(pairs[k] <= pairs[k + 1] for k in range(len(pairs) - 1)):
                self._heap = pairs
                return
        h.extend(pairs)
        # heapify is O(n); per-item sift-up is O(n log n) — for small
        # tails on a big heap the pushes win, so pick by size
        if len(pairs) * 8 >= len(h):
            heapq.heapify(h)
        else:
            del h[-len(pairs):]
            for p in pairs:
                heapq.heappush(h, p)
        self._fix_top()

    def pop_ready(self, b: int, before: float = float("inf")) -> List[int]:
        """Bulk EDF pop with a deadline bound: pop the ≤``b`` earliest
        live indices whose deadline is < ``before`` (exclusive), in the
        exact ``(deadline, index)`` order sequential pops would use.
        ``before=inf`` makes it :meth:`pop_batch`.  Stale tuples
        (re-keyed / cancelled entries) are discarded as they surface."""
        pop = heapq.heappop
        h, live = self._heap, self._live
        out: List[int] = []
        while h and len(out) < b and h[0][0] < before:
            dl, idx = pop(h)
            if live.get(idx) == dl:
                del live[idx]
                out.append(idx)
        self._fix_top()
        return out

    def update_deadline(self, idx: int, new_deadline: float) -> bool:
        """Re-key a queued index to ``new_deadline``; False when the
        index is not queued (dispatched / cancelled / unknown)."""
        old = self._live.get(idx)
        if old is None:
            return False
        if old == new_deadline:
            return True
        self._live[idx] = new_deadline
        heapq.heappush(self._heap, (new_deadline, idx))
        self._fix_top()
        return True

    def cancel(self, idx: int) -> bool:
        """Remove a queued index; False when it is not queued
        (double-cancel safe)."""
        if self._live.pop(idx, None) is None:
            return False
        self._fix_top()
        return True

    def peek_deadline(self) -> float:
        return self._heap[0][0]

    def pop_batch(self, b: int) -> List[int]:
        """Pop the ≤b earliest-deadline live request indices (EDF
        order), discarding stale tuples as they surface."""
        pop = heapq.heappop
        h, live = self._heap, self._live
        out: List[int] = []
        while h and len(out) < b:
            dl, idx = pop(h)
            if live.get(idx) == dl:
                del live[idx]
                out.append(idx)
        self._fix_top()
        return out

    def drain(self) -> List[Tuple[float, int]]:
        """Pop every live entry in EDF order as ``(deadline, index)``
        pairs (fleet re-routing / retirement)."""
        pop = heapq.heappop
        h, live = self._heap, self._live
        out: List[Tuple[float, int]] = []
        while h:
            dl, idx = pop(h)
            if live.get(idx) == dl:
                del live[idx]
                out.append((dl, idx))
        return out

    def remaining_array(self, now: float) -> np.ndarray:
        """Sorted remaining budgets — one vectorized pass over the
        live-entry map."""
        dl = np.fromiter(self._live.values(), np.float64, len(self._live))
        return np.sort(dl - now)

    def snapshot_remaining(self, now: float) -> List[float]:
        return self.remaining_array(now).tolist()


class TokenFastEDFQueue(FastEDFQueue):
    """Fast-path EDF queue bound to a struct-of-arrays token workload.

    ``bind`` attaches the workload's per-request ``prompt_tokens`` and
    ``tbt_slo`` columns once; ``token_snapshot`` then assembles the
    token-aware solver input (EDF-sorted budgets, aligned token counts,
    tightest queued TBT) from the live-entry map with three vectorized
    passes — the same no-objects discipline as :class:`FastEDFQueue`.
    """

    def __init__(self):
        super().__init__()
        self._prompt_tokens: Optional[np.ndarray] = None
        self._tbt: Optional[np.ndarray] = None

    def bind(self, prompt_tokens: np.ndarray, tbt_slo: np.ndarray) -> None:
        """Attach the workload columns the snapshots index into."""
        self._prompt_tokens = np.asarray(prompt_tokens, np.float64)
        self._tbt = np.asarray(tbt_slo, np.float64)

    def token_snapshot(self, now: float):
        """Same contract as ``EDFQueue.token_snapshot``."""
        if not self._live:
            return (np.empty(0, np.float64), np.empty(0, np.float64),
                    float("inf"))
        assert self._prompt_tokens is not None, "bind() the workload first"
        n = len(self._live)
        dl = np.fromiter(self._live.values(), np.float64, n)
        idx = np.fromiter(self._live.keys(), np.int64, n)
        order = np.argsort(dl, kind="stable")
        toks = self._prompt_tokens[idx[order]]
        tbt = float(self._tbt[idx].min())
        return dl[order] - now, toks, tbt


class DynamicBatcher:
    """Forms batches of the scaler's current b from the EDF queue."""

    def __init__(self, queue: EDFQueue, b: int = 1):
        self.queue = queue
        self.b = b

    def set_batch_size(self, b: int) -> None:
        assert b >= 1
        self.b = b

    def next_batch(self) -> List[Request]:
        return self.queue.pop_batch(self.b)

    def has_work(self) -> bool:
        return len(self.queue) > 0
