"""HLO text analysis: collective-traffic accounting for the roofline.

``cost_analysis()`` gives FLOPs and HBM bytes but NOT collective traffic, so
we parse the compiled (SPMD-partitioned) HLO text and sum the result sizes
of every collective op.  Shapes in the partitioned module are per-device, so
the totals are per-chip bytes moved over ICI (receive-side convention; for
all-reduce the ring cost is ~2x(n-1)/n of that — we report raw result bytes
and keep the convention fixed across experiments so deltas are comparable).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# e.g.:  %x = bf16[8,128]{1,0} all-gather(...)   or tuple results
_OP_RE = re.compile(
    r"=\s*(?P<result>\([^=]*?\)|[\w\[\],{}:#\s]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")


def shape_bytes(type_str: str) -> int:
    """Sum bytes over every tensor shape appearing in a type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    def summary(self) -> str:
        parts = [f"{k}: n={self.count_by_kind[k]} "
                 f"bytes={self.bytes_by_kind[k]:,}"
                 for k in sorted(self.bytes_by_kind)]
        return "; ".join(parts) if parts else "none"


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by = defaultdict(int)
    count_by = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group("suffix") == "-done":
            continue  # avoid double counting start/done pairs
        kind = m.group("op")
        b = shape_bytes(m.group("result"))
        bytes_by[kind] += b
        count_by[kind] += 1
    return CollectiveStats(dict(bytes_by), dict(count_by))


def duplicate_op_counts(hlo_text: str, top: int = 10) -> list[tuple[str, int]]:
    """Fusion-name histogram — a cheap remat/recompute indicator."""
    counts: Dict[str, int] = defaultdict(int)
    for m in re.finditer(r"fusion\(|dot\(|convolution\(", hlo_text):
        counts[m.group(0)[:-1]] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
