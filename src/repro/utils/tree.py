"""Pytree helpers shared across the framework."""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes across all leaves (uses leaf dtype itemsize)."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return total


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map ``fn(path_string, leaf)`` over a pytree."""
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_path_str(p), x), tree)


def tree_paths(tree: Any) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [_path_str(p) for p, _ in flat]
