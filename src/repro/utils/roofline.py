"""Three-term roofline from a compiled dry-run artifact.

TPU v5e per-chip constants (the TARGET hardware; this container is CPU):
    peak bf16 compute  : 197 TFLOP/s
    HBM bandwidth      : 819 GB/s
    ICI link bandwidth : ~50 GB/s

Terms (seconds, per step, per chip — cost_analysis of the SPMD-partitioned
executable reports per-device flops/bytes):
    compute    = HLO_FLOPs_per_chip / peak
    memory     = HLO_bytes_per_chip / hbm_bw
    collective = collective_bytes_per_chip / ici_bw
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Optional


PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float            # 6*N*D (dense) / 6*N_active*D (MoE), global
    useful_ratio: float           # model_flops / (flops_per_chip * chips)
    collectives: dict
    memory_analysis: dict
    note: str = ""

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)

    @property
    def step_time_s(self) -> float:
        """Simple roofline step-time estimate: overlapped compute/memory
        plus (conservatively serial) collectives."""
        return max(self.compute_s, self.memory_s) + self.collective_s

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} "
                f"| {self.compute_s*1e3:.2f} | {self.memory_s*1e3:.2f} "
                f"| {self.collective_s*1e3:.2f} | {self.dominant} "
                f"| {self.useful_ratio:.2f} |")


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, model_flops: float,
            memory_analysis: Optional[object] = None,
            note: str = "") -> Roofline:
    # trip-count-weighted totals (XLA's cost_analysis counts while bodies
    # once — fatal for scan-over-layers models; see utils/hlo_cost.py)
    from repro.utils.hlo_cost import analyze_weighted
    wc = analyze_weighted(hlo_text)
    flops = float(wc.flops)
    byts = float(wc.bytes_accessed)
    coll_b = {k: int(v) for k, v in wc.collective_bytes.items()}
    coll_n = {k: int(v) for k, v in wc.collective_counts.items()}
    cb = float(wc.total_collective_bytes)
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = cb / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    ma = {}
    if memory_analysis is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "peak_memory_in_bytes"):
            v = getattr(memory_analysis, k, None)
            if v is not None:
                ma[k] = int(v)
    useful = model_flops / max(flops * chips, 1.0)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        collective_bytes_per_chip=cb,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful,
        collectives={"bytes": coll_b, "count": coll_n,
                     "xla_flops_unweighted": xla_flops,
                     "xla_bytes_unweighted": xla_bytes},
        memory_analysis=ma, note=note)
