"""Trip-count-weighted HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model under-reports flops/bytes/collectives by ~num_layers.
This module parses the optimized HLO text, builds the computation call graph
(entry -> fusions/calls/whiles), extracts while trip counts from their
condition computations, and aggregates:

* flops        — 2 * prod(result_shape) * prod(contracting_dims) per dot
                 (+ convolutions), the standard matmul-dominant estimate;
* bytes        — operands + results of top-level instructions per
                 computation (fusion-internal values never touch HBM);
* collectives  — result bytes per collective kind;

each weighted by the product of enclosing while trip counts.  Validated
against XLA's own numbers on unrolled graphs (tests/test_hlo_cost.py).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.utils.hlo_analysis import COLLECTIVES, _DTYPE_BYTES

_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.+?)\s*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_WHILE = re.compile(r"\bwhile\(")
_OPNAME = re.compile(r"^(?:\([^)]*\)|[\w\[\],{}]+)\s+([\w\-]+)")


def _parse_shapes(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) \
            else ()
        out.append((dt, dims))
    return out


def _nbytes(type_str: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    instructions: List[Tuple[str, str]] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # sym -> type str
    root: Optional[str] = None


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        hm = _COMP_HEADER.match(raw) or _COMP_HEADER.match(line)
        if hm and ("->" in line) and line.endswith("{"):
            cur = Computation(hm.group(1))
            comps[cur.name] = cur
            # parameter shapes come from their `parameter(i)` instructions
            continue
        if cur is None:
            continue
        if line == "}" or line.startswith("}"):
            cur = None
            continue
        im = _INSTR.match(line)
        if im:
            sym, rhs = im.group(1), im.group(2)
            if line.lstrip().startswith("ROOT"):
                cur.root = sym
            cur.instructions.append((sym, rhs))
            # result type = leading type expression of the rhs
            tm = re.match(r"(\([^=]*?\)|[\w\[\],{}]+)\s", rhs)
            if tm:
                cur.shapes[sym] = tm.group(1)
    return comps


def _trip_count(cond: Computation) -> int:
    """Trip count from a scan-style condition: compare(iv, constant(N))."""
    consts: Dict[str, int] = {}
    for sym, rhs in cond.instructions:
        cm = re.search(r"\bconstant\((\d+)\)", rhs)
        if cm:
            consts[sym] = int(cm.group(1))
    for sym, rhs in cond.instructions:
        if " compare(" in rhs or rhs.startswith("pred[] compare("):
            ops = re.findall(r"%?([\w.\-]+)", rhs.split("compare(", 1)[1]
                             .split(")")[0])
            for o in ops:
                if o in consts and consts[o] > 0:
                    return consts[o]
    if consts:
        return max(consts.values())
    return 1


def _op_of(rhs: str) -> str:
    m = _OPNAME.match(rhs)
    return m.group(1) if m else ""


@dataclass
class WeightedCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    trip_counts: Dict[str, int] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _dot_flops(comp: Computation, sym: str, rhs: str) -> float:
    """2 * prod(result) * prod(contracting)."""
    res = _parse_shapes(comp.shapes.get(sym, ""))
    if not res:
        return 0.0
    out_elems = 1
    for _, dims in res[:1]:
        for d in dims:
            out_elems *= d
    # contracting dims from lhs shape + lhs_contracting_dims
    m = re.search(r"dot\(([^)]*)\)", rhs)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    if not (m and cm):
        return 2.0 * out_elems  # unknown: count as elementwise-ish
    operands = re.findall(r"%?([\w.\-]+)", m.group(1))
    lhs_type = comp.shapes.get(operands[0], "") if operands else ""
    lhs_shapes = _parse_shapes(lhs_type)
    if not lhs_shapes:
        return 2.0 * out_elems
    lhs_dims = lhs_shapes[0][1]
    k = 1
    for ci in (int(x) for x in cm.group(1).split(",") if x):
        if ci < len(lhs_dims):
            k *= lhs_dims[ci]
    return 2.0 * out_elems * k


def analyze_weighted(hlo: str) -> WeightedCost:
    comps = parse_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.strip().startswith("ENTRY"):
            m = _COMP_HEADER.match(line.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c].instructions)) \
            if comps else None
    wc = WeightedCost()
    if entry is None:
        return wc

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # propagate multipliers: BFS over call edges (HLO call graph is a DAG)
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps[cname]
        for sym, rhs in comp.instructions:
            op = _op_of(rhs)
            callees = _CALLS.findall(rhs)
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", rhs)
                cond = re.search(r"condition=%?([\w.\-]+)", rhs)
                trip = 1
                if cond and cond.group(1) in comps:
                    trip = _trip_count(comps[cond.group(1)])
                if body:
                    wc.trip_counts[body.group(1)] = trip
                    mult[body.group(1)] += mult[cname] * trip
                    if body.group(1) not in seen:
                        seen.add(body.group(1))
                        order.append(body.group(1))
            else:
                for cal in callees:
                    if cal in comps:
                        mult[cal] += mult[cname]
                        if cal not in seen:
                            seen.add(cal)
                            order.append(cal)

    # computations that are fusion bodies: their instructions never touch
    # HBM individually (the caller fusion accounts for reads/writes)
    fusion_bodies: set = set()
    for cname, comp in comps.items():
        for sym, rhs in comp.instructions:
            if _op_of(rhs) == "fusion":
                cm_ = re.search(r"calls=%?([\w.\-]+)", rhs)
                if cm_:
                    fusion_bodies.add(cm_.group(1))

    read_cache: Dict[str, Dict[int, Optional[float]]] = {}

    def _operands(rhs: str) -> List[str]:
        opers = re.search(r"\(([^()]*(?:\([^()]*\))?[^()]*)\)", rhs)
        if not opers:
            return []
        return re.findall(r"%([\w.\-]+)", opers.group(1))

    def _fusion_write_bytes(fname: str, default: float) -> float:
        """Fusion output HBM write: if the fused root is an in-place
        dynamic-update-slice (possibly behind bitcasts/converts/tuples),
        only the update slice is written (scan ys buffers are aliased)."""
        comp = comps.get(fname)
        if comp is None or not comp.instructions:
            return default
        rhs_by = dict(comp.instructions)
        sym = comp.root or comp.instructions[-1][0]
        for _ in range(4):  # follow trivial wrappers to the real producer
            rhs = rhs_by.get(sym, "")
            op = _op_of(rhs)
            if op == "dynamic-update-slice":
                ops_ = _operands(rhs)
                if len(ops_) > 1:
                    return float(_nbytes(comp.shapes.get(ops_[1], "")))
                return default
            if op in ("bitcast", "copy", "convert", "tuple"):
                ops_ = _operands(rhs)
                if not ops_:
                    return default
                sym = ops_[0]
                continue
            return default
        return default

    def _fusion_param_reads(fname: str) -> Dict[int, Optional[float]]:
        """Per-parameter HBM read estimate inside a fused computation.
        None => full parameter; float => sliced read bytes."""
        if fname in read_cache:
            return read_cache[fname]
        out: Dict[int, Optional[float]] = {}
        comp = comps.get(fname)
        if comp is None:
            read_cache[fname] = out
            return out
        psyms: Dict[str, int] = {}
        for sym, rhs in comp.instructions:
            pm = re.match(r".*\bparameter\((\d+)\)", rhs)
            if pm:
                psyms[sym] = int(pm.group(1))
        for sym, idx in psyms.items():
            sliced = 0.0
            full = False
            used = False
            for s2, rhs2 in comp.instructions:
                ops2 = _operands(rhs2)
                if sym not in ops2:
                    continue
                used = True
                op2 = _op_of(rhs2)
                if op2 in ("dynamic-slice", "slice") and ops2 \
                        and ops2[0] == sym:
                    sliced += _nbytes(comp.shapes.get(s2, ""))
                elif op2 == "dynamic-update-slice" and ops2 \
                        and ops2[0] == sym:
                    # in-place target: reads nothing extra of the target
                    pass
                else:
                    full = True
                    break
            out[idx] = None if (full or not used) else sliced
        read_cache[fname] = out
        return out

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for sym, rhs in comp.instructions:
            op = _op_of(rhs)
            if op == "dot" or op.startswith("dot."):
                wc.flops += m * _dot_flops(comp, sym, rhs)
            elif op in ("convolution",):
                wc.flops += m * 2.0 * _nbytes(comp.shapes.get(sym, "")) / 4
            if op in COLLECTIVES or any(op == c + "-start"
                                        for c in COLLECTIVES):
                kind = op.replace("-start", "")
                b = _nbytes(comp.shapes.get(sym, ""))
                wc.collective_bytes[kind] = \
                    wc.collective_bytes.get(kind, 0.0) + m * b
                wc.collective_counts[kind] = \
                    wc.collective_counts.get(kind, 0.0) + m
            # ---- bytes accessed (HBM traffic estimate) -------------------
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "while", "call", "conditional"):
                continue
            if cname in fusion_bodies:
                continue  # accounted at the caller fusion
            ops_ = _operands(rhs)
            if op in ("dynamic-slice", "slice"):
                b = 2.0 * _nbytes(comp.shapes.get(sym, ""))  # read+write slice
            elif op == "dynamic-update-slice":
                upd = ops_[1] if len(ops_) > 1 else None
                ub = _nbytes(comp.shapes.get(upd, "")) if upd else 0
                b = 2.0 * ub                                  # in-place
            elif op == "fusion":
                cm_ = re.search(r"calls=%?([\w.\-]+)", rhs)
                reads = _fusion_param_reads(cm_.group(1)) if cm_ else {}
                b = _nbytes(comp.shapes.get(sym, ""))
                if cm_:
                    b = _fusion_write_bytes(cm_.group(1), b)
                for i, on in enumerate(ops_):
                    r = reads.get(i, None)
                    ob = _nbytes(comp.shapes.get(on, ""))
                    b += min(r, ob) if r is not None else ob
            else:
                b = _nbytes(comp.shapes.get(sym, ""))
                for on in ops_:
                    b += _nbytes(comp.shapes.get(on, ""))
            wc.bytes_accessed += m * b
    return wc
