"""Fleet-scale serving benchmark: joint (n, c, b) scaling vs a static fleet.

Runs the ``fleet-flash-crowd`` scenario at >=500k requests through the
struct-of-arrays fleet engine (``repro.serving.fleet.FleetFastSimRunner``
+ the quantized joint memoized solver), then replays the *same* workload
under a ladder of peak-provisioned static fleets (``StaticFleetPolicy``,
8 replicas at several pinned core counts).  Reported per run: goodput
(requests finishing inside their dynamic SLO per second), SLO violation
rate, total core-seconds and the solver cache hit rate.

The acceptance bar (ISSUE 4): the joint scaler must use **>= 8 replicas**
at peak and save **>= 20% core-seconds** against the static-fleet
baseline *at equal SLO violation rate* — operationally: the baseline is
the cheapest static fleet whose violation rate is no worse than the
joint scaler's (when every static fleet violates more, the largest one
is used and the joint scaler wins both axes outright).

    PYTHONPATH=src python -m benchmarks.fleet_bench
    PYTHONPATH=src python benchmarks/fleet_bench.py --requests 100000
"""
from __future__ import annotations

import argparse
import time

from repro.core.perf_model import yolov5s_like
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.serving.fleet import (FleetFastSimRunner, FleetSpongeScaler,
                                 StaticFleetPolicy)
from repro.serving.scenarios import build_scenario

MIN_SAVINGS = 0.20
MIN_PEAK_REPLICAS = 8
STATIC_CORES = (16, 12, 8)      # the static ladder: 8 replicas x cores
VIOL_TOL = 0.002                # "equal violation rate" tolerance


def _goodput(report, horizon: float) -> float:
    return (report.n_requests - report.n_violations) / max(horizon, 1e-9)


def run(n_requests: int = 500_000, seed: int = 1,
        router: str = "least-loaded") -> list[tuple[str, float, str]]:
    perf = yolov5s_like()
    t0 = time.perf_counter()
    batch, meta = build_scenario("fleet-flash-crowd", requests=n_requests,
                                 seed=seed)
    print(f"fleet-flash-crowd: {len(batch):,} requests generated in "
          f"{time.perf_counter() - t0:.1f} s")
    horizon = float(batch.arrival[-1]) + 60.0
    tick = meta["tick"]
    rps = meta["expected_rps"]
    n0 = meta["n0"]

    # --- joint (n, c, b) sponge fleet ------------------------------------
    scaler = FleetSpongeScaler(perf, adaptation_interval=tick,
                               budget_quantum=0.01, lam_quantum=0.5)
    fleet = FleetFastSimRunner(scaler, perf, DEFAULT_C, DEFAULT_B,
                               n0=n0, c0=meta["c0"], tick=tick,
                               prior_rps=rps, router=router)
    t0 = time.perf_counter()
    rep = fleet.run(batch, horizon, events=meta["fleet_events"])
    wall = time.perf_counter() - t0
    stats = scaler.solver_stats()
    eps = fleet.events_processed / wall
    print(f"sponge-fleet : {rep.n_requests:,} requests, "
          f"{fleet.events_processed:,} events in {wall:.1f} s "
          f"= {eps:,.0f} events/s  (router={router})")
    print(f"               violations={rep.violation_rate*100:.3f}%  "
          f"goodput={_goodput(rep, horizon):,.1f} req/s  "
          f"core_seconds={rep.core_seconds:,.0f}  "
          f"peak_replicas={fleet.max_replicas}")
    print(f"solver cache : hit_rate={stats['hit_rate']*100:.1f}% "
          f"({stats['hits']:,} hits / {stats['misses']:,} grid solves)")

    # --- static-fleet ladder on the same workload ------------------------
    statics = []
    for cores in STATIC_CORES:
        pol = StaticFleetPolicy(perf, replicas=n0, cores=cores,
                                interval=tick, budget_quantum=0.01,
                                lam_quantum=0.5)
        run_static = FleetFastSimRunner(pol, perf, DEFAULT_C, DEFAULT_B,
                                        n0=n0, c0=cores, tick=tick,
                                        prior_rps=rps, router=router)
        r = run_static.run(batch, horizon, events=meta["fleet_events"])
        statics.append((cores, r))
        print(f"{pol.name:13s}: violations={r.violation_rate*100:.3f}%  "
              f"goodput={_goodput(r, horizon):,.1f} req/s  "
              f"core_seconds={r.core_seconds:,.0f}")

    # --- the equal-violation-rate comparison -----------------------------
    eligible = [(c, r) for c, r in statics
                if r.violation_rate <= rep.violation_rate + VIOL_TOL]
    if eligible:
        base_cores, base = min(eligible, key=lambda cr: cr[1].core_seconds)
        basis = "cheapest static fleet at equal-or-better violation rate"
    else:
        # every static fleet violates more than the joint scaler: compare
        # against the largest (the joint scaler wins both axes outright)
        base_cores, base = max(statics, key=lambda cr: cr[1].core_seconds)
        basis = "largest static fleet (all statics violate more)"
    savings = 1.0 - rep.core_seconds / base.core_seconds
    print(f"baseline     : static {n0}x{base_cores} ({basis})")
    print(f"savings      : {savings*100:.1f}% core-seconds "
          f"(bar: >= {MIN_SAVINGS*100:.0f}%)  "
          f"violations {rep.violation_rate*100:.3f}% vs "
          f"{base.violation_rate*100:.3f}%")
    assert len(batch) >= 500_000 or n_requests < 500_000, len(batch)
    assert fleet.max_replicas >= MIN_PEAK_REPLICAS, fleet.max_replicas
    assert rep.violation_rate <= base.violation_rate + VIOL_TOL, \
        (rep.violation_rate, base.violation_rate)
    assert savings >= MIN_SAVINGS, \
        f"only {savings*100:.1f}% core-seconds saved vs static {n0}x{base_cores}"
    return [
        ("fleet_sponge", 1e6 / eps,
         f"events_per_s={eps:.0f};viol={rep.violation_rate:.5f};"
         f"goodput={_goodput(rep, horizon):.1f};"
         f"core_s={rep.core_seconds:.0f};peak_n={fleet.max_replicas};"
         f"hit_rate={stats['hit_rate']:.3f}"),
        ("fleet_static_base", 1e6 / eps,
         f"cores={base_cores};viol={base.violation_rate:.5f};"
         f"core_s={base.core_seconds:.0f};savings={savings:.3f}"),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=500_000)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--router", default="least-loaded")
    args = ap.parse_args(argv)
    run(args.requests, args.seed, args.router)


if __name__ == "__main__":
    main()
