"""Ablation: which of Sponge's three pillars carries the result?

The paper motivates (1) in-place vertical scaling, (2) EDF reordering,
(3) dynamic batching, but only evaluates the full system.  This ablation
removes one pillar at a time, plus the paper's own future-work extension
(joint vertical+horizontal under an overload ramp).
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass

import numpy as np

from repro.core.baselines import SpongePolicy, StaticPolicy

# the multidim ramp ablation deliberately exercises the deprecated
# share-splitting policy (that is the point of the comparison)
with warnings.catch_warnings():
    warnings.simplefilter("ignore", DeprecationWarning)
    # spongelint: disable=deprecation-hygiene -- the ablation compares against the legacy policy
    from repro.core.multidim import MultiDimPolicy
from repro.core.perf_model import yolov5s_like
from repro.core.queueing import EDFQueue
from repro.core.scaler import SpongeScaler
from repro.core.slo import Request
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.network.traces import synth_4g_trace
from repro.serving.api import ScenarioRunner, SimBackend
from repro.serving.workload import WorkloadGenerator


class FIFOQueue(EDFQueue):
    """No-reordering ablation: service order = arrival order (deadlines
    are still tracked for the solver's budget snapshot — only the heap
    ordering key changes)."""

    @staticmethod
    def _key(req: Request) -> float:
        return req.arrival


@dataclass
class FixedBatchSponge(SpongePolicy):
    """No-dynamic-batching ablation: vertical scaling + EDF, b pinned."""
    b_fixed: int = 1
    name: str = "sponge-b1"

    def on_tick(self, now: float, sim) -> None:
        super().on_tick(now, sim)
        sim.set_batch(self.b_fixed)


def _run(perf, policy, reqs, c0=16, fifo=False, rps=20.0):
    sim = ScenarioRunner(policy, SimBackend(perf, DEFAULT_C, DEFAULT_B,
                                            c0=c0))
    if fifo:
        sim.queue = FIFOQueue()
    sim.monitor.rate.prior_rps = rps
    return sim.run(reqs)


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    perf = yolov5s_like()
    trace = synth_4g_trace(600, seed=42)
    # heterogeneous client classes: half tight (0.6 s), half loose (1.6 s)
    # SLOs — the regime where EDF reordering can matter at all (with
    # uniform SLOs FIFO == EDF up to ties)
    wl_tight = WorkloadGenerator(rps=10, slo=0.6, size_kb=100, seed=1)
    wl_loose = WorkloadGenerator(rps=10, slo=1.6, size_kb=400, seed=2)
    mixed = sorted(wl_tight.generate(trace) + wl_loose.generate(trace),
                   key=lambda r: r.arrival)
    rows = []
    print("\n== Ablation: Sponge's three pillars "
          "(2x10 RPS, SLOs 0.6s/1.6s mixed) ==")
    variants = [
        ("full", SpongePolicy(SpongeScaler(perf)), False),
        ("no-EDF (FIFO)", SpongePolicy(SpongeScaler(perf)), True),
        ("no-dyn-batch (b=1)",
         FixedBatchSponge(SpongeScaler(perf, b_set=(1,)),
                          name="sponge-b1"), False),
        ("no-vertical (static-16)", StaticPolicy(perf, cores=16), False),
    ]
    print(f"{'variant':<26} {'viol %':>8} {'avg cores':>10}")
    for name, pol, fifo in variants:
        r = _run(perf, pol, [Request.make(arrival=q.arrival,
                                          comm_latency=q.comm_latency,
                                          slo=q.slo, size_kb=q.size_kb)
                             for q in mixed], fifo=fifo)
        print(f"{name:<26} {r['violation_rate']*100:>8.2f} "
              f"{r['avg_cores']:>10.2f}")
        rows.append((f"ablation_{name.split()[0]}",
                     (time.perf_counter() - t0) * 1e6,
                     f"viol={r['violation_rate']*100:.2f};"
                     f"cores={r['avg_cores']:.2f}"))

    # --- overload ramp: the paper's multidimensional-scaling future work --
    print("\n== Overload ramp (20 -> 60 RPS at t=200): single vs multidim ==")
    reqs = []
    from repro.network.latency import comm_latency
    for t_ in np.arange(0, 600, 1.0):
        rate = 20.0 if t_ < 200 else 60.0
        for i in range(int(rate)):
            ts = t_ + i / rate
            cl = comm_latency(200, trace, ts)
            reqs.append(Request.make(arrival=ts + cl, comm_latency=cl,
                                     slo=1.0))
    single = _run(perf, SpongePolicy(SpongeScaler(perf)),
                  list(reqs), rps=20)
    multi = _run(perf, MultiDimPolicy(SpongeScaler(perf)),
                 list(reqs), rps=20)
    print(f"{'sponge-single':<26} {single['violation_rate']*100:>8.2f} "
          f"{single['avg_cores']:>10.2f}")
    print(f"{'sponge-multidim':<26} {multi['violation_rate']*100:>8.2f} "
          f"{multi['avg_cores']:>10.2f}")
    rows.append(("ablation_ramp_single", (time.perf_counter() - t0) * 1e6,
                 f"viol={single['violation_rate']*100:.2f}"))
    rows.append(("ablation_ramp_multidim", (time.perf_counter() - t0) * 1e6,
                 f"viol={multi['violation_rate']*100:.2f};"
                 f"cores={multi['avg_cores']:.2f}"))
    return rows


if __name__ == "__main__":
    run()
