"""Solver micro-benchmark: faithful Algorithm 1 vs the vectorized exact
solver (same optimum, different asymptotics) across queue sizes."""
from __future__ import annotations

import time

import numpy as np

from repro.core.perf_model import yolov5s_like
from repro.core.solver import solve_bruteforce, solve_pruned


def run() -> list[tuple[str, float, str]]:
    perf = yolov5s_like()
    rng = np.random.default_rng(0)
    rows = []
    print("\n== Solver: Algorithm 1 (bruteforce) vs vectorized ==")
    print(f"{'queue':>6} {'bruteforce us':>14} {'pruned us':>10} "
          f"{'same optimum':>13}")
    for n in (0, 10, 50, 200, 1000):
        rem = np.clip(rng.normal(0.7, 0.2, n), 0.05, 2.0).tolist()
        reps = 20
        t0 = time.perf_counter()
        for _ in range(reps):
            d1 = solve_bruteforce(rem, 20.0, perf)
        t_bf = (time.perf_counter() - t0) / reps * 1e6
        t0 = time.perf_counter()
        for _ in range(reps):
            d2 = solve_pruned(rem, 20.0, perf)
        t_pr = (time.perf_counter() - t0) / reps * 1e6
        same = (d1.c, d1.b, d1.feasible) == (d2.c, d2.b, d2.feasible)
        print(f"{n:>6} {t_bf:>14.0f} {t_pr:>10.0f} {str(same):>13}")
        rows.append((f"solver_bruteforce_q{n}", t_bf,
                     f"c={d1.c};b={d1.b}"))
        rows.append((f"solver_pruned_q{n}", t_pr, f"same={same}"))
    return rows


if __name__ == "__main__":
    run()
