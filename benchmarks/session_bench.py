"""Online-session benchmark: mid-flight renegotiation at 100k+ scale.

Runs the ``slo-renegotiation`` scenario (network telemetry re-keys
queued requests' deadlines — fades tighten, recoveries relax) through
the struct-of-arrays fast engine **via the online session API**
(``repro.serving.session``): the whole workload is submitted through a
live session and tens of thousands of ``update_slo`` ops are applied
between ``step_until`` advances.  The same workload is then replayed
closed-world (submits only) to measure what the renegotiation stream
does to the solver's ``(c, b)`` decision stream and the violation rate.
A ``cancel-storm`` pass exercises the cancellation path (EDF excision +
cancel-aware λ) at the same scale.

Acceptance bars (asserted):

* >= 100,000 requests served through the session per scenario;
* the renegotiated decision stream differs from the closed-world
  replay (tightened budgets must move the solver);
* the cancel storm allocates no more core-seconds than its
  closed-world replay (withdrawn demand must not inflate provisioning).

    PYTHONPATH=src python -m benchmarks.session_bench
    PYTHONPATH=src python benchmarks/session_bench.py --requests 150000
"""
from __future__ import annotations

import argparse
import time

from repro.serving.scenarios import run_scenario

MIN_REQUESTS = 100_000


def _one(name: str, requests: int, seed: int):
    t0 = time.perf_counter()
    rep, stats = run_scenario(name, engine="fast", requests=requests,
                              seed=seed)
    live_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    rep0, _ = run_scenario(name, engine="fast", requests=requests,
                           seed=seed, mid_flight=False)
    plain_s = time.perf_counter() - t0
    return rep, stats, live_s, rep0, plain_s


def run(requests: int = 120_000, seed: int = 11
        ) -> list[tuple[str, float, str]]:
    rows = []

    # --- slo-renegotiation: the headline scenario -------------------------
    rep, stats, live_s, rep0, plain_s = _one("slo-renegotiation",
                                             requests, seed)
    ap = stats["session"]
    eps = stats["events"] / max(stats["run_wall_s"], 1e-9)
    d_live = [(t, d.c, d.b) for t, d in rep.decisions]
    d_plain = [(t, d.c, d.b) for t, d in rep0.decisions]
    n_diff = sum(1 for a, b in zip(d_live, d_plain) if a != b)
    print(f"slo-renegotiation: {rep.n_requests:,} requests, "
          f"{ap['update']:,} mid-flight updates applied "
          f"({ap['noop']:,} raced the dispatcher) in {live_s:.1f} s "
          f"= {eps:,.0f} events/s")
    print(f"  decisions changed vs closed-world replay: {n_diff:,} of "
          f"{len(d_live):,}")
    print(f"  violations: {rep.violation_rate*100:.2f}% (renegotiated) "
          f"vs {rep0.violation_rate*100:.2f}% (frozen budgets)  "
          f"avg_cores {rep.avg_cores:.2f} vs {rep0.avg_cores:.2f}")
    assert rep.n_requests >= MIN_REQUESTS, rep.n_requests
    assert n_diff > 0, "renegotiation must move the (c, b) stream"
    rows.append(("session_renegotiation",
                 stats["run_wall_s"] / max(stats["events"], 1) * 1e6,
                 f"decisions_changed={n_diff}"))

    # --- cancel-storm: the withdrawal path --------------------------------
    rep, stats, live_s, rep0, _ = _one("cancel-storm", requests, seed)
    ap = stats["session"]
    print(f"cancel-storm: {rep.n_requests:,} served + "
          f"{rep.n_cancelled:,} cancelled mid-queue in {live_s:.1f} s")
    print(f"  core-seconds: {rep.core_seconds:,.0f} (storm) vs "
          f"{rep0.core_seconds:,.0f} (no cancels) — withdrawn demand "
          "must not inflate provisioning")
    assert rep.n_requests + rep.n_cancelled >= MIN_REQUESTS
    assert rep.n_cancelled > 0
    assert rep.core_seconds <= rep0.core_seconds + 1e-9
    rows.append(("session_cancel_storm",
                 stats["run_wall_s"] / max(stats["events"], 1) * 1e6,
                 f"cancelled={rep.n_cancelled}"))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=120_000)
    ap.add_argument("--seed", type=int, default=11)
    args = ap.parse_args(argv)
    rows = run(requests=args.requests, seed=args.seed)
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
