"""Fig. 4 reproduction: trace-driven serving study (unified serving API).

Sponge vs FA2-style horizontal autoscaler vs static 8/16-core instances
under a dynamic 4G network, 20 RPS, SLO 1000 ms, 1 s adaptation interval.
Paper claims: Sponge <0.3%% violations, >15x fewer than FA2, >20%% fewer
cores than static-16.  Also reports the TPU-adapted variant where the
feasible c-set is powers of two (submesh degrees, DESIGN.md §2).

Every configuration is one ``make_sim_server`` call — policy, backend and
runner are wired once in ``repro.serving.api``.
"""
from __future__ import annotations

import time

from repro.core.perf_model import yolov5s_like
from repro.core.solver import DEFAULT_B, DEFAULT_C, TPU_C
from repro.network.traces import synth_4g_trace
from repro.serving.api import make_sim_server
from repro.serving.workload import WorkloadGenerator

RPS, SLO, SIZE_KB, DUR, SEED = 20.0, 1.0, 200.0, 600, 42


def _run(perf, policy, trace, c_set=DEFAULT_C, b_set=DEFAULT_B, c0=1):
    wl = WorkloadGenerator(rps=RPS, slo=SLO, size_kb=SIZE_KB)
    server = make_sim_server(perf, policy, c_set=c_set, b_set=b_set, c0=c0,
                             prior_rps=RPS, slo=SLO, expected_rps=RPS)
    return server.serve(wl, trace)


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    perf = yolov5s_like()
    trace = synth_4g_trace(DUR, seed=SEED)
    res = {}
    res["sponge"] = _run(perf, "sponge", trace, c0=16)
    # TPU adaptation: c quantized to submesh degrees; every b in 1..16 has
    # a compiled entry in the executable table (80 executables), so the
    # batch axis stays fine-grained
    res["sponge-tpu"] = _run(perf, "sponge", trace, c_set=TPU_C, c0=16)
    res["fa2"] = _run(perf, "fa2", trace)
    res["static-8"] = _run(perf, "static-8", trace, c0=8)
    res["static-16"] = _run(perf, "static-16", trace, c0=16)
    dt = (time.perf_counter() - t0) * 1e6

    print("\n== Fig 4: SLO violations and allocated cores ==")
    print(f"{'policy':>11} {'viol %':>8} {'avg cores':>10} {'p50 s':>7} "
          f"{'p99 s':>7}")
    for k, v in res.items():
        print(f"{k:>11} {v['violation_rate']*100:>8.2f} "
              f"{v['avg_cores']:>10.2f} {v['p50']:>7.3f} {v['p99']:>7.3f}")
    sp, fa, s16 = res["sponge"], res["fa2"], res["static-16"]
    ratio = fa["violation_rate"] / max(sp["violation_rate"], 1e-9)
    saving = 100 * (1 - sp["avg_cores"] / s16["avg_cores"])
    tpu_sav = 100 * (1 - res["sponge-tpu"]["avg_cores"] / s16["avg_cores"])
    print(f"violation reduction vs FA2: {ratio:.1f}x  (paper: >15x)")
    print(f"core saving vs static-16:   {saving:.1f}%  (paper: >20%)")
    print("TPU power-of-two c-set:     viol "
          f"{res['sponge-tpu']['violation_rate']*100:.2f}%, saving "
          f"{tpu_sav:.1f}% (allocation-quantization cost of the adaptation)")
    return [
        ("fig4_sponge_violation_pct", dt,
         f"{sp['violation_rate']*100:.3f}"),
        ("fig4_fa2_over_sponge_ratio", dt, f"{ratio:.1f}"),
        ("fig4_core_saving_vs_static16_pct", dt, f"{saving:.1f}"),
        ("fig4_sponge_tpu_violation_pct", dt,
         f"{res['sponge-tpu']['violation_rate']*100:.3f}"),
    ]


if __name__ == "__main__":
    run()
