"""Fig. 1 reproduction: 4G bandwidth variability and the remaining
server-side SLO for 100/200/500 KB payloads."""
from __future__ import annotations

import time

import numpy as np

from repro.network.latency import comm_latency
from repro.network.traces import synth_4g_trace

SLO = 1.0


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    trace = synth_4g_trace(600, seed=42)
    rows = []
    print("\n== Fig 1: bandwidth + remaining SLO (SLO=1000ms) ==")
    print(f"bandwidth: min={trace.mbps.min():.2f} max={trace.mbps.max():.2f} "
          f"mean={trace.mbps.mean():.2f} MB/s (paper: 0.5-7 MB/s)")
    for kb in (100, 200, 500):
        cls = np.array([comm_latency(kb, trace, t)
                        for t in range(int(trace.duration))])
        rem = SLO - cls
        print(f"{kb:4d}KB: comm latency mean={cls.mean()*1e3:.0f}ms "
              f"p99={np.percentile(cls,99)*1e3:.0f}ms -> remaining SLO "
              f"min={rem.min()*1e3:.0f}ms mean={rem.mean()*1e3:.0f}ms")
        rows.append((f"fig1_remaining_slo_{kb}kb",
                     (time.perf_counter() - t0) * 1e6,
                     f"min_ms={rem.min()*1e3:.0f};mean_ms={rem.mean()*1e3:.0f}"))
    assert trace.mbps.min() >= 0.4 and trace.mbps.max() <= 7.2
    return rows


if __name__ == "__main__":
    run()
