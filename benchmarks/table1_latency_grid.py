"""Table 1 reproduction: execution latency / throughput / total cores of the
profiled model across (cores, batch) while guaranteeing a 1000 ms SLO under
100 RPS — the paper's motivating example."""
from __future__ import annotations

import time

import numpy as np

from repro.core.perf_model import TABLE1_SAMPLES, fit_table1

SLO = 1.0
RPS = 100.0


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    pm = fit_table1()
    rows = []
    print("\n== Table 1: latency(b,c) of the ResNet human detector ==")
    print(f"(model fit on the paper's measured points: r2={pm.r2:.3f}, "
          f"rmse={pm.rmse*1e3:.2f}ms)")
    print(f"{'cores':>6} {'batch':>6} {'lat ms (paper)':>15} "
          f"{'lat ms (fit)':>13} {'thr/inst':>9} {'inst':>5} {'total':>6}")
    for b, c, l_paper in TABLE1_SAMPLES:
        l_fit = float(pm.latency(b, c))
        thr = b / l_fit
        n_inst = int(np.ceil(RPS / thr))
        print(f"{int(c):>6} {int(b):>6} {l_paper*1e3:>15.0f} "
              f"{l_fit*1e3:>13.1f} {thr:>9.1f} {n_inst:>5} "
              f"{int(c)*n_inst:>6}")
    # paper's §2.1 claim: with 600ms network delay, (c=8, b=4) still works
    rem = [0.4] * 16
    from repro.core.solver import solve_bruteforce
    d = solve_bruteforce(rem, RPS, pm)
    dt = (time.perf_counter() - t0) * 1e6
    print(f"600ms-delay scenario -> solver picks c={d.c}, b={d.b} "
          f"(feasible={d.feasible}; paper: 8 cores, batch 4)")
    rows.append(("table1_fit_r2", dt, f"{pm.r2:.4f}"))
    rows.append(("table1_600ms_solution", dt,
                 f"c={d.c};b={d.b};feasible={d.feasible}"))
    return rows


if __name__ == "__main__":
    run()
