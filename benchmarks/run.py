"""Benchmark harness: one entry per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV at the end (per harness contract).

    PYTHONPATH=src python -m benchmarks.run [--only fig4]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (ablation_bench, fig1_dynamic_slo, fig3_perf_model,
                        fig4_e2e, fleet_bench, perf_iter, predictive_bench,
                        roofline_report, session_bench, smoke, solver_bench,
                        table1_latency_grid, throughput_bench,
                        token_serving_bench)

BENCHES = [
    ("smoke", smoke),
    ("table1", table1_latency_grid),
    ("fig1", fig1_dynamic_slo),
    ("fig3", fig3_perf_model),
    ("fig4", fig4_e2e),
    ("solver", solver_bench),
    ("roofline", roofline_report),
    ("predictive", predictive_bench),
    ("perf", perf_iter),
    ("ablation", ablation_bench),
    # control-plane throughput: the 1M-request scenario through the fast
    # engine vs the pre-refactor loop (see benchmarks/throughput_bench.py)
    ("throughput", throughput_bench),
    # autoregressive serving: 100k-request continuous batching + the
    # real-kernel TokenJaxBackend slice (benchmarks/token_serving_bench.py)
    ("token", token_serving_bench),
    # fleet serving: 500k requests across >=8 replicas, joint (n, c, b)
    # scaling vs a static fleet (benchmarks/fleet_bench.py)
    ("fleet", fleet_bench),
    # online sessions: 100k+ requests with mid-flight SLO renegotiation
    # and cancel storms via the session API (benchmarks/session_bench.py)
    ("session", session_bench),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args(argv)
    rows = []
    failed = []
    for name, mod in BENCHES:
        if args.only and args.only != name:
            continue
        try:
            rows.extend(mod.run())
        except Exception as e:
            traceback.print_exc()
            failed.append((name, repr(e)))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
