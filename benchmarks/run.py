"""Benchmark harness: one entry per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV at the end (per harness contract).

Bench modules are imported lazily: an entry whose module cannot be
imported (an optional engine dependency missing from the environment,
e.g. JAX on a CPU-only box) is **skipped with a reason** instead of
taking the whole sweep down — ``make``-driven sweeps survive partial
environments.  A bench that imports but *fails to run* still fails the
harness; only missing dependencies downgrade to skips.

Every completed bench run is appended to ``BENCH_<name>.json`` at the
repo root via :func:`record_bench` — an append-mode trajectory of
``{timestamp, commit, metrics}`` entries, so bench numbers are tracked
across commits instead of asserted ad hoc.  Benches may also call
:func:`record_bench` themselves with richer metrics (set module attr
``RECORDS_OWN = True`` to suppress the harness's automatic entry).

    PYTHONPATH=src python -m benchmarks.run [--only fig4]
"""
from __future__ import annotations

import argparse
import importlib
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]

BENCHES = [
    ("smoke", "benchmarks.smoke"),
    ("table1", "benchmarks.table1_latency_grid"),
    ("fig1", "benchmarks.fig1_dynamic_slo"),
    ("fig3", "benchmarks.fig3_perf_model"),
    ("fig4", "benchmarks.fig4_e2e"),
    ("solver", "benchmarks.solver_bench"),
    ("roofline", "benchmarks.roofline_report"),
    ("predictive", "benchmarks.predictive_bench"),
    ("perf", "benchmarks.perf_iter"),
    ("ablation", "benchmarks.ablation_bench"),
    # control-plane throughput: the 1M-request scenario through the fast
    # engine vs the pre-refactor loop (see benchmarks/throughput_bench.py)
    ("throughput", "benchmarks.throughput_bench"),
    # autoregressive serving: 100k-request continuous batching + the
    # real-kernel TokenJaxBackend slice (benchmarks/token_serving_bench.py)
    ("token", "benchmarks.token_serving_bench"),
    # fleet serving: 500k requests across >=8 replicas, joint (n, c, b)
    # scaling vs a static fleet (benchmarks/fleet_bench.py)
    ("fleet", "benchmarks.fleet_bench"),
    # online sessions: 100k+ requests with mid-flight SLO renegotiation
    # and cancel storms via the session API (benchmarks/session_bench.py)
    ("session", "benchmarks.session_bench"),
    # multi-tenant pool: >=200k requests over 3 heterogeneous tenants on
    # a 128-core pool vs static partitions (benchmarks/tenant_bench.py)
    ("tenant", "benchmarks.tenant_bench"),
    # distribution-aware admission: quantile planning + cancel-on-overrun
    # vs the deterministic-cost scaler on heavy-tailed decode lengths
    # (benchmarks/uncertainty_bench.py)
    ("uncertainty", "benchmarks.uncertainty_bench"),
    # accuracy degradation: the (m, n, c, b) planner vs fixed-model
    # fleets on the degrade-under-pressure family
    # (benchmarks/degrade_bench.py)
    ("degrade", "benchmarks.degrade_bench"),
]


def _git_commit() -> str:
    """Best-effort short commit hash for trajectory entries."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:                                # pragma: no cover
        return "unknown"


def record_bench(name: str, metrics, *, path: Path = None) -> Path:
    """Append one ``{timestamp, commit, metrics}`` entry to
    ``BENCH_<name>.json`` (created as a JSON list on first use).

    ``metrics`` is any JSON-serializable value — the harness passes the
    CSV rows; benches with richer results (e.g. ``tenant_bench``'s
    pooled-vs-static comparison) pass their own dict.  Returns the file
    path.  The file stays a valid JSON array across appends so the
    trajectory is trivially loadable.
    """
    out = path or REPO_ROOT / f"BENCH_{name}.json"
    entries = []
    if out.exists():
        try:
            entries = json.loads(out.read_text())
            if not isinstance(entries, list):        # pragma: no cover
                entries = [entries]
        except Exception:                            # pragma: no cover
            entries = []
    entries.append({"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
                    "unix_time": round(time.time(), 3),
                    "commit": _git_commit(), "metrics": metrics})
    out.write_text(json.dumps(entries, indent=1, default=float) + "\n")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--no-record", action="store_true",
                    help="skip the BENCH_<name>.json trajectory append")
    args = ap.parse_args(argv)
    rows = []
    failed = []
    skipped = []
    for name, modpath in BENCHES:
        if args.only and args.only != name:
            continue
        try:
            mod = importlib.import_module(modpath)
        except ImportError as e:
            # optional engine dependency absent: degrade to a skip
            skipped.append((name, f"import failed: {e}"))
            print(f"SKIP {name}: {e}", file=sys.stderr)
            continue
        try:
            bench_rows = list(mod.run())
        except Exception as e:
            traceback.print_exc()
            failed.append((name, repr(e)))
            continue
        rows.extend(bench_rows)
        if not args.no_record and not getattr(mod, "RECORDS_OWN", False):
            record_bench(name, [list(r) for r in bench_rows])
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if skipped:
        print(f"SKIPPED benches: {skipped}", file=sys.stderr)
    if failed:
        print(f"FAILED benches: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
