"""Fast end-to-end smoke of the unified serving API (<30 s).

Exercises ``ScenarioRunner`` on BOTH execution backends:

* ``SimBackend``  — sponge + fa2 on a 60 s 4G trace (Fig. 4 in miniature);
* ``JaxBackend``  — a real jitted executable table (toy tanh step),
  measured clock, plus the FA2-style multi-instance live path.

    PYTHONPATH=src python benchmarks/smoke.py
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.perf_model import PerfModel, yolov5s_like
from repro.core.slo import Request
from repro.network.traces import synth_4g_trace
from repro.serving.api import (JaxBackend, SpongeServer, make_policy,
                               make_sim_server, pad_vectors, toy_step_fns)
from repro.serving.workload import WorkloadGenerator

DIM = 16
C_SET = B_SET = (1, 2, 4)


def _live_script(n, rps, dim=DIM, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        ts = i / rps
        cl = float(rng.uniform(0.02, 0.2))
        out.append((Request.make(arrival=ts + cl, comm_latency=cl, slo=0.8),
                    rng.standard_normal(dim).astype(np.float32)))
    return out


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    rows = []

    # --- sim backend ------------------------------------------------------
    perf = yolov5s_like()
    trace = synth_4g_trace(60, seed=3)
    wl = WorkloadGenerator(rps=20, slo=1.0, size_kb=200)
    for name, c0 in (("sponge", 16), ("fa2", 1)):
        server = make_sim_server(perf, name, c0=c0, prior_rps=20,
                                 slo=1.0, expected_rps=20)
        r = server.serve(wl, trace)
        assert r.n_requests >= 1100 and not len(server.queue), \
            (r.n_requests, len(server.queue))
        rows.append((f"smoke_sim_{name}", (time.perf_counter() - t0) * 1e6,
                     f"viol={r.violation_rate*100:.2f};"
                     f"cores={r.avg_cores:.2f}"))

    # --- jax backend (real execution, measured clock) ---------------------
    lperf = PerfModel(gamma=0.030, eps=0.010, delta=0.002, eta=0.004)
    fns = toy_step_fns(C_SET, B_SET, dim=DIM)
    for name, prior in (("sponge", 15.0), ("fa2", 40.0)):
        pol = make_policy(name, lperf, c_set=C_SET, b_set=B_SET,
                          adaptation_interval=0.5, slo=0.8,
                          expected_rps=prior, **(
                              {"cold_start": 0.5, "reconfig_interval": 1.0}
                              if name == "fa2" else {}))
        server = SpongeServer(pol, JaxBackend(fns, pad_vectors, lperf,
                                              clock="measured", c0=1),
                              tick=0.5, prior_rps=prior)
        n = 60 if name == "sponge" else 80
        r = server.run(_live_script(n, prior), horizon=8.0)
        assert r.n_requests == n, (name, r.n_requests)
        assert all(it.result is not None for it in server.backend.results)
        rows.append((f"smoke_jax_{name}", (time.perf_counter() - t0) * 1e6,
                     f"viol={r.violation_rate*100:.2f};"
                     "max_replicas="
                     f"{max(c for _, c in r.core_timeline)}"))

    dt = time.perf_counter() - t0
    print("\n== smoke: ScenarioRunner on sim + jax backends "
          f"({dt:.1f} s) ==")
    for name, _, derived in rows:
        print(f"  {name:18s} {derived}")
    assert dt < 30.0, f"smoke exceeded 30 s budget: {dt:.1f}"
    return rows


if __name__ == "__main__":
    run()
