"""Degradation benchmark: the (m, n, c, b) planner vs fixed-model fleets.

Runs the three ``degrade-under-pressure`` scenarios (sustained overload,
flash crowd beyond top-rung capacity, a network fade that tightens
deadlines below the top rung's single-item latency) through the fast
fleet engine twice over: once with the full-ladder
:class:`~repro.serving.fleet.DegradingFleetScaler` (accuracy floor 0.60)
and once per **fixed** ladder rung (``policy="fixed-<arch>"`` — the same
scaler/runner machinery pinned to a one-rung ladder, so every baseline
report carries accuracy-weighted goodput and the comparison is like for
like).

The acceptance bar (ISSUE 9), per scenario *and* in aggregate:

* the planner beats the **top rung** (what a no-degradation deployment
  must provision) on accuracy-weighted goodput at **equal-or-lower
  core-seconds** — headline ``acc_goodput_gain=<x>x`` per row;
* the planner's aggregate accuracy-weighted goodput is within
  ``ORACLE_TOL`` of the best fixed rung chosen *in hindsight* per
  scenario — the planner cannot know the adverse window's shape in
  advance, so the oracle bound is a ratio, not a strict win;
* at least one scenario actually exercises the ladder (swaps > 0).

    PYTHONPATH=src python -m benchmarks.degrade_bench
    PYTHONPATH=src python benchmarks/degrade_bench.py --duration 120
"""
from __future__ import annotations

import argparse
import time

from repro.core.degradation import DEFAULT_LADDER_ARCHS
from repro.serving.scenarios import run_scenario

SCENARIOS = ("degrade-sustained-overload", "degrade-flash-overload",
             "degrade-fade-overload")
# accuracy-descending; [0] is the top rung the headline compares against
RUNGS = tuple(sorted(
    DEFAULT_LADDER_ARCHS, key=lambda a: a != "gemma-2b"))
TOP_RUNG = "gemma-2b"
ORACLE_TOL = 0.95       # aggregate planner agp >= 95% of the hindsight
                        # best fixed rung (currently it wins outright)


def _one(scenario: str, policy: str, duration: float, seed: int):
    t0 = time.perf_counter()
    rep, stats = run_scenario(scenario, policy=policy, engine="fast",
                              duration=duration, seed=seed)
    wall = time.perf_counter() - t0
    return rep, stats["events"] / max(wall, 1e-9)


def run(duration: float = 300.0, seed: int = 7
        ) -> list[tuple[str, float, str]]:
    rows = []
    agg = {p: [0.0, 0.0] for p in ("sponge", *RUNGS)}   # [agp, core_s]
    total_swaps = 0
    for scenario in SCENARIOS:
        short = scenario[len("degrade-"):]
        rep, eps = _one(scenario, "sponge", duration, seed)
        agp, cs = rep.accuracy_goodput * duration, rep.core_seconds
        agg["sponge"][0] += agp
        agg["sponge"][1] += cs
        total_swaps += rep.model_swaps
        print(f"{short:22s} sponge-degrade    "
              f"viol={rep.violation_rate*100:6.2f}%  agp={agp:10.1f}  "
              f"macc={rep.mean_served_accuracy:.3f}  "
              f"swaps={rep.model_swaps:2d}  core_s={cs:9.0f}")
        fixed = {}
        for arch in RUNGS:
            r, _ = _one(scenario, f"fixed-{arch}", duration, seed)
            fixed[arch] = (r.accuracy_goodput * duration, r.core_seconds)
            agg[arch][0] += fixed[arch][0]
            agg[arch][1] += fixed[arch][1]
            print(f"{short:22s} fixed-{arch:12s} "
                  f"viol={r.violation_rate*100:6.2f}%  "
                  f"agp={fixed[arch][0]:10.1f}  "
                  f"macc={r.mean_served_accuracy:.3f}  "
                  f"core_s={fixed[arch][1]:9.0f}")
        top_agp, top_cs = fixed[TOP_RUNG]
        gain = agp / max(top_agp, 1e-9)
        # the per-scenario bar: beat the top rung on accuracy-weighted
        # goodput without spending more cores than it does
        assert agp > top_agp, (scenario, agp, top_agp)
        assert cs <= top_cs, (scenario, cs, top_cs)
        rows.append((f"degrade_{short}", 1e6 / eps,
                     f"acc_goodput_gain={gain:.2f}x;agp={agp:.1f};"
                     f"viol={rep.violation_rate:.5f};"
                     f"macc={rep.mean_served_accuracy:.3f};"
                     f"swaps={rep.model_swaps};core_s={cs:.0f};"
                     f"top_core_s={top_cs:.0f}"))

    sp_agp, sp_cs = agg["sponge"]
    top_agp, top_cs = agg[TOP_RUNG]
    best_arch = max(RUNGS, key=lambda a: agg[a][0])
    best_agp, best_cs = agg[best_arch]
    gain = sp_agp / max(top_agp, 1e-9)
    oracle_ratio = sp_agp / max(best_agp, 1e-9)
    print(f"TOTAL sponge-degrade  agp={sp_agp:10.1f}  core_s={sp_cs:9.0f}")
    print(f"TOTAL top rung        agp={top_agp:10.1f}  core_s={top_cs:9.0f}"
          f"  (gain {gain:.2f}x)")
    print(f"TOTAL hindsight best  fixed-{best_arch}  agp={best_agp:10.1f}"
          f"  core_s={best_cs:9.0f}  (planner at {oracle_ratio:.3f}x)")
    assert sp_agp > top_agp and sp_cs <= top_cs, \
        (sp_agp, top_agp, sp_cs, top_cs)
    assert oracle_ratio >= ORACLE_TOL, \
        f"planner at {oracle_ratio:.3f}x of fixed-{best_arch} " \
        f"(bar: >= {ORACLE_TOL})"
    assert total_swaps > 0, "no scenario exercised a model swap"
    rows.append(("degrade_total", rows[-1][1],
                 f"acc_goodput_gain={gain:.2f}x;agp={sp_agp:.1f};"
                 f"core_s={sp_cs:.0f};top_core_s={top_cs:.0f};"
                 f"oracle_ratio={oracle_ratio:.3f};"
                 f"oracle=fixed-{best_arch}"))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=300.0)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)
    run(args.duration, args.seed)


if __name__ == "__main__":
    main()
