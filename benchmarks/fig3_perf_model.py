"""Fig. 3 reproduction: latency vs (cores, batch) — real (measured/profiled)
vs predicted by the Eq. 2 model, for two DL models.

Two profiling sources:
* ResNet18-class: the paper's Table 1 measured points;
* YOLOv5n-class: noisy synthetic profile (5% noise + 10% outliers) to
  exercise the RANSAC robust regression the paper cites;
* (bonus, TPU adaptation) smollm-135m: real measured jitted forward passes
  on this container at varying batch, validating the fitting machinery on
  actual hardware measurements.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.perf_model import PerfModel, fit_table1


def run() -> list[tuple[str, float, str]]:
    rows = []
    t0 = time.perf_counter()
    print("\n== Fig 3: perf-model fit quality ==")

    pm1 = fit_table1()
    print(f"resnet18-class (paper Table 1 points): r2={pm1.r2:.3f} "
          f"rmse={pm1.rmse*1e3:.2f}ms")
    rows.append(("fig3_resnet18_r2", (time.perf_counter()-t0)*1e6,
                 f"{pm1.r2:.4f}"))

    truth = PerfModel(gamma=0.020, eps=0.008, delta=0.0018, eta=0.004)
    prof = truth.sample_profile(range(1, 17), (1, 2, 4, 8, 16),
                                noise=0.05, outlier_frac=0.10, seed=5)
    fit = PerfModel.fit(prof, robust=True, seed=0)
    bs, cs = np.meshgrid(np.arange(1, 17), np.array([1, 2, 4, 8, 16]))
    rel = np.abs(fit.latency(bs, cs) - truth.latency(bs, cs)) \
        / truth.latency(bs, cs)
    print("yolov5n-class (noisy profile + outliers, RANSAC): "
          f"r2={fit.r2:.3f} mean_rel_err={rel.mean()*100:.1f}%")
    rows.append(("fig3_yolov5n_relerr_pct", (time.perf_counter()-t0)*1e6,
                 f"{rel.mean()*100:.2f}"))

    # real measured samples on this container (batch scaling only; the
    # c-axis on TPU is the submesh degree, exercised in the dry-run)
    try:
        import jax
        from repro.configs import get_config
        from repro.models import build_model
        cfg = get_config("smollm-135m", reduced=True)
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        fwd = jax.jit(lambda p, t: m.forward(p, {"tokens": t})[0])
        samples = []
        for b in (1, 2, 4, 8, 16):
            x = np.ones((b, 32), np.int32)
            fwd(params, x)
            t1 = time.perf_counter()
            for _ in range(3):
                jax.block_until_ready(fwd(params, x))
            samples.append((b, 1, (time.perf_counter() - t1) / 3))
        # fit the batch-linear part (c fixed): l = alpha*b + beta
        bs_ = np.array([s[0] for s in samples], float)
        ls_ = np.array([s[2] for s in samples], float)
        A = np.stack([bs_, np.ones_like(bs_)], 1)
        coef, res, *_ = np.linalg.lstsq(A, ls_, rcond=None)
        pred = A @ coef
        r2 = 1 - ((ls_ - pred) ** 2).sum() / ((ls_ - ls_.mean()) ** 2).sum()
        print("measured smollm-135m-reduced forward (CPU): linear "
              f"batch->latency r2={r2:.3f} "
              f"(alpha={coef[0]*1e3:.2f}ms/item, beta={coef[1]*1e3:.2f}ms)")
        rows.append(("fig3_measured_linear_r2",
                     (time.perf_counter()-t0)*1e6, f"{r2:.4f}"))
    except Exception as e:  # pragma: no cover
        print("measured profile skipped:", e)
    return rows


if __name__ == "__main__":
    run()
