"""Control-plane throughput: the million-request scenario benchmark.

Runs the ``steady`` scenario (Fig. 4's workload continued to scale) at
1,000,000 requests through the struct-of-arrays fast engine
(``FastSimRunner`` + memoized solver) and measures control-plane
events/second, then replays a true prefix of the *same* workload through
the verbatim pre-refactor loop (``repro.serving.reference``) to report
the speedup ratio.  The acceptance bar is >= 10x; the equivalence tests
in ``tests/test_fastpath.py`` separately prove the fast engine
decision-identical to the baseline, so the ratio compares equal work.

Also reported: the memoized solver's cache hit rate — the fraction of
``decide()`` calls answered by a table lookup instead of a grid solve.

    PYTHONPATH=src python -m benchmarks.throughput_bench
    PYTHONPATH=src python benchmarks/throughput_bench.py --requests 200000
"""
from __future__ import annotations

import argparse
import time

from repro.core.baselines import SpongePolicy
from repro.core.perf_model import yolov5s_like
from repro.core.scaler import SpongeScaler
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.serving.api import SimBackend
from repro.serving.fastpath import FastSimRunner
from repro.serving.reference import ReferenceRunner
from repro.serving.scenarios import build_scenario

MIN_SPEEDUP = 10.0


def run(n_requests: int = 1_000_000,
        baseline_requests: int = 20_000,
        seed: int = 1) -> list[tuple[str, float, str]]:
    perf = yolov5s_like()
    t0 = time.perf_counter()
    batch, meta = build_scenario("steady", requests=n_requests, seed=seed)
    gen_s = time.perf_counter() - t0
    rps = meta["rps"]
    print(f"steady scenario: {len(batch):,} requests generated in "
          f"{gen_s:.1f} s (vectorized)")

    # --- fast engine over the full trace ---------------------------------
    scaler = SpongeScaler(perf, solver="memo",
                          budget_quantum=0.01, lam_quantum=0.5)
    fast = FastSimRunner(SpongePolicy(scaler), perf, DEFAULT_C, DEFAULT_B,
                         c0=16, prior_rps=rps)
    t0 = time.perf_counter()
    rep = fast.run(batch)
    fast_s = time.perf_counter() - t0
    fast_eps = fast.events_processed / fast_s
    stats = scaler.solver_stats()
    print(f"fast engine : {rep.n_requests:,} requests, "
          f"{fast.events_processed:,} events in {fast_s:.1f} s "
          f"= {fast_eps:,.0f} events/s")
    print(f"              violations={rep.violation_rate*100:.3f}%  "
          f"avg_cores={rep.avg_cores:.2f}")
    print(f"solver cache: hit_rate={stats['hit_rate']*100:.1f}% "
          f"({stats['hits']:,} hits / {stats['misses']:,} grid solves)")

    # --- pre-refactor baseline on a prefix of the same workload ----------
    prefix = batch.head(baseline_requests)
    ref = ReferenceRunner(SpongePolicy(SpongeScaler(perf)),
                          SimBackend(perf, DEFAULT_C, DEFAULT_B, c0=16))
    ref.monitor.rate.prior_rps = rps
    reqs = prefix.to_requests()
    t0 = time.perf_counter()
    ref.run(reqs)
    ref_s = time.perf_counter() - t0
    ref_eps = ref.events_processed / ref_s
    ratio = fast_eps / ref_eps
    print(f"pre-refactor: {len(prefix):,}-request prefix, "
          f"{ref.events_processed:,} events in {ref_s:.1f} s "
          f"= {ref_eps:,.0f} events/s")
    print(f"speedup     : {ratio:.1f}x control-plane events/s "
          f"(bar: >= {MIN_SPEEDUP:.0f}x)")
    assert ratio >= MIN_SPEEDUP, \
        f"fast engine only {ratio:.1f}x over the pre-refactor runner"
    return [
        ("throughput_fast", 1e6 / fast_eps,
         f"events_per_s={fast_eps:.0f};hit_rate={stats['hit_rate']:.3f};"
         f"viol={rep.violation_rate:.5f}"),
        ("throughput_baseline", 1e6 / ref_eps,
         f"events_per_s={ref_eps:.0f};speedup={ratio:.1f}x"),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=1_000_000)
    ap.add_argument("--baseline-requests", type=int, default=20_000)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args(argv)
    run(args.requests, args.baseline_requests, args.seed)


if __name__ == "__main__":
    main()
