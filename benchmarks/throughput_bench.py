"""Control-plane throughput: the 10M-request scenario benchmark.

Three legs over the *same* ``steady`` workload (Fig. 4's trace continued
to scale), slowest to fastest:

* **pre-refactor loop** (``repro.serving.reference``) on a 10k-request
  prefix — the verbatim paper loop, the denominator of every speedup;
* **fast engine** (``FastSimRunner`` + memoized solver) on a 1M-request
  prefix — the struct-of-arrays event loop, bar >= 10x;
* **vectorpath** (``VectorSimRunner``, ISSUE 8) on the full 10M-request
  trace — each inter-decision window processed as array ops (batched
  arrival ingestion, cumulative-capacity dispatch, tick-granular
  λ windows), bar >= **100x**.

All three legs run a **50 ms control cadence** (``tick=0.05`` with
``adaptation_interval=0.05``): Sponge targets sub-second SLOs, so the
scaler must re-decide at a fraction of the tightest deadline — the
regime the batched tick train exists for.  In the pre-refactor loop
each of those ticks is a full bruteforce grid solve (~2.3 ms), so the
cadence is also what makes the baseline honestly slow rather than
artificially idle.

The equivalence suites (``tests/test_fastpath.py``,
``tests/test_determinism.py``, ``tests/test_vectorpath.py``) separately
prove all three engines decision-identical on shared workloads, so the
ratios compare equal work.  Events/s counts each engine's own event
convention; the vectorpath counts arrivals + ticks + batch launches
(it has no wake-poke events), which *understates* its ratio.

Rows are recorded to ``BENCH_throughput.json`` via
``benchmarks.run.record_bench`` (``RECORDS_OWN``), and
``tools/bench_gate.py`` enforces the 10x/100x floors on the recorded
``speedup=`` figures.

    PYTHONPATH=src python -m benchmarks.throughput_bench
    PYTHONPATH=src python benchmarks/throughput_bench.py --requests 200000
"""
from __future__ import annotations

import argparse
import time

from repro.core.baselines import SpongePolicy
from repro.core.perf_model import yolov5s_like
from repro.core.scaler import SpongeScaler
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.serving.api import SimBackend
from repro.serving.fastpath import FastSimRunner
from repro.serving.reference import ReferenceRunner
from repro.serving.scenarios import build_scenario
from repro.serving.vectorpath import VectorSimRunner

MIN_SPEEDUP = 10.0          # fast engine vs pre-refactor loop
MIN_VECTOR_SPEEDUP = 100.0  # vectorpath vs pre-refactor loop (ISSUE 8)
TICK = 0.05                 # 50 ms control cadence on every leg
RECORDS_OWN = True          # we append richer rows ourselves


def _policy(perf):
    scaler = SpongeScaler(perf, solver="memo", adaptation_interval=TICK,
                          budget_quantum=0.01, lam_quantum=0.5)
    return SpongePolicy(scaler), scaler


def run(n_requests: int = 10_000_000,
        fast_requests: int = 1_000_000,
        baseline_requests: int = 10_000,
        seed: int = 1,
        record: bool = True) -> list[tuple[str, float, str]]:
    perf = yolov5s_like()
    t0 = time.perf_counter()
    batch, meta = build_scenario("steady", requests=n_requests, seed=seed)
    gen_s = time.perf_counter() - t0
    rps = meta["rps"]
    print(f"steady scenario: {len(batch):,} requests generated in "
          f"{gen_s:.1f} s (vectorized)")

    # --- pre-refactor baseline on a prefix of the same workload ----------
    prefix = batch.head(baseline_requests)
    ref = ReferenceRunner(
        SpongePolicy(SpongeScaler(perf, adaptation_interval=TICK)),
        SimBackend(perf, DEFAULT_C, DEFAULT_B, c0=16), tick=TICK)
    ref.monitor.rate.prior_rps = rps
    reqs = prefix.to_requests()
    t0 = time.perf_counter()
    ref.run(reqs)
    ref_s = time.perf_counter() - t0
    ref_eps = ref.events_processed / ref_s
    print(f"pre-refactor: {len(prefix):,}-request prefix, "
          f"{ref.events_processed:,} events in {ref_s:.1f} s "
          f"= {ref_eps:,.0f} events/s")

    # --- fast engine on a 1M-request prefix ------------------------------
    fast_prefix = batch.head(fast_requests)
    pol, scaler = _policy(perf)
    fast = FastSimRunner(pol, perf, DEFAULT_C, DEFAULT_B,
                         c0=16, tick=TICK, prior_rps=rps)
    t0 = time.perf_counter()
    rep_f = fast.run(fast_prefix)
    fast_s = time.perf_counter() - t0
    fast_eps = fast.events_processed / fast_s
    stats = scaler.solver_stats()
    ratio_fast = fast_eps / ref_eps
    print(f"fast engine : {rep_f.n_requests:,} requests, "
          f"{fast.events_processed:,} events in {fast_s:.1f} s "
          f"= {fast_eps:,.0f} events/s ({ratio_fast:.1f}x)")
    print(f"              violations={rep_f.violation_rate*100:.3f}%  "
          f"avg_cores={rep_f.avg_cores:.2f}")
    print(f"solver cache: hit_rate={stats['hit_rate']*100:.1f}% "
          f"({stats['hits']:,} hits / {stats['misses']:,} grid solves)")

    # --- vectorpath over the full trace ----------------------------------
    pol_v, scaler_v = _policy(perf)
    vec = VectorSimRunner(pol_v, perf, DEFAULT_C, DEFAULT_B,
                          c0=16, tick=TICK, prior_rps=rps)
    t0 = time.perf_counter()
    rep_v = vec.run(batch)
    vec_s = time.perf_counter() - t0
    vec_eps = vec.events_processed / vec_s
    ratio_vec = vec_eps / ref_eps
    print(f"vectorpath  : {rep_v.n_requests:,} requests, "
          f"{vec.events_processed:,} events in {vec_s:.1f} s "
          f"= {vec_eps:,.0f} events/s ({ratio_vec:.1f}x)")
    print(f"              violations={rep_v.violation_rate*100:.3f}%  "
          f"avg_cores={rep_v.avg_cores:.2f}")
    print(f"speedups    : fast {ratio_fast:.1f}x (bar >= "
          f"{MIN_SPEEDUP:.0f}x), vector {ratio_vec:.1f}x (bar >= "
          f"{MIN_VECTOR_SPEEDUP:.0f}x)")
    assert ratio_fast >= MIN_SPEEDUP, \
        f"fast engine only {ratio_fast:.1f}x over the pre-refactor runner"
    assert ratio_vec >= MIN_VECTOR_SPEEDUP, \
        f"vectorpath only {ratio_vec:.1f}x over the pre-refactor runner"
    rows = [
        ("throughput_fast", 1e6 / fast_eps,
         f"events_per_s={fast_eps:.0f};hit_rate={stats['hit_rate']:.3f};"
         f"viol={rep_f.violation_rate:.5f};speedup={ratio_fast:.1f}x"),
        ("throughput_vector", 1e6 / vec_eps,
         f"events_per_s={vec_eps:.0f};requests={len(batch)};"
         f"viol={rep_v.violation_rate:.5f};speedup={ratio_vec:.1f}x"),
        ("throughput_baseline", 1e6 / ref_eps,
         f"events_per_s={ref_eps:.0f}"),
    ]
    if record:
        from benchmarks.run import record_bench
        record_bench("throughput", [list(r) for r in rows])
    return rows


SMOKE_FLOOR_EPS = 20_000.0  # absolute floor for `make perf-smoke`


def smoke(n_requests: int = 200_000, seed: int = 1,
          floor: float = SMOKE_FLOOR_EPS) -> float:
    """CI-sized vectorpath-only run with an **absolute** events/s floor.

    No reference leg, no recording: the full ratio bench is minutes of
    single-core work, but an accidentally de-vectorized hot path (a
    per-arrival Python loop sneaking back in) drops the vectorpath to
    low-thousands events/s — an order of magnitude under the floor on
    any hardware CI plausibly runs on, while the real engine clears it
    by >5x even on shared runners."""
    perf = yolov5s_like()
    batch, meta = build_scenario("steady", requests=n_requests, seed=seed)
    pol, _ = _policy(perf)
    vec = VectorSimRunner(pol, perf, DEFAULT_C, DEFAULT_B,
                          c0=16, tick=TICK, prior_rps=meta["rps"])
    t0 = time.perf_counter()
    vec.run(batch)
    wall = time.perf_counter() - t0
    eps = vec.events_processed / wall
    print(f"perf-smoke  : {n_requests:,} requests, "
          f"{vec.events_processed:,} events in {wall:.1f} s "
          f"= {eps:,.0f} events/s (floor {floor:,.0f})")
    assert eps >= floor, \
        f"vectorpath smoke only {eps:,.0f} events/s (floor {floor:,.0f})"
    return eps


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=10_000_000)
    ap.add_argument("--fast-requests", type=int, default=1_000_000)
    ap.add_argument("--baseline-requests", type=int, default=10_000)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--no-record", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="200k-request vectorpath-only run with an "
                         "absolute events/s floor (make perf-smoke)")
    args = ap.parse_args(argv)
    if args.smoke:
        n = args.requests if args.requests != 10_000_000 else 200_000
        smoke(n, args.seed)
        return
    run(args.requests, args.fast_requests, args.baseline_requests,
        args.seed, record=not args.no_record)


if __name__ == "__main__":
    main()
