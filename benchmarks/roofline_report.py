"""Roofline table from the dry-run artifacts (experiments/dryrun/*.json).

Single-pod (16x16) rows per §Roofline: the three terms in ms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS useful ratio.
"""
from __future__ import annotations

import glob
import json
import os
import time

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load(mesh: str = "16x16") -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    recs = load()
    rows = []
    if not recs:
        print("\n== Roofline: no dry-run artifacts found "
              "(run python -m repro.launch.dryrun --all) ==")
        return [("roofline_rows", 0.0, "0")]
    print("\n== Roofline (single-pod 16x16 = 256 chips, TPU v5e terms) ==")
    print(f"{'arch':<18} {'shape':<12} {'comp ms':>9} {'mem ms':>10} "
          f"{'coll ms':>9} {'dominant':>10} {'useful':>7}")
    dom_count = {}
    for r in recs:
        rf = r["roofline"]
        dom_count[rf["dominant"]] = dom_count.get(rf["dominant"], 0) + 1
        print(f"{r['arch']:<18} {r['shape']:<12} "
              f"{rf['compute_s']*1e3:>9.2f} {rf['memory_s']*1e3:>10.2f} "
              f"{rf['collective_s']*1e3:>9.2f} {rf['dominant']:>10} "
              f"{rf['useful_ratio']:>7.2f}")
    dt = (time.perf_counter() - t0) * 1e6
    print(f"dominant-term distribution: {dom_count}")
    rows.append(("roofline_rows", dt, str(len(recs))))
    for k, v in dom_count.items():
        rows.append((f"roofline_dominant_{k}", dt, str(v)))
    return rows


if __name__ == "__main__":
    run()
