"""Token serving benchmark: the autoregressive continuous-batching path.

Two measurements (ISSUE 3 acceptance):

1. **Scale** — the ``llm-chat`` scenario at >= 100,000 autoregressive
   requests through ``TokenFastSimRunner`` (struct-of-arrays decode
   streams + token memoized solver).  Reports simulated tokens/s, TTFT
   p50/p99, the per-token (TBT) deadline violation rate, engine
   events/s, and the token-solver cache hit rate.  Asserts the run
   actually sustains the 100k-request bar.
2. **Real kernels** (skippable with ``--no-jax``) — a small slice of the
   same scenario executed for real through ``TokenJaxBackend``: prefill
   via the Pallas ``swa_prefill`` kernel, decode steps via the Pallas
   ``decode_attention`` kernel, smollm-135m-reduced config, jitted per
   (c, b).  Reports executed tokens and the same SLO metrics.

    PYTHONPATH=src python -m benchmarks.token_serving_bench
    PYTHONPATH=src python benchmarks/token_serving_bench.py \
        --requests 200000 --no-jax
"""
from __future__ import annotations

import argparse
import time

MIN_REQUESTS = 100_000


def run(n_requests: int = 101_000, jax_requests: int = 12,
        seed: int = 1) -> list[tuple[str, float, str]]:
    from repro.serving.scenarios import run_scenario

    t0 = time.perf_counter()
    rep, stats = run_scenario("llm-chat", engine="fast",
                              requests=n_requests, seed=seed)
    wall = stats["run_wall_s"]
    gen_s = time.perf_counter() - t0 - wall
    hit = stats["solver"].get("hit_rate", 0.0)
    print(f"llm-chat fast engine: {rep.n_requests:,} requests "
          f"({rep.tokens_served:,} tokens) generated in {gen_s:.1f} s, "
          f"served in {wall:.1f} s engine wall")
    print(f"  tokens/s (sim)  : {rep.tokens_per_s:,.1f}")
    print(f"  TTFT p50/p99    : {rep.ttft_p50*1e3:.1f} / "
          f"{rep.ttft_p99*1e3:.1f} ms")
    print(f"  TBT violations  : {rep.tbt_violation_rate*100:.4f}% of "
          "decode tokens")
    print(f"  request viols   : {rep.violation_rate*100:.3f}%   "
          f"avg_cores={rep.avg_cores:.2f}")
    print(f"  engine          : {stats['events']:,} events "
          f"= {stats['events']/max(wall,1e-9):,.0f} events/s, "
          f"solver hit rate {hit*100:.1f}%")
    assert rep.n_requests >= MIN_REQUESTS, \
        f"only {rep.n_requests:,} autoregressive requests served " \
        f"(bar: >= {MIN_REQUESTS:,})"
    rows = [("token_fast", 1e6 * wall / max(stats["events"], 1),
             f"tokens_per_s={rep.tokens_per_s:.0f};"
             f"ttft_p99={rep.ttft_p99:.4f};"
             f"tbt_viol={rep.tbt_violation_rate:.6f};"
             f"hit_rate={hit:.3f}")]

    if jax_requests > 0:
        from repro.serving.token_backend import run_token_jax_scenario
        rep, stats = run_token_jax_scenario("llm-chat",
                                            requests=jax_requests,
                                            seed=seed)
        wall = stats["run_wall_s"]
        print(f"llm-chat TokenJaxBackend ({stats['arch']}): "
              f"{rep.n_requests} requests, "
              f"{stats['tokens_executed']} real tokens in {wall:.1f} s")
        print(f"  tokens/s (virtual): {rep.tokens_per_s:.2f}   "
              f"TTFT p99: {rep.ttft_p99*1e3:.1f} ms   "
              f"TBT violations: {rep.tbt_violation_rate*100:.2f}%")
        rows.append(("token_jax",
                     1e6 * wall / max(stats["tokens_executed"], 1),
                     f"tokens={stats['tokens_executed']};"
                     f"ttft_p99={rep.ttft_p99:.4f};"
                     f"tbt_viol={rep.tbt_violation_rate:.6f}"))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=101_000)
    ap.add_argument("--jax-requests", type=int, default=12)
    ap.add_argument("--no-jax", action="store_true",
                    help="skip the real-kernel TokenJaxBackend slice")
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args(argv)
    run(args.requests, 0 if args.no_jax else args.jax_requests, args.seed)


if __name__ == "__main__":
    main()
