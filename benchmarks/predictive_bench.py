"""Beyond-paper study: reactive Sponge vs predictive Sponge under deep
fades (the regime where reactive control is structurally late)."""
from __future__ import annotations

import time

from repro.core.baselines import SpongePolicy
from repro.core.perf_model import yolov5s_like
from repro.core.predictive import (PredictivePolicy, PredictiveSpongeScaler,
                                   TelemetryPolicy)
from repro.core.scaler import SpongeScaler
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.network.traces import synth_4g_trace
from repro.serving.api import ScenarioRunner, SimBackend
from repro.serving.workload import WorkloadGenerator


def _run(perf, policy, trace, rps=20.0):
    wl = WorkloadGenerator(rps=rps, slo=1.0, size_kb=200)
    sim = ScenarioRunner(policy, SimBackend(perf, DEFAULT_C, DEFAULT_B,
                                            c0=16))
    sim.monitor.rate.prior_rps = rps
    return sim.run(wl.generate(trace))


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    perf = yolov5s_like()
    rows = []
    print("\n== Beyond-paper: reactive vs predictive Sponge ==")
    print(f"{'trace':>10} {'reactive':>9} {'holt-pred':>10} {'telemetry':>10} "
          f"{'cores r/h/t':>20}")
    for lo, seed in ((0.5, 42), (0.3, 42), (0.3, 7)):
        trace = synth_4g_trace(600, seed=seed, lo=lo)
        r1 = _run(perf, SpongePolicy(SpongeScaler(perf)), trace)
        r2 = _run(perf, PredictivePolicy(PredictiveSpongeScaler(perf)),
                  trace)
        r3 = _run(perf, TelemetryPolicy(SpongeScaler(perf), trace), trace)
        print(f"{lo:>6.1f}/{seed:<3d} {r1['violation_rate']*100:>8.2f}% "
              f"{r2['violation_rate']*100:>9.2f}% "
              f"{r3['violation_rate']*100:>9.2f}% "
              f"{r1['avg_cores']:>6.2f}/{r2['avg_cores']:.2f}/"
              f"{r3['avg_cores']:.2f}")
        rows.append((f"predictive_lo{lo}_s{seed}_viol_pct",
                     (time.perf_counter() - t0) * 1e6,
                     f"react={r1['violation_rate']*100:.2f};"
                     f"holt={r2['violation_rate']*100:.2f};"
                     f"telem={r3['violation_rate']*100:.2f}"))
    return rows


if __name__ == "__main__":
    run()
