"""Distribution-aware admission benchmark: quantile planning +
cancel-on-overrun vs the deterministic-cost scaler on heavy-tailed
traffic.

Runs the ``llm-heavy-tail`` scenario (>=100k autoregressive requests,
lognormal decode lengths with sigma=1.4 — the p90 is ~6x the median
and the tail above it carries about half the total decode mass)
through the token fast engine twice over the *same* workload:

* **deterministic** — ``admission_quantile=0.0`` disables the
  uncertainty path entirely; the scaler plans slot turnover at the
  cost model's mean decode length (today's behavior, bit-identical to
  the pre-uncertainty engine);
* **aware** — the scenario's declared ``LognormalLengths`` drives
  quantile admission (p90 planning drag), speculative over-admission
  with per-stream token budgets, cancel-on-overrun through the PR 5
  cancellation machinery, and the coverage-calibrated predictor slack
  (``repro.core.uncertainty``).

The acceptance bar (ISSUE 7): the aware variant must hold a
**strictly lower violation rate at equal-or-lower core-seconds** —
planning at the mean under a heavy tail *both* misses deadlines (the
tail hogs slots the solver never planned for) and wastes cores (the
monster streams run to completion); cutting the tail at the promised
quantile fixes the two at once.  The run is recorded to
``BENCH_uncertainty.json`` (append-mode trajectory via
``benchmarks.run.record_bench``).

    PYTHONPATH=src python -m benchmarks.uncertainty_bench
    PYTHONPATH=src python benchmarks/uncertainty_bench.py --requests 20000
"""
from __future__ import annotations

import argparse
import time

from benchmarks.run import record_bench
from repro.serving.scenarios import run_scenario

RECORDS_OWN = True   # run() appends its own BENCH_uncertainty.json entry
SCENARIO = "llm-heavy-tail"
# core-seconds tolerance on the "equal-or-lower" arm of the bar: zero —
# on heavy-tailed traffic the cancelled tail mass dwarfs any reaction
# transient, so the aware variant must win the cost axis outright.
CORE_S_TOL = 0.0


def _one(label: str, n_requests: int, seed: int, **kw):
    t0 = time.perf_counter()
    rep, stats = run_scenario(SCENARIO, engine="fast",
                              requests=n_requests, seed=seed, **kw)
    wall = time.perf_counter() - t0
    eps = stats["events"] / wall
    print(f"{label:13s}: {rep.n_requests:,} served "
          f"(+{rep.n_cancelled:,} cancelled), "
          f"{stats['events']:,} events in {wall:.1f} s = {eps:,.0f} "
          "events/s")
    print(f"               violations={rep.violation_rate * 100:.3f}%  "
          f"core_seconds={rep.core_seconds:,.0f}  "
          f"ttft_p99={rep.ttft_p99:.3f}s")
    unc = stats.get("uncertainty")
    if unc:
        print(f"               quantile={unc['quantile']}  "
              f"slack={float(unc['slack_factor']):.3f}  "
              f"calib_err={float(unc['calibration_error']):.4f}  "
              f"overrun_cancels={unc['overrun_cancels']:,}")
    return rep, stats, eps


def run(n_requests: int = 120_000, seed: int = 7) -> list:
    det, _, det_eps = _one("deterministic", n_requests, seed,
                           admission_quantile=0.0)
    aware, saw, aw_eps = _one("aware", n_requests, seed)
    unc = saw["uncertainty"]

    total = det.n_requests + det.n_cancelled
    print(f"delta        : violations {det.violation_rate * 100:.3f}% -> "
          f"{aware.violation_rate * 100:.3f}%  core-seconds "
          f"{det.core_seconds:,.0f} -> {aware.core_seconds:,.0f} "
          f"({(1 - aware.core_seconds / det.core_seconds) * 100:.1f}% "
          "saved)")

    # poisson thinning undershoots the request target by a few percent
    assert total >= 0.9 * n_requests, total
    assert det.n_cancelled == 0, det.n_cancelled
    assert aware.n_cancelled > 0, "speculative admission never cancelled"
    # the bar: strictly fewer violations at equal-or-lower core-seconds
    assert aware.violation_rate < det.violation_rate, (
        f"aware {aware.violation_rate:.5f} not below "
        f"det {det.violation_rate:.5f}")
    assert aware.core_seconds <= det.core_seconds + CORE_S_TOL, (
        f"aware {aware.core_seconds:.0f} core-s exceeds "
        f"det {det.core_seconds:.0f}")

    metrics = {
        "scenario": SCENARIO, "n_requests": int(total), "seed": seed,
        "deterministic": {"violation_rate": det.violation_rate,
                          "core_seconds": det.core_seconds,
                          "ttft_p99": det.ttft_p99,
                          "events_per_s": round(det_eps, 1)},
        "aware": {"violation_rate": aware.violation_rate,
                  "core_seconds": aware.core_seconds,
                  "ttft_p99": aware.ttft_p99,
                  "events_per_s": round(aw_eps, 1),
                  "n_cancelled": int(aware.n_cancelled),
                  "admission_quantile": float(unc["quantile"]),
                  "slack_factor": float(unc["slack_factor"]),
                  "calibration_error": float(unc["calibration_error"])},
        "core_seconds_saved": 1.0 - aware.core_seconds / det.core_seconds,
    }
    record_bench("uncertainty", metrics)
    return [
        ("uncertainty_det", 1e6 / det_eps,
         f"viol={det.violation_rate:.5f};core_s={det.core_seconds:.0f}"),
        ("uncertainty_aware", 1e6 / aw_eps,
         f"viol={aware.violation_rate:.5f};"
         f"core_s={aware.core_seconds:.0f};"
         f"cancelled={aware.n_cancelled}"),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=120_000)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args(argv)
    run(args.requests, args.seed)


if __name__ == "__main__":
    main()
