"""§Perf iteration reproducer: baseline vs tuned roofline terms for the
three hillclimbed pairs, read from the dry-run artifacts (re-run
`python -m repro.launch.dryrun --arch A --shape S [--opt tuned]` to
regenerate; full narrative in EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import glob
import json
import os
import time

DIRS = {"baseline": "experiments/dryrun", "tuned": "experiments/perf"}
PAIRS = [("smollm-135m", "train_4k"),
         ("rwkv6-1.6b", "decode_32k"),
         ("kimi-k2-1t-a32b", "decode_32k"),
         ("deepseek-v3-671b", "decode_32k")]
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load(d, arch, shape, suffix):
    pat = os.path.join(ROOT, d, f"{arch}_{shape}_16x16{suffix}.json")
    fs = glob.glob(pat)
    return json.load(open(fs[0])) if fs else None


def run() -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    rows = []
    print("\n== Perf iterations: baseline vs tuned (16x16) ==")
    print(f"{'pair':<34} {'step_ms base':>13} {'step_ms tuned':>14} {'gain':>6}")
    for arch, shape in PAIRS:
        b = _load(DIRS["baseline"], arch, shape, "")
        t = _load(DIRS["tuned"], arch, shape, "-tuned")
        if not (b and t):
            continue
        def step(r):
            rf = r["roofline"]
            return (max(rf["compute_s"], rf["memory_s"])
                    + rf["collective_s"]) * 1e3
        sb, st_ = step(b), step(t)
        gain = sb / max(st_, 1e-9)
        print(f"{arch + ' x ' + shape:<34} {sb:>13.1f} {st_:>14.1f} "
              f"{gain:>5.1f}x")
        rows.append((f"perf_{arch}_{shape}_gain",
                     (time.perf_counter() - t0) * 1e6, f"{gain:.2f}"))
    if not rows:
        print("(no artifacts; run the dry-runs first)")
        rows.append(("perf_pairs", 0.0, "0"))
    return rows


if __name__ == "__main__":
    run()
