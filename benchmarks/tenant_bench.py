"""Multi-tenant pool benchmark: marginal-value core swapping vs static
partitions.

Runs the ``mixed-zoo`` scenario (whisper + chat LLM + rwkv6, >=200k
requests total) through the shared-pool fast engine
(``repro.serving.tenancy.TenantFastRunner``, 128 cores, the
``greedy-marginal`` reallocation policy), then replays **each tenant's
own stream** under a ladder of statically partitioned fleets: the
tenant's initial pool slice pinned as every ``n x c`` shape that fills
it (``StaticFleetPolicy`` — batch-adaptive, shape-pinned, always on).
The per-tenant baseline is the *best* static shape by violation rate —
the strongest partition an operator could have pinned with the same
core split.

The acceptance bar (ISSUE 6): the pool must spend **lower aggregate
core-seconds** than the statically partitioned fleets at
**equal-or-lower per-tenant violation rates**, and the run is recorded
to ``BENCH_tenant.json`` (append-mode trajectory via
``benchmarks.run.record_bench``).

    PYTHONPATH=src python -m benchmarks.tenant_bench
    PYTHONPATH=src python benchmarks/tenant_bench.py --requests 50000
"""
from __future__ import annotations

import argparse
import time

from benchmarks.run import record_bench
from repro.serving.fleet import FleetFastSimRunner, StaticFleetPolicy
from repro.serving.scenarios import build_scenario
from repro.serving.tenancy import TenantFastRunner

RECORDS_OWN = True        # run() appends its own BENCH_tenant.json entry
MIN_SAVINGS = 0.20        # aggregate core-seconds bar vs static partition
# "equal violation rate" tolerance, per tenant.  Wider than
# fleet_bench's 0.002: a tenant whose static partition is grossly
# overprovisioned (smollm's 64-core slice serves a <=13-core load) sits
# at exactly 0%, while any autoscaler pays a few tenths of a percent in
# reaction transients — that gap is the cost of elasticity, not a
# capacity deficit, so "equal" here means within half a percent.
VIOL_TOL = 0.005
POOL_POLICY = "greedy-marginal"


def _partition_shapes(cap: int, c_set, n_max: int = 16):
    """Every ``n x c`` fleet shape that exactly fills a ``cap``-core
    partition (the static ladder for one tenant)."""
    cs = sorted(set(int(c) for c in c_set), reverse=True)
    out = []
    for c in cs:
        n = cap // c
        if 1 <= n <= n_max and n * c == cap:
            out.append((n, c))
    return out


def run(n_requests: int = 200_000, seed: int = 1,
        policy: str = POOL_POLICY) -> list[tuple[str, float, str]]:
    t0 = time.perf_counter()
    batch, meta = build_scenario("mixed-zoo", requests=n_requests,
                                 seed=seed)
    specs = list(meta["tenants"])
    tick = meta["tick"]
    pool_cores = int(meta["pool_cores"])
    horizon = max(float(s.batch.arrival[-1]) for s in specs) + 60.0
    print(f"mixed-zoo: {len(batch):,} requests over {len(specs)} tenants "
          f"generated in {time.perf_counter() - t0:.1f} s "
          f"(horizon {horizon:,.0f} s, pool {pool_cores} cores)")

    # --- the shared pool (per-tenant solvers + marginal-value swaps) ------
    pool_run = TenantFastRunner(specs, budget=pool_cores, policy=policy,
                                tick=tick, budget_quantum=0.01,
                                lam_quantum=0.5)
    caps_init = tuple(pool_run.pool.caps)      # the static partition split
    t0 = time.perf_counter()
    rep = pool_run.run(horizon)
    wall = time.perf_counter() - t0
    eps = pool_run.events_processed / wall
    print(f"tenant-pool  : {rep.n_requests:,} requests, "
          f"{pool_run.events_processed:,} events in {wall:.1f} s "
          f"= {eps:,.0f} events/s  (policy={policy}, "
          f"swaps={len(pool_run.pool.swaps)})")
    print(f"               violations={rep.violation_rate*100:.3f}%  "
          f"core_seconds={rep.core_seconds:,.0f}  "
          f"caps {list(caps_init)} -> {pool_run.pool.caps}")

    # --- statically partitioned per-tenant fleets on the same split ------
    static_cs = 0.0
    per_tenant = []
    for spec, cap, prep in zip(specs, caps_init, pool_run.tenant_reports):
        best = None
        for n, c in _partition_shapes(cap, spec.c_set):
            pol = StaticFleetPolicy(spec.cost, replicas=n, cores=c,
                                    interval=tick, budget_quantum=0.01,
                                    lam_quantum=0.5)
            fl = FleetFastSimRunner(pol, spec.cost, spec.c_set, spec.b_set,
                                    n0=n, c0=c, tick=tick,
                                    prior_rps=spec.expected_rps)
            r = fl.run(spec.batch, horizon)
            if best is None or (r.violation_rate, r.core_seconds) < \
                    (best[1].violation_rate, best[1].core_seconds):
                best = ((n, c), r)
        (bn, bc), br = best
        static_cs += br.core_seconds
        per_tenant.append((spec, prep, (bn, bc), br))
        print(f"{spec.name:13s}: pooled viol={prep.violation_rate*100:.3f}% "
              f"core_s={prep.core_seconds:,.0f}  |  best static "
              f"{bn}x{bc} viol={br.violation_rate*100:.3f}% "
              f"core_s={br.core_seconds:,.0f}")

    savings = 1.0 - rep.core_seconds / static_cs
    print(f"aggregate    : pooled {rep.core_seconds:,.0f} core-s vs "
          f"static partition {static_cs:,.0f} core-s -> "
          f"{savings*100:.1f}% saved (bar: >= {MIN_SAVINGS*100:.0f}%)")

    assert len(specs) >= 3, len(specs)
    assert pool_cores >= 128, pool_cores
    # poisson thinning undershoots the request target by a few percent
    assert len(batch) >= 0.9 * min(n_requests, 200_000), len(batch)
    for spec, prep, shape, br in per_tenant:
        assert prep.violation_rate <= br.violation_rate + VIOL_TOL, (
            f"{spec.name}: pooled {prep.violation_rate:.5f} worse than "
            f"static {shape} {br.violation_rate:.5f}")
    assert savings >= MIN_SAVINGS, f"only {savings*100:.1f}% saved"

    metrics = {
        "scenario": "mixed-zoo", "policy": policy,
        "n_requests": int(rep.n_requests), "pool_cores": pool_cores,
        "caps_init": list(caps_init),
        "caps_final": list(pool_run.pool.caps),
        "swaps": len(pool_run.pool.swaps),
        "events_per_s": round(eps, 1),
        "pooled": {"violation_rate": rep.violation_rate,
                   "core_seconds": rep.core_seconds},
        "static": {"core_seconds": static_cs},
        "savings": savings,
        "tenants": {spec.name: {
            "pooled_violation_rate": prep.violation_rate,
            "pooled_core_seconds": prep.core_seconds,
            "static_shape": list(shape),
            "static_violation_rate": br.violation_rate,
            "static_core_seconds": br.core_seconds,
        } for spec, prep, shape, br in per_tenant},
    }
    record_bench("tenant", metrics)
    return [
        ("tenant_pool", 1e6 / eps,
         f"events_per_s={eps:.0f};viol={rep.violation_rate:.5f};"
         f"core_s={rep.core_seconds:.0f};"
         f"swaps={len(pool_run.pool.swaps)}"),
        ("tenant_static_base", 1e6 / eps,
         f"core_s={static_cs:.0f};savings={savings:.3f}"),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200_000)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--policy", default=POOL_POLICY)
    args = ap.parse_args(argv)
    run(args.requests, args.seed, args.policy)


if __name__ == "__main__":
    main()
