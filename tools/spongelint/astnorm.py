"""AST normalization for the inline-drift rule.

Two fragments are *alpha-equivalent* when they have the same statement
structure after consistently renaming local identifiers: ``nt``/``s``
in an inlined copy may stand for ``now``/``self`` in the canonical
function, but the statements, operators, attribute names, call
keywords and constants must match exactly, in order.  The comparison
works by canonicalizing both sides independently — every ``Name`` is
renamed to ``ν0, ν1, …`` in first-occurrence order — and comparing the
resulting dumps: alpha-equivalent fragments canonicalize to the same
string, and a reordered, inserted or deleted statement cannot.

What normalization removes (cosmetic, cannot change behaviour):

* docstrings (the leading string expression of a module/class/function
  body, when other statements follow);
* annotations (``x: int = 1`` vs ``x = 1``) and type comments;
* line/column information and expression context (Load/Store/Del).

What it preserves (semantic, drift when changed):

* statement order and structure, operators, constants;
* attribute names (``self.core_seconds``), call keyword names,
  imported module names;
* the *pattern* of identifier use — ``a = a + b`` never matches
  ``a = b + a``.

:func:`fingerprint` hashes a canonical dump to the short hex digest
used by ``pin=`` markers: a pin survives pure renames and comment or
docstring edits in the canonical function, and breaks on any change to
its statements — exactly the "re-verify the transformed copy" trigger
the lint wants.
"""
from __future__ import annotations

import ast
import hashlib
from typing import List, Sequence

# identifier-valued AST fields that bind or reference *local* names and
# therefore take part in alpha-renaming (everything else — attribute
# names, call keywords, import sources — is compared verbatim)
_RENAMED_FIELDS = {
    (ast.FunctionDef, "name"), (ast.AsyncFunctionDef, "name"),
    (ast.ClassDef, "name"), (ast.ExceptHandler, "name"),
}


class _Env:
    """First-occurrence alpha-renaming environment."""

    def __init__(self) -> None:
        self._map: dict = {}

    def rename(self, name: str) -> str:
        if name not in self._map:
            self._map[name] = f"ν{len(self._map)}"
        return self._map[name]


def _is_docstring(stmt: ast.stmt) -> bool:
    return (isinstance(stmt, ast.Expr)
            and isinstance(stmt.value, ast.Constant)
            and isinstance(stmt.value.value, str))


def strip_docstring(body: Sequence[ast.stmt]) -> List[ast.stmt]:
    """Drop a leading docstring when other statements follow it."""
    body = list(body)
    if len(body) > 1 and _is_docstring(body[0]):
        return body[1:]
    return body


def _canon(node, env: _Env, out: List[str]) -> None:
    if node is None:
        out.append("∅")
        return
    if isinstance(node, ast.Name):
        out.append(f"N:{env.rename(node.id)}")
        return
    if isinstance(node, ast.arg):
        out.append(f"a:{env.rename(node.arg)}")
        return
    if isinstance(node, ast.Attribute):
        out.append("Attr(")
        _canon(node.value, env, out)
        out.append(f",{node.attr})")
        return
    if isinstance(node, ast.Constant):
        out.append(f"C:{type(node.value).__name__}:{node.value!r}")
        return
    if isinstance(node, (ast.Load, ast.Store, ast.Del)):
        return
    if isinstance(node, ast.keyword):
        # keyword names are part of the call contract — verbatim
        out.append(f"kw:{node.arg or '**'}(")
        _canon(node.value, env, out)
        out.append(")")
        return
    if isinstance(node, ast.alias):
        out.append(f"alias:{node.name}")
        if node.asname:
            out.append(f"as:{env.rename(node.asname)}")
        return
    if isinstance(node, (ast.Global, ast.Nonlocal)):
        out.append(type(node).__name__ + "("
                   + ",".join(env.rename(n) for n in node.names) + ")")
        return
    if isinstance(node, ast.AnnAssign):
        # annotation is cosmetic; a value-less AnnAssign is a pure
        # declaration and canonicalizes to its target alone
        out.append("Ann(")
        _canon(node.target, env, out)
        out.append(",")
        _canon(node.value, env, out)
        out.append(")")
        return
    out.append(type(node).__name__ + "(")
    for field, value in ast.iter_fields(node):
        if field in ("type_comment", "annotation", "returns",
                     "lineno", "col_offset"):
            continue
        if (type(node), field) in _RENAMED_FIELDS:
            out.append(f"{field}={env.rename(value) if value else '∅'},")
            continue
        if field == "body" and isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef,
                       ast.ClassDef, ast.Module)):
            value = strip_docstring(value)
        if isinstance(value, list):
            out.append(f"{field}=[")
            for item in value:
                _canon(item, env, out)
                out.append(",")
            out.append("],")
        elif isinstance(value, ast.AST):
            out.append(f"{field}=")
            _canon(value, env, out)
            out.append(",")
        else:
            out.append(f"{field}={value!r},")
    out.append(")")


def canonical_dump(nodes) -> str:
    """Canonicalize a node or statement sequence to a comparable string
    (one fresh renaming environment per call)."""
    env = _Env()
    out: List[str] = []
    if isinstance(nodes, (list, tuple)):
        for n in nodes:
            _canon(n, env, out)
            out.append(";")
    else:
        _canon(nodes, env, out)
    return "".join(out)


def body_dump(func: ast.AST) -> str:
    """Canonical dump of a function's body (docstring stripped) — what a
    strict ``inline-of`` copy is compared against."""
    if not isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
        raise TypeError(f"expected a function, got {type(func).__name__}")
    return canonical_dump(strip_docstring(func.body))


def fingerprint(func: ast.AST) -> str:
    """Short stable hash of a function's normalized AST (arguments +
    body, docstring and annotations stripped) for ``pin=`` markers."""
    dump = canonical_dump(func)
    return hashlib.sha256(dump.encode("utf-8")).hexdigest()[:12]


def alpha_equal(stmts: Sequence[ast.stmt], func: ast.AST) -> bool:
    """True when ``stmts`` alpha-matches the body of ``func``."""
    return canonical_dump(list(stmts)) == body_dump(func)
