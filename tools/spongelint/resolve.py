"""Static ``module.qualname`` → AST resolution for ``inline-of`` targets.

No imports are executed: the module path is mapped to a source file
under one of the resolution roots (longest importable prefix wins —
``repro.serving.fastpath._Slot.account`` resolves the module
``repro/serving/fastpath.py`` and walks the remaining ``_Slot.account``
through the parsed class/function tree).  Parsed modules are cached per
linter run.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple


class ResolutionError(LookupError):
    """The target cannot be mapped to a function definition."""


class TargetResolver:
    """Resolve dotted targets against a list of source roots."""

    def __init__(self, roots: Sequence[Path]):
        self.roots = [Path(r) for r in roots]
        self._trees: Dict[Path, ast.Module] = {}

    def _parse(self, path: Path) -> ast.Module:
        if path not in self._trees:
            self._trees[path] = ast.parse(path.read_text(encoding="utf-8"),
                                          filename=str(path))
        return self._trees[path]

    def _module_file(self, parts: List[str]
                     ) -> Optional[Tuple[Path, List[str]]]:
        """Longest prefix of ``parts`` that is a module file under a
        root; returns ``(file, remaining_qualname_parts)``."""
        for i in range(len(parts), 0, -1):
            rel = Path(*parts[:i])
            for root in self.roots:
                mod = root / rel.with_suffix(".py")
                if mod.is_file():
                    return mod, parts[i:]
                pkg = root / rel / "__init__.py"
                if pkg.is_file():
                    return pkg, parts[i:]
        return None

    def resolve(self, target: str) -> Tuple[Path, ast.FunctionDef]:
        """Map ``module.qualname`` to ``(source_file, FunctionDef)``."""
        parts = target.split(".")
        hit = self._module_file(parts)
        if hit is None:
            raise ResolutionError(
                f"no module file for {target!r} under roots "
                f"{[str(r) for r in self.roots]}")
        path, qual = hit
        if not qual:
            raise ResolutionError(
                f"{target!r} names a module, not a function")
        node: ast.AST = self._parse(path)
        for name in qual:
            body = getattr(node, "body", [])
            nxt = next((s for s in body
                        if isinstance(s, (ast.FunctionDef,
                                          ast.AsyncFunctionDef,
                                          ast.ClassDef))
                        and s.name == name), None)
            if nxt is None:
                raise ResolutionError(
                    f"{'.'.join(qual)!r} not found in {path.name}")
            node = nxt
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            raise ResolutionError(
                f"{target!r} resolves to a {type(node).__name__}, "
                "not a function")
        return path, node
