"""Comment directives: ``inline-of`` markers and suppressions.

Grammar (one directive per comment, anywhere a comment is legal)::

    # spongelint: inline-of <module.qualname> [pin=<hex>] [stmts=<N>]
    # spongelint: disable=<rule>[,<rule>...]   [-- reason]

``inline-of`` marks an inlined copy of a canonical function:

* as a **standalone** comment it anchors to the next statement (with
  ``stmts=N``, to that statement and its next ``N-1`` siblings);
* as a **trailing** comment it anchors to the outermost statement that
  starts on its line;
* without ``pin=`` the anchored statements must alpha-match the
  canonical function's body (strict verbatim-inline contract);
* with ``pin=<hex>`` the copy is a documented *transformation* of the
  canonical (hoisted loads, scalarized array ops): the pin is the
  canonical's :func:`~tools.spongelint.astnorm.fingerprint`, so any
  statement-level change to the canonical breaks the pin and forces
  re-verification of the copy (``python -m tools.spongelint
  --print-pin <target>`` prints the current value to re-stamp with).

``disable=`` suppresses the named rules for the comment's own line
(trailing form) or the next line (standalone form); everything after
``--`` is a free-form reason.  Rule names must exist — a typo'd
suppression is itself reported.
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

_DIRECTIVE = re.compile(r"#\s*spongelint:\s*(?P<body>.+?)\s*$")
_INLINE_OF = re.compile(
    r"^inline-of\s+(?P<target>[A-Za-z_][\w.]*)"
    r"(?P<opts>(?:\s+\w+=\S+)*)\s*$")
_DISABLE = re.compile(
    r"^disable=(?P<rules>[\w,-]+)(?:\s+--\s*(?P<reason>.*))?$")
_OPT = re.compile(r"(\w+)=(\S+)")


@dataclass
class InlineMarker:
    """One parsed ``inline-of`` directive."""
    line: int                    # 1-based line the comment sits on
    standalone: bool             # comment-only line vs trailing
    target: str                  # module.qualname of the canonical
    pin: Optional[str] = None    # expected canonical fingerprint
    stmts: int = 1               # statements covered (strict mode)


@dataclass
class Directives:
    """Every spongelint directive found in one file."""
    markers: List[InlineMarker] = field(default_factory=list)
    # line -> rule names suppressed on that line
    suppressions: Dict[int, Set[str]] = field(default_factory=dict)
    # (line, message) pairs for malformed directives
    errors: List[Tuple[int, str]] = field(default_factory=list)


def parse_directives(source: str) -> Directives:
    """Extract markers and suppressions from ``source`` (tokenize-based,
    so directives inside string literals are never misread)."""
    out = Directives()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.start[1], t.string, t.line)
                    for t in tokens if t.type == tokenize.COMMENT]
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return out
    for line, col, text, raw_line in comments:
        m = _DIRECTIVE.match(text)
        if m is None:
            continue
        body = m.group("body")
        standalone = raw_line[:col].strip() == ""
        im = _INLINE_OF.match(body)
        if im is not None:
            marker = InlineMarker(line=line, standalone=standalone,
                                  target=im.group("target"))
            bad = False
            for key, val in _OPT.findall(im.group("opts") or ""):
                if key == "pin":
                    marker.pin = val
                elif key == "stmts":
                    try:
                        marker.stmts = int(val)
                    except ValueError:
                        bad = True
                    if marker.stmts < 1:
                        bad = True
                else:
                    bad = True
            if bad:
                out.errors.append(
                    (line, f"malformed inline-of options: {body!r}"))
            else:
                out.markers.append(marker)
            continue
        dm = _DISABLE.match(body)
        if dm is not None:
            rules = {r for r in dm.group("rules").split(",") if r}
            target_line = line if not standalone else line + 1
            out.suppressions.setdefault(target_line, set()).update(rules)
            continue
        out.errors.append((line, f"unrecognized directive: {body!r}"))
    return out
