"""deprecation-hygiene: non-test code must not import deprecated shims.

``repro.serving.simulator``, ``repro.serving.engine`` and
``repro.core.multidim`` are warn-on-import compatibility shims kept
only so historical test suites and notebooks keep working.  New code
reaching through them silently re-entrenches the old API and hides the
DeprecationWarning behind ``warnings.catch_warnings`` blocks.  This
rule flags any ``import`` of a deprecated module from non-test code.

Exempt: files named ``test_*.py`` / ``conftest.py`` (the shims'
regression tests must import them) and the shim modules themselves.
A deliberate use (e.g. an ablation benchmark comparing against the
legacy solver) carries an explicit suppression::

    # spongelint: disable=deprecation-hygiene -- comparing legacy solver
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from tools.spongelint import FileContext, Finding, rule

RULE = "deprecation-hygiene"

DEPRECATED = {
    "repro.serving.simulator":
        "use repro.serving.fastpath / repro.serving.api instead",
    "repro.serving.engine":
        "use repro.serving.api (build_llm_step_fns, serve_*) instead",
    "repro.core.multidim":
        "use repro.core.solver (MemoizedSolver) instead",
}
# ``from repro.serving import engine`` style: parent package -> leaf names
_PARENTS = {}
for _mod in DEPRECATED:
    _pkg, _, _leaf = _mod.rpartition(".")
    _PARENTS.setdefault(_pkg, set()).add(_leaf)


def _exempt(ctx: FileContext) -> bool:
    name = ctx.path.name
    if name.startswith("test_") or name == "conftest.py":
        return True
    # the shims themselves (and their re-export guards)
    mod_key = "/".join(ctx.path.parts[-3:]).replace(".py", "") \
        .replace("/", ".")
    return any(mod_key.endswith(m.split(".", 1)[1]) for m in DEPRECATED)


@rule(RULE, "non-test code must not import deprecated shim modules")
def check(ctx: FileContext) -> Iterable[Finding]:
    if _exempt(ctx):
        return []
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                hit = next((m for m in DEPRECATED
                            if a.name == m or a.name.startswith(m + ".")),
                           None)
                if hit:
                    findings.append(ctx.finding(
                        node, RULE, f"import of deprecated {hit}: "
                        f"{DEPRECATED[hit]}"))
        elif isinstance(node, ast.ImportFrom) and node.module:
            if node.module in DEPRECATED:
                findings.append(ctx.finding(
                    node, RULE, f"import from deprecated {node.module}: "
                    f"{DEPRECATED[node.module]}"))
            elif node.module in _PARENTS:
                for a in node.names:
                    if a.name in _PARENTS[node.module]:
                        findings.append(ctx.finding(
                            node, RULE, "import of deprecated "
                            f"{node.module}.{a.name}: "
                            f"{DEPRECATED[f'{node.module}.{a.name}']}"))
    return findings
