"""scan-purity: functions traced by ``lax.scan`` / ``jax.jit`` stay pure.

The scan-path engine compiles its decode step once and replays it for
every chunk; a traced function runs at *trace* time, so closure
mutation, I/O, or host callbacks silently execute once (or never) and
then disappear from the compiled computation.  This rule finds every
function handed to ``lax.scan`` or ``jax.jit`` (positional argument,
decorator, or lambda) and flags, inside it:

* ``global`` / ``nonlocal`` declarations — closure mutation;
* assignment to attributes, or to subscripts of names the function
  does not bind itself — mutating enclosing state;
* mutating method calls (``append`` / ``update`` / ``write`` / …) on
  names the function does not bind itself;
* I/O builtins (``print`` / ``open`` / ``input``);
* host callbacks outside the whitelist (``jax.debug.print`` and
  ``jax.debug.callback`` are allowed — they are trace-safe debugging
  aids; ``io_callback`` / ``pure_callback`` / ``host_callback`` are
  not, because the repo's scan step must stay device-only).

Suppress a deliberate exception with
``# spongelint: disable=scan-purity``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set

from tools.spongelint import FileContext, Finding, rule
from tools.spongelint.rules.determinism import _alias_map, _dotted

RULE = "scan-purity"

_TRACE_ENTRY = {"jax.lax.scan": 0, "jax.jit": 0}
_IO_BUILTINS = {"print", "open", "input", "breakpoint"}
_CALLBACK_WHITELIST = {"jax.debug.print", "jax.debug.callback"}
_CALLBACK_BANNED = {
    "jax.pure_callback", "jax.experimental.io_callback",
    "jax.experimental.host_callback.call",
    "jax.experimental.host_callback.id_tap", "jax.debug.breakpoint",
}
_MUTATORS = {"append", "extend", "insert", "remove", "pop", "clear",
             "update", "setdefault", "add", "discard", "write",
             "writelines", "sort", "reverse"}


def _local_names(fn: ast.AST) -> Set[str]:
    """Names the function binds itself: parameters plus store-targets."""
    names: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.arg):
            names.add(node.arg)
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            names.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(node.name)
    return names


def _check_traced(ctx: FileContext, fn: ast.AST, label: str,
                  aliases: Dict[str, str]) -> List[Finding]:
    findings: List[Finding] = []
    local = _local_names(fn)
    for node in ast.walk(fn):
        if isinstance(node, (ast.Global, ast.Nonlocal)):
            findings.append(ctx.finding(
                node, RULE, f"{label}: {type(node).__name__.lower()} "
                "declaration mutates enclosing state inside a traced "
                "function"))
        elif isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Attribute):
                    findings.append(ctx.finding(
                        node, RULE, f"{label}: attribute assignment "
                        "mutates object state inside a traced function"))
                elif isinstance(t, ast.Subscript) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id not in local:
                    findings.append(ctx.finding(
                        node, RULE, f"{label}: subscript assignment to "
                        f"closed-over {t.value.id!r} inside a traced "
                        "function"))
        elif isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name) \
                    and node.func.id in _IO_BUILTINS \
                    and node.func.id not in local:
                findings.append(ctx.finding(
                    node, RULE, f"{label}: {node.func.id}() performs "
                    "host I/O inside a traced function"))
                continue
            dotted = _dotted(node.func, aliases)
            if dotted in _CALLBACK_BANNED or (
                    dotted and "callback" in dotted
                    and dotted not in _CALLBACK_WHITELIST):
                findings.append(ctx.finding(
                    node, RULE, f"{label}: host callback {dotted} is "
                    "not whitelisted (allowed: "
                    f"{', '.join(sorted(_CALLBACK_WHITELIST))})"))
            elif isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _MUTATORS \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id not in local \
                    and node.func.value.id not in aliases:
                findings.append(ctx.finding(
                    node, RULE, f"{label}: .{node.func.attr}() on "
                    f"closed-over {node.func.value.id!r} mutates "
                    "enclosing state inside a traced function"))
    return findings


def _is_trace_deco(deco: ast.expr, aliases: Dict[str, str]) -> bool:
    if _dotted(deco, aliases) == "jax.jit":
        return True
    if isinstance(deco, ast.Call):
        if _dotted(deco.func, aliases) == "jax.jit":
            return True
        # functools.partial(jax.jit, ...) applied as a decorator
        if _dotted(deco.func, aliases) == "functools.partial" \
                and deco.args \
                and _dotted(deco.args[0], aliases) == "jax.jit":
            return True
    return False


@rule(RULE, "functions passed to lax.scan/jax.jit must not mutate "
            "closures, do I/O, or call non-whitelisted callbacks")
def check(ctx: FileContext) -> Iterable[Finding]:
    aliases = _alias_map(ctx.tree)
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)

    findings: List[Finding] = []
    seen: Set[int] = set()

    def visit(fn: ast.AST, label: str) -> None:
        if id(fn) in seen:
            return
        seen.add(id(fn))
        findings.extend(_check_traced(ctx, fn, label, aliases))

    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func, aliases)
            idx = _TRACE_ENTRY.get(dotted)
            if idx is None or len(node.args) <= idx:
                continue
            arg = node.args[idx]
            if isinstance(arg, ast.Lambda):
                visit(arg, f"lambda traced by {dotted}")
            elif isinstance(arg, ast.Name):
                for fn in defs.get(arg.id, []):
                    visit(fn, f"{arg.id} (traced by {dotted})")
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_trace_deco(d, aliases) for d in node.decorator_list):
                visit(node, f"{node.name} (decorated with jax.jit)")
    return findings
