"""inline-drift: machine-check every "inlined verbatim" contract.

The fast engines inline canonical accounting/decision code into their
hot loops (``vectorpath._run_ticks_fast`` carries ``_Slot.account``
and the scaler's decide arithmetic; the session/fleet/tenant dispatch
loops carry each other's "rules, verbatim").  Each such copy must be
marked::

    # spongelint: inline-of repro.serving.fastpath._Slot.account

Strict markers (no ``pin=``): the marked statements must alpha-match
the canonical function's body (see ``tools.spongelint.astnorm``) —
reordering, inserting or deleting a statement in either the copy or
the canonical fails the lint.

Pinned markers (``pin=<hex>``): the copy is a documented transformation
(hoisted loads, scalarized arithmetic) that cannot be AST-matched; the
pin is the canonical function's normalized fingerprint.  Any
statement-level change to the canonical breaks the pin, failing the
lint until a human re-verifies the transformed copy and re-stamps
(``python -m tools.spongelint --print-pin <target>``).
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from tools.spongelint import FileContext, Finding, rule
from tools.spongelint.astnorm import (body_dump, canonical_dump,
                                      fingerprint, strip_docstring)
from tools.spongelint.markers import InlineMarker
from tools.spongelint.resolve import ResolutionError

RULE = "inline-drift"


def _statement_lists(tree: ast.Module) -> List[List[ast.stmt]]:
    """Every statement suite in the module (module body, function and
    class bodies, branch suites) — the sibling groups markers index."""
    suites: List[List[ast.stmt]] = []
    for node in ast.walk(tree):
        for field in ("body", "orelse", "finalbody"):
            val = getattr(node, field, None)
            if isinstance(val, list) and val \
                    and all(isinstance(s, ast.stmt) for s in val):
                suites.append(val)
    return suites


def _anchor(marker: InlineMarker, suites: List[List[ast.stmt]]
            ) -> Optional[List[ast.stmt]]:
    """The statements a marker covers, or None when nothing anchors."""
    best = None          # (lineno, col, suite, index)
    for suite in suites:
        for i, stmt in enumerate(suite):
            ln, col = stmt.lineno, stmt.col_offset
            if marker.standalone:
                ok = ln > marker.line
            else:
                ok = ln == marker.line
            if not ok:
                continue
            key = (ln, col)
            if best is None or key < best[0]:
                best = (key, suite, i)
    if best is None:
        return None
    _, suite, i = best
    if i + marker.stmts > len(suite):
        return None
    return suite[i:i + marker.stmts]


@rule(RULE, "annotated inlined copies must match their canonical source")
def check(ctx: FileContext) -> Iterable[Finding]:
    markers = ctx.directives.markers
    if not markers:
        return []
    findings: List[Finding] = []
    suites = _statement_lists(ctx.tree)
    for m in markers:
        try:
            src_path, func = ctx.resolver.resolve(m.target)
        except (ResolutionError, OSError, SyntaxError) as e:
            findings.append(ctx.finding(
                m.line, RULE, "cannot resolve inline-of target "
                f"{m.target!r}: {e}"))
            continue
        if m.pin is not None:
            actual = fingerprint(func)
            if actual != m.pin:
                findings.append(ctx.finding(
                    m.line, RULE,
                    f"canonical {m.target} changed (pin {m.pin}, now "
                    f"{actual}): re-verify the transformed copy below, "
                    "then re-stamp with `python -m tools.spongelint "
                    f"--print-pin {m.target}`"))
            continue
        stmts = _anchor(m, suites)
        if stmts is None:
            findings.append(ctx.finding(
                m.line, RULE, "inline-of marker anchors to no "
                f"statement (stmts={m.stmts})"))
            continue
        if len(stmts) == 1 and isinstance(
                stmts[0], (ast.FunctionDef, ast.AsyncFunctionDef)):
            copy_dump = canonical_dump(strip_docstring(stmts[0].body))
        else:
            copy_dump = canonical_dump(stmts)
        if copy_dump != body_dump(func):
            findings.append(ctx.finding(
                stmts[0], RULE,
                f"inlined copy has drifted from {m.target} "
                f"({src_path.name}:{func.lineno}): statements no longer "
                "alpha-match the canonical body"))
    return findings
