"""determinism: keep the hot paths replayable bit-for-bit.

Every engine-identity contract in this repo (reference == fast ==
vector == scan) assumes a run is a pure function of its workload and
seed.  Inside the ``serving``/``core`` hot paths this rule forbids:

* **wall-clock reads** — ``time.time`` / ``time.time_ns`` /
  ``datetime.now`` and friends.  (``time.perf_counter`` /
  ``time.monotonic`` stay legal: they feed duration *telemetry* like
  ``Decision.solver_time``, which is excluded from the identity
  contracts.)
* **unseeded global RNG** — the module-level ``random.*`` functions
  and legacy ``numpy.random.*`` global API mutate interpreter-global
  state; replays must thread explicit seeded generators
  (``np.random.default_rng(seed)`` / ``random.Random(seed)``).
  Constructing a generator *without* a seed is flagged too.
* **set iteration** — ``for x in {…}`` / ``set(…)``: with hash
  randomization the iteration order varies per process, and float
  accumulation order is load-bearing (see the solver's drain loops and
  ``_Slot.account``); iterate a list or ``sorted(...)`` instead.

Scope: files with a ``serving`` or ``core`` directory component.
Suppress a deliberate use with ``# spongelint: disable=determinism``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List

from tools.spongelint import FileContext, Finding, rule

RULE = "determinism"

_WALL_CLOCK = {
    "time.time", "time.time_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}
# numpy.random attributes that are generator *constructors*, not draws
# from the global state
_NP_RANDOM_OK = {"default_rng", "Generator", "RandomState",
                 "SeedSequence", "BitGenerator", "PCG64", "Philox",
                 "MT19937"}
# constructors that must be given an explicit seed
_NEEDS_SEED = {"numpy.random.default_rng", "numpy.random.RandomState",
               "random.Random"}
_RANDOM_CLASSES = {"Random", "SystemRandom"}


def _alias_map(tree: ast.Module) -> Dict[str, str]:
    """Local name -> dotted import source, for top-level and nested
    imports alike (``np`` -> ``numpy``, ``time`` -> ``time``, …)."""
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                local = a.asname or a.name.split(".")[0]
                aliases[local] = a.name if a.asname else a.name.split(".")[0]
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _dotted(node: ast.AST, aliases: Dict[str, str]) -> str:
    """Resolve ``np.random.rand`` to ``numpy.random.rand`` (empty string
    when the chain does not bottom out in an imported name)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return ""
    base = aliases.get(node.id)
    if base is None:
        return ""
    parts.append(base)
    return ".".join(reversed(parts))


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset"))


def in_scope(ctx: FileContext) -> bool:
    return "serving" in ctx.parts or "core" in ctx.parts


@rule(RULE, "no wall-clock, unseeded global RNG, or set iteration in "
            "serving/core hot paths")
def check(ctx: FileContext) -> Iterable[Finding]:
    if not in_scope(ctx):
        return []
    aliases = _alias_map(ctx.tree)
    findings: List[Finding] = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func, aliases)
            if not dotted:
                continue
            if dotted in _WALL_CLOCK:
                findings.append(ctx.finding(
                    node, RULE, f"wall-clock read {dotted}() in a hot "
                    "path: decisions must be a function of virtual "
                    "time only (perf_counter is fine for telemetry)"))
            elif dotted.startswith("numpy.random."):
                attr = dotted.split(".")[-1]
                if attr not in _NP_RANDOM_OK:
                    findings.append(ctx.finding(
                        node, RULE, f"global numpy RNG {dotted}(): "
                        "thread an explicit np.random.default_rng("
                        "seed) instead"))
            elif dotted.startswith("random.") \
                    and dotted.split(".")[-1] not in _RANDOM_CLASSES \
                    and dotted.count(".") == 1:
                findings.append(ctx.finding(
                    node, RULE, f"global stdlib RNG {dotted}(): thread "
                    "an explicit random.Random(seed) instead"))
            if dotted in _NEEDS_SEED and not node.args \
                    and not node.keywords:
                findings.append(ctx.finding(
                    node, RULE, f"{dotted}() constructed without a "
                    "seed: replays will not be reproducible"))
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            if _is_set_expr(node.iter):
                findings.append(ctx.finding(
                    node, RULE, "iteration over a set in a hot path: "
                    "order varies under hash randomization and float "
                    "accumulation order is load-bearing — iterate a "
                    "list or sorted(...)"))
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                if _is_set_expr(gen.iter):
                    findings.append(ctx.finding(
                        node, RULE, "comprehension over a set in a hot "
                        "path: order varies under hash randomization — "
                        "iterate a list or sorted(...)"))
    return findings
