"""Rule modules — importing this package registers every rule."""
from tools.spongelint.rules import (deprecation, determinism,  # noqa: F401
                                    inline_drift, scan_purity)
