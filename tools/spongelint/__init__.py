"""spongelint — repo-specific static analysis for the Sponge codebase.

The repo's core guarantee is that every fast engine replays the
IP-derived decision stream of its reference engine bit-identically.
The code backing that guarantee is full of *contracts in prose* —
"inlined verbatim", "rules, verbatim", "pure ``(carry, xs)`` step" —
each enforced only by runtime equivalence tests that catch drift after
it changes behaviour.  spongelint proves those contracts at the AST
level, at review time (see ``docs/linting.md`` for the rule catalog):

* **inline-drift** — ``# spongelint: inline-of <target>`` markers on
  every inlined copy; strict copies must alpha-match the canonical
  function's body, transformed copies pin the canonical's normalized
  fingerprint so changing the canonical forces re-verification;
* **determinism** — no wall-clock reads, no unseeded global RNG, no
  set-iteration feeding accumulation inside the ``serving``/``core``
  hot paths (accumulation order is load-bearing for bit-identity);
* **scan-purity** — functions handed to ``lax.scan``/``jax.jit`` must
  not mutate enclosing state, perform I/O, or call non-whitelisted
  host callbacks;
* **deprecation-hygiene** — non-test code must not import the
  deprecated ``serving.simulator`` / ``serving.engine`` /
  ``core.multidim`` shims.

Usage::

    python -m tools.spongelint src [more paths...]
    python -m tools.spongelint --list-rules
    python -m tools.spongelint --print-pin repro.core.scaler.SpongeScaler.decide

Per-line suppression: ``# spongelint: disable=<rule> -- reason``.
Framework: stdlib ``ast``/``tokenize`` only, no dependencies.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence

from tools.spongelint.markers import Directives, parse_directives
from tools.spongelint.resolve import TargetResolver

REPO = Path(__file__).resolve().parent.parent.parent
DEFAULT_ROOTS = (REPO / "src", REPO)


@dataclass(frozen=True)
class Finding:
    """One lint finding, file/line-anchored."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: " \
               f"{self.rule}: {self.message}"

    def sort_key(self):
        return (self.path, self.line, self.col, self.rule)


@dataclass
class FileContext:
    """Everything a rule needs about one source file."""
    path: Path                        # absolute
    rel: str                          # as reported in findings
    source: str
    tree: ast.Module
    directives: Directives
    resolver: TargetResolver

    def finding(self, node_or_line, rule: str, message: str) -> Finding:
        if isinstance(node_or_line, int):
            line, col = node_or_line, 0
        else:
            line = getattr(node_or_line, "lineno", 0)
            col = getattr(node_or_line, "col_offset", 0)
        return Finding(self.rel, line, col, rule, message)

    @property
    def parts(self) -> tuple:
        return self.path.parts


RuleFn = Callable[[FileContext], Iterable[Finding]]
RULES: Dict[str, "Rule"] = {}


@dataclass
class Rule:
    """A registered rule: its suppression id, one-line summary, and
    the check function run per file."""
    name: str
    summary: str
    check: RuleFn


def rule(name: str, summary: str):
    """Register a rule function under ``name`` (the suppression id)."""
    def deco(fn: RuleFn) -> RuleFn:
        RULES[name] = Rule(name, summary, fn)
        return fn
    return deco


def iter_py_files(paths: Sequence[Path]) -> List[Path]:
    """Expand files/directories to a sorted list of ``.py`` files."""
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(f for f in sorted(p.rglob("*.py"))
                       if "__pycache__" not in f.parts)
        elif p.suffix == ".py":
            out.append(p)
    return out


def lint_file(path: Path, resolver: TargetResolver, *,
              rel: Optional[str] = None,
              select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run every (selected) rule over one file, apply suppressions."""
    path = Path(path).resolve()
    if rel is None:
        try:
            rel = str(path.relative_to(Path.cwd()))
        except ValueError:
            rel = str(path)
    source = path.read_text(encoding="utf-8")
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as e:
        return [Finding(rel, e.lineno or 0, e.offset or 0, "parse-error",
                        f"cannot parse: {e.msg}")]
    directives = parse_directives(source)
    ctx = FileContext(path=path, rel=rel, source=source, tree=tree,
                      directives=directives, resolver=resolver)
    findings: List[Finding] = [
        ctx.finding(line, "bad-directive", msg)
        for line, msg in directives.errors]
    names = list(select) if select else list(RULES)
    for name in names:
        if name not in RULES:
            raise KeyError(f"unknown rule {name!r}; known: {sorted(RULES)}")
        findings.extend(RULES[name].check(ctx))
    for line, rules in directives.suppressions.items():
        unknown = rules - set(RULES) - {"all"}
        for r in sorted(unknown):
            findings.append(ctx.finding(
                line, "bad-directive",
                f"suppression names unknown rule {r!r}"))
    kept = []
    for f in findings:
        sup = directives.suppressions.get(f.line, ())
        if f.rule != "bad-directive" and (f.rule in sup or "all" in sup):
            continue
        kept.append(f)
    return sorted(kept, key=Finding.sort_key)


def lint_paths(paths: Sequence, *, roots: Optional[Sequence] = None,
               select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint every ``.py`` file under ``paths``.

    ``roots`` are the module-resolution roots for ``inline-of`` targets
    (default: the repo's ``src/`` plus the repo root).
    """
    resolver = TargetResolver([Path(r) for r in (roots or DEFAULT_ROOTS)])
    findings: List[Finding] = []
    for f in iter_py_files([Path(p) for p in paths]):
        findings.extend(lint_file(f, resolver, select=select))
    return sorted(findings, key=Finding.sort_key)


# importing the rule modules registers them; this sits at module bottom
# so the modules can import the registry from the partially initialized
# package without a cycle
from tools.spongelint import rules as _rules  # noqa: E402,F401

