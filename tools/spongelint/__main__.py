"""CLI: ``python -m tools.spongelint src [paths...]``.

Exit status 0 when no findings, 1 when findings were reported, 2 on
usage errors.  ``--print-pin`` stamps the normalized fingerprint used
by pinned ``inline-of`` markers (see ``docs/linting.md``).
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from tools.spongelint import (DEFAULT_ROOTS, RULES, lint_paths)
from tools.spongelint.astnorm import fingerprint
from tools.spongelint.resolve import ResolutionError, TargetResolver


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m tools.spongelint",
        description="Sponge-specific AST lint: inline-drift, "
                    "determinism, scan-purity, deprecation-hygiene.")
    parser.add_argument("paths", nargs="*", type=Path,
                        help="files or directories to lint")
    parser.add_argument("--root", action="append", type=Path, default=[],
                        metavar="DIR",
                        help="module-resolution root for inline-of "
                             "targets (repeatable; default: src/ and "
                             "the repo root)")
    parser.add_argument("--select", action="append", default=[],
                        metavar="RULE",
                        help="run only the named rule (repeatable)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    parser.add_argument("--print-pin", metavar="TARGET",
                        help="print the normalized fingerprint of "
                             "module.qualname, for pin= markers")
    args = parser.parse_args(argv)

    roots = args.root or list(DEFAULT_ROOTS)

    if args.list_rules:
        for name in sorted(RULES):
            print(f"{name}: {RULES[name].summary}")
        return 0

    if args.print_pin:
        try:
            _, func = TargetResolver(roots).resolve(args.print_pin)
        except (ResolutionError, OSError, SyntaxError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        print(fingerprint(func))
        return 0

    if not args.paths:
        parser.error("no paths given (try: python -m tools.spongelint src)")

    for name in args.select:
        if name not in RULES:
            parser.error(f"unknown rule {name!r}; known: {sorted(RULES)}")

    findings = lint_paths(args.paths, roots=roots,
                          select=args.select or None)
    for f in findings:
        print(f.render())
    if findings:
        print(f"spongelint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
