"""Benchmark regression gate (``make verify`` / CI).

Reads the ``BENCH_*.json`` trajectory files that
``benchmarks.run.record_bench`` appends (one JSON list of
``{timestamp, commit, metrics}`` entries per benchmark) and fails when
the latest entry regresses:

1. **Savings trajectories** — benches whose metrics dict carries a
   savings-style scalar (``tenant.savings``,
   ``uncertainty.core_seconds_saved``) must not fall more than
   ``SAVINGS_REGRESSION`` (10%) below the best value ever recorded in
   the trajectory.
2. **Throughput rows** — harness-recorded row lists
   (``[name, us_per_call, derived]``) whose derived string carries a
   ``speedup=<x>x`` or ``acc_goodput_gain=<x>x`` figure must stay at
   or above its floor: the generic ``MIN_SPEEDUP`` (the repo's 10x
   fast-vs-exact bar, mirroring ``benchmarks/throughput_bench.py``)
   or a per-row floor from ``ROW_FLOORS`` (``throughput_vector*``
   rows — the batched-tick vectorpath engine — must hold >=100x;
   ``degrade*`` rows — the (m, n, c, b) planner's accuracy-weighted
   goodput vs the top fixed rung — must hold >=1x, i.e. the planner
   never loses to the rung it degrades from).

A missing trajectory file is a *notice*, not a failure — benches only
record on machines that ran them; the gate protects whatever history
exists.  Exit code 0 when clean; 1 with a findings list otherwise.

    PYTHONPATH=src python tools/bench_gate.py
    python tools/bench_gate.py --root /tmp/other-checkout
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
# bench name -> dotted path of the savings-style scalar in its metrics
SAVINGS_KEYS = {
    "tenant": "savings",
    "uncertainty": "core_seconds_saved",
}
SAVINGS_REGRESSION = 0.10     # latest may trail the best by at most 10%
MIN_SPEEDUP = 10.0            # fast-vs-exact bar (throughput_bench)
# per-row floors by row-name prefix: rows the generic bar is wrong
# for.  The vectorized batched-tick engine (ISSUE 8) must hold >=100x
# over the pre-refactor loop, not merely the 10x fast-path bar; the
# degradation planner (ISSUE 9) reports accuracy-weighted-goodput
# gains over the top fixed rung, where breaking even is the bar.
ROW_FLOORS = {
    "throughput_vector": 100.0,
    "degrade": 1.0,
}
_SPEEDUP = re.compile(r"(?:speedup|acc_goodput_gain)=([0-9.]+)x")


def _row_floor(name: str) -> float:
    """The speedup floor for a bench row: a ``ROW_FLOORS`` prefix match
    (longest wins) or the generic ``MIN_SPEEDUP`` bar."""
    best = MIN_SPEEDUP
    best_len = -1
    for prefix, floor in ROW_FLOORS.items():
        if name.startswith(prefix) and len(prefix) > best_len:
            best, best_len = floor, len(prefix)
    return best


def _dig(metrics: dict, dotted: str):
    """Resolve a dotted key path in a metrics dict (None if absent)."""
    cur = metrics
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _load(path: Path):
    entries = json.loads(path.read_text(encoding="utf-8"))
    if not isinstance(entries, list) or not entries:
        raise ValueError(f"{path.name}: expected a non-empty JSON list")
    return entries


def check_length(path: Path) -> list[str]:
    """A trajectory with fewer than 2 records cannot regress *yet* —
    emit the named ``short-trajectory`` notice so a wiped or freshly
    seeded history is visible instead of silently passing the gate."""
    entries = _load(path)
    if len(entries) < 2:
        return [f"{path.name}: short-trajectory ({len(entries)} record(s) "
                "— regression gating needs at least 2)"]
    return []


def check_savings(path: Path, key: str) -> list[str]:
    """Latest savings must be within SAVINGS_REGRESSION of the best."""
    entries = _load(path)
    vals = [v for v in (_dig(e.get("metrics", {}), key) for e in entries)
            if isinstance(v, (int, float))]
    if not vals:
        return [f"{path.name}: no entry carries metrics.{key}"]
    best, latest = max(vals), vals[-1]
    if latest < best * (1.0 - SAVINGS_REGRESSION):
        return [f"{path.name}: metrics.{key} regressed to {latest:.4f} "
                f"(best {best:.4f}, floor "
                f"{best * (1.0 - SAVINGS_REGRESSION):.4f})"]
    return []


def check_speedups(path: Path) -> list[str]:
    """Every speedup figure in the latest row-list entry meets the bar."""
    entries = _load(path)
    metrics = entries[-1].get("metrics")
    if not isinstance(metrics, list):
        return []                      # dict-metrics bench: no rows here
    problems = []
    for row in metrics:
        derived = str(row[-1]) if isinstance(row, (list, tuple)) else ""
        name = str(row[0]) if isinstance(row, (list, tuple)) and row else "?"
        floor = _row_floor(name)
        for m in _SPEEDUP.finditer(derived):
            speedup = float(m.group(1))
            if speedup < floor:
                problems.append(
                    f"{path.name}: {name} speedup "
                    f"{speedup:.1f}x below the {floor:.0f}x bar")
    return problems


def run_gate(root: Path) -> tuple[list[str], list[str]]:
    """Returns ``(problems, notices)`` over every BENCH_*.json in root."""
    problems: list[str] = []
    notices: list[str] = []
    seen = set()
    for name, key in SAVINGS_KEYS.items():
        path = root / f"BENCH_{name}.json"
        seen.add(path.name)
        if not path.exists():
            notices.append(f"{path.name}: not recorded here (skipped)")
            continue
        try:
            problems += check_savings(path, key)
            notices += check_length(path)
        except (ValueError, json.JSONDecodeError) as e:
            problems.append(f"{path.name}: unreadable ({e})")
    for path in sorted(root.glob("BENCH_*.json")):
        if path.name in seen:
            continue
        try:
            problems += check_speedups(path)
            notices += check_length(path)
        except (ValueError, json.JSONDecodeError) as e:
            problems.append(f"{path.name}: unreadable ({e})")
    return problems, notices


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", type=Path, default=REPO,
                    help="directory holding the BENCH_*.json files")
    args = ap.parse_args(argv)
    problems, notices = run_gate(args.root)
    for n in notices:
        print(f"bench-gate: note: {n}")
    if problems:
        print("bench-gate: FAILED")
        for p in problems:
            print(f"  - {p}")
        return 1
    n_checked = len(list(args.root.glob("BENCH_*.json")))
    print(f"bench-gate: OK ({n_checked} trajectories checked, "
          f"{len(notices)} notice(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
