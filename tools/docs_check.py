"""Documentation integrity check (``make docs-check``).

Two gates, both cheap enough for every verify run:

1. **Link integrity** — every relative markdown link in README.md,
   ARCHITECTURE.md and docs/*.md must resolve to an existing file
   (fragments are stripped; http(s)/mailto links are skipped).
2. **Docstring coverage** — every public class and function defined in
   ``repro.serving.api`` (the serving contract surface) must carry a
   docstring, as must the scenario registry's public surface.

Exit code 0 when clean; 1 with a findings list otherwise.

    PYTHONPATH=src python tools/docs_check.py
"""
from __future__ import annotations

import inspect
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = [REPO / "README.md", REPO / "ARCHITECTURE.md",
             *sorted((REPO / "docs").glob("*.md"))]
# [text](target) — excluding images; target split from optional title
_LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")

DOCSTRING_MODULES = ["repro.serving.api", "repro.serving.scenarios",
                     "repro.serving.fastpath", "repro.core.cost_model",
                     "repro.serving.token_backend", "repro.serving.fleet",
                     "repro.serving.session", "repro.serving.tenancy",
                     "repro.core.uncertainty", "repro.core.degradation",
                     "tools.spongelint"]


def check_links() -> list[str]:
    problems = []
    for doc in DOC_FILES:
        if not doc.exists():
            problems.append(f"{doc.relative_to(REPO)}: file missing")
            continue
        for m in _LINK.finditer(doc.read_text(encoding="utf-8")):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (doc.parent / path).resolve()
            if not resolved.exists():
                problems.append(f"{doc.relative_to(REPO)}: broken link "
                                f"-> {target}")
    return problems


def check_docstrings() -> list[str]:
    problems = []
    sys.path.insert(0, str(REPO / "src"))
    sys.path.insert(0, str(REPO))        # for the tools.* packages
    for modname in DOCSTRING_MODULES:
        try:
            mod = __import__(modname, fromlist=["_"])
        except Exception as e:           # pragma: no cover
            problems.append(f"{modname}: import failed ({e!r})")
            continue
        if not (mod.__doc__ or "").strip():
            problems.append(f"{modname}: missing module docstring")
        for name, obj in vars(mod).items():
            if name.startswith("_"):
                continue
            if not (inspect.isclass(obj) or inspect.isfunction(obj)):
                continue
            if getattr(obj, "__module__", None) != modname:
                continue                 # re-exports are checked at home
            if not (inspect.getdoc(obj) or "").strip():
                problems.append(f"{modname}.{name}: public "
                                f"{'class' if inspect.isclass(obj) else 'function'}"
                                " missing docstring")
    return problems


def main() -> int:
    problems = check_links() + check_docstrings()
    if problems:
        print("docs-check: FAILED")
        for p in problems:
            print(f"  - {p}")
        return 1
    n_links = sum(len(_LINK.findall(d.read_text(encoding='utf-8')))
                  for d in DOC_FILES if d.exists())
    print(f"docs-check: OK ({len(DOC_FILES)} docs, {n_links} links, "
          f"{len(DOCSTRING_MODULES)} modules docstring-complete)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
