"""Quickstart: the unified Sponge serving API in ~40 lines.

Builds the paper's performance model, composes a SpongeServer (policy +
backend + runner), submits requests whose network latency ate part of the
SLO, and watches the scaler pick (cores, batch) via the Integer Program
(Algorithm 1) while the runner serves them.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.perf_model import fit_table1
from repro.core.slo import Request
from repro.serving.api import make_sim_server

# 1. performance model l(b, c) fitted on the paper's Table 1 measurements
perf = fit_table1()
print(f"l(b=4, c=8) = {perf.latency(4, 8)*1e3:.1f} ms "
      f"(paper measured: 37 ms)")

# 2. one call wires the whole control plane: IP-solver policy + simulated
#    execution backend + the event-loop runner
server = make_sim_server(perf, "sponge", c0=1, prior_rps=100.0)

# 3. requests whose network latency ate part of the end-to-end SLO — the
#    dynamic-SLO quantity the scaler must react to
reqs = [Request.make(arrival=0.0, comm_latency=cl, slo=1.0)
        for cl in (0.05, 0.30, 0.60, 0.12, 0.45)]
print(f"remaining budgets: {[round(r.slo - r.comm_latency, 2) for r in reqs]}")

# 4. run the scenario; the runner feeds the EDF queue, the scaler solves
#    the IP each adaptation interval, the backend applies the in-place
#    vertical resize (no cold start) and executes batches
report = server.run(reqs, horizon=5.0)

t0, first = report.decisions[0]
print(f"first decision: c={first.c} cores, b={first.b}, "
      f"feasible={first.feasible} "
      f"({first.solver_iters} IP iterations, {first.solver_time*1e6:.0f} us)")
inst = server.pool[0].instance
print(f"in-place resizes applied: "
      f"{[(e.c_from, e.c_to) for e in inst.resizes]} "
      f"(penalty {inst.resize_penalty*1e3:.1f} ms each; "
      f"a horizontal cold start is ~10 s)")
print(f"served {report.n_requests} requests, "
      f"violations={report.n_violations}, p99={report.p99*1e3:.0f} ms, "
      f"core-seconds={report.core_seconds:.2f}")
