"""Quickstart: the Sponge control plane in ~40 lines.

Builds the paper's performance model, submits requests with dynamic
network-dependent SLO budgets, and watches the scaler pick (cores, batch)
via the Integer Program (Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.perf_model import fit_table1
from repro.core.queueing import EDFQueue
from repro.core.scaler import SpongeScaler
from repro.core.slo import Request

# 1. performance model l(b, c) fitted on the paper's Table 1 measurements
perf = fit_table1()
print(f"l(b=4, c=8) = {perf.latency(4, 8)*1e3:.1f} ms "
      f"(paper measured: 37 ms)")

# 2. EDF queue with requests whose network latency ate part of the SLO
queue = EDFQueue()
for i, comm_latency in enumerate([0.05, 0.30, 0.60, 0.12, 0.45]):
    queue.push(Request.make(arrival=0.0, comm_latency=comm_latency, slo=1.0))
print(f"queue remaining budgets: "
      f"{[round(r, 2) for r in queue.snapshot_remaining(0.0)]}")

# 3. the scaler solves the IP: minimal cores + batch meeting every deadline
scaler = SpongeScaler(perf)
decision = scaler.decide(now=0.0, queue=queue, lam=100.0)
print(f"scaler decision: c={decision.c} cores, b={decision.b}, "
      f"feasible={decision.feasible} "
      f"({decision.solver_iters} IP iterations, "
      f"{decision.solver_time*1e6:.0f} us)")

# 4. in-place vertical scaling: apply without cold start
from repro.core.vertical import VerticalScaledInstance
inst = VerticalScaledInstance(range(1, 17), range(1, 17), perf, c0=1)
penalty = inst.resize(decision.c, now=0.0)
print(f"resized 1 -> {inst.c} cores in-place "
      f"(penalty {penalty*1e3:.1f} ms; a horizontal cold start is ~10 s)")
print(f"batch of {decision.b} now serves in "
      f"{inst.latency(decision.b)*1e3:.0f} ms")
