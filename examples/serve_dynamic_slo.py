"""End-to-end serving driver (deliverable b): a real reduced model served
with batched requests behind the full Sponge pipeline — EDF queue, dynamic
batching, IP-solver scaler, executable-table vertical scaling — under a
synthetic 4G bandwidth trace.

    PYTHONPATH=src python examples/serve_dynamic_slo.py \
        [--arch smollm-135m-reduced] [--rps 12] [--duration 8]
"""
import argparse
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or [])
from repro.launch.serve import main  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m-reduced")
    ap.add_argument("--rps", type=float, default=12.0)
    ap.add_argument("--duration", type=float, default=8.0)
    a = ap.parse_args()
    main(["--mode", "live", "--arch", a.arch, "--rps", str(a.rps),
          "--duration", str(a.duration), "--slo", "3.0",
          "--prompt-len", "16", "--gen-tokens", "4"])
