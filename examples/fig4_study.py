"""The paper's Fig. 4 study as a runnable example: Sponge vs FA2 vs static
instances on a 10-minute 4G trace (discrete-event simulation calibrated
with the YOLOv5s-class perf model).

    PYTHONPATH=src python examples/fig4_study.py [--duration 600]
"""
import argparse

from repro.launch.serve import main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=600.0)
    ap.add_argument("--rps", type=float, default=20.0)
    a = ap.parse_args()
    main(["--mode", "sim", "--duration", str(a.duration),
          "--rps", str(a.rps)])
