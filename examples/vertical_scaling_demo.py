"""In-place vertical scaling under a live bandwidth squeeze.

Simulates a network fade mid-run and shows the scaler reacting within one
adaptation interval (vs a 10 s horizontal cold start), printing the (c, b)
trajectory and per-request outcomes — all through the unified serving API.

    PYTHONPATH=src python examples/vertical_scaling_demo.py
"""
import numpy as np

from repro.core.perf_model import yolov5s_like
from repro.core.slo import Request
from repro.serving.api import make_sim_server

perf = yolov5s_like()
server = make_sim_server(perf, "sponge", c0=12, prior_rps=20.0)

# 60 s of traffic; the network fades hard between t=20 and t=30
reqs = []
rng = np.random.default_rng(0)
for i in range(20 * 60):
    ts = i / 20.0
    cl = 0.55 if 20 <= ts < 30 else 0.08
    reqs.append(Request.make(arrival=ts + cl, comm_latency=cl, slo=1.0))
res = server.run(reqs, horizon=70)

print("time  ->  (cores, batch) decisions around the fade:")
for t, d in res.decisions:
    if 16 <= t <= 34 and int(t) == t:
        marker = " <= fade" if 20 <= t < 30 else ""
        print(f"  t={t:5.1f}s  c={d.c:2d}  b={d.b:2d}  "
              f"feasible={d.feasible}{marker}")
inst = server.pool[0].instance
print(f"\nresizes: {len(inst.resizes)}; "
      f"violations: {res['n_violations']}/{res['n_requests']} "
      f"({res['violation_rate']*100:.2f}%)")
print(f"avg allocated cores: {res['avg_cores']:.1f} "
      f"(static worst-case would hold 16 throughout)")
