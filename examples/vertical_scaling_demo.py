"""In-place vertical scaling under a live bandwidth squeeze.

Simulates a network fade mid-run and shows the scaler reacting within one
adaptation interval (vs a 10 s horizontal cold start), printing the (c, b)
trajectory and per-request outcomes.

    PYTHONPATH=src python examples/vertical_scaling_demo.py
"""
import numpy as np

from repro.core.baselines import SpongePolicy
from repro.core.perf_model import yolov5s_like
from repro.core.scaler import SpongeScaler
from repro.core.slo import Request
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.serving.simulator import ClusterSimulator

perf = yolov5s_like()
scaler = SpongeScaler(perf)
sim = ClusterSimulator(perf, SpongePolicy(scaler), DEFAULT_C, DEFAULT_B,
                       c0=12)
sim.monitor.rate.prior_rps = 20

# 60 s of traffic; the network fades hard between t=20 and t=30
reqs = []
rng = np.random.default_rng(0)
for i in range(20 * 60):
    ts = i / 20.0
    cl = 0.55 if 20 <= ts < 30 else 0.08
    reqs.append(Request.make(arrival=ts + cl, comm_latency=cl, slo=1.0))
res = sim.run(reqs, horizon=70)

print("time  ->  (cores, batch) decisions around the fade:")
for t, d in scaler.decisions:
    if 16 <= t <= 34 and int(t) == t:
        marker = " <= fade" if 20 <= t < 30 else ""
        print(f"  t={t:5.1f}s  c={d.c:2d}  b={d.b:2d}  "
              f"feasible={d.feasible}{marker}")
inst = sim.pool[0].instance
print(f"\nresizes: {len(inst.resizes)}; "
      f"violations: {res['n_violations']}/{res['n_requests']} "
      f"({res['violation_rate']*100:.2f}%)")
print(f"avg allocated cores: {res['avg_cores']:.1f} "
      f"(static worst-case would hold 16 throughout)")
