"""Train a ~1M-param SmolLM-family model for a few hundred steps on the
synthetic pipeline and checkpoint it (deliverable b, training driver).
The identical code path drives the full 135M config on real hardware.

    PYTHONPATH=src python examples/train_smollm.py [--steps 200]
"""
import argparse

from repro.launch.train import main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt", default="out/smollm_ckpt")
    a = ap.parse_args()
    main(["--arch", "smollm-135m-reduced", "--steps", str(a.steps),
          "--batch", "8", "--seq", "64", "--lr", "1e-3",
          "--ckpt", a.ckpt, "--log-every", "20"])
