PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify smoke fig4 bench

# tier-1 verification (the ROADMAP contract)
verify:
	$(PY) -m pytest -x -q

# fast end-to-end smoke of the unified serving API on both backends (<30 s)
smoke:
	$(PY) benchmarks/smoke.py

# the paper's headline study
fig4:
	$(PY) -m benchmarks.run --only fig4

# full benchmark harness
bench:
	$(PY) -m benchmarks.run
