PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify test-fast smoke perf-smoke fig4 bench throughput \
	token-bench fleet-bench session-bench tenant-bench \
	uncertainty-bench degrade-bench docs-check bench-gate lint help

# tier-1 verification (the ROADMAP contract) + the benchmark
# regression gate over recorded BENCH_*.json trajectories + the
# repo-specific AST lint (docs/linting.md)
# companions: `make docs-check` (doc gates) and `make throughput`
# (the million-request control-plane benchmark) — see `make help`
verify:
	$(PY) -m pytest -x -q
	$(PY) tools/bench_gate.py
	$(PY) -m tools.spongelint src tools benchmarks

# spongelint (inline-drift / determinism / scan-purity /
# deprecation-hygiene — docs/linting.md) + the ruff F+I baseline
# (pyproject.toml); ruff is skipped with a note when not installed
lint:
	$(PY) -m tools.spongelint src tools benchmarks
	@if command -v ruff >/dev/null 2>&1; then \
		ruff check src tests tools benchmarks; \
	else \
		echo "lint: ruff not installed here — CI runs it (pip install ruff)"; \
	fi

# the fast tier-1 subset: control plane, solvers, scenarios, fleet —
# no model builds, no kernel interpret-mode sweeps (a couple of minutes)
test-fast:
	$(PY) -m pytest -x -q tests/test_solver.py tests/test_solver_properties.py \
		tests/test_queueing.py tests/test_network.py tests/test_perf_model.py \
		tests/test_fastpath.py tests/test_vectorpath.py tests/test_scanpath.py \
		tests/test_scenarios.py tests/test_fleet.py \
		tests/test_determinism.py tests/test_session.py tests/test_tenancy.py \
		tests/test_uncertainty.py tests/test_bench_gate.py \
		tests/test_public_api.py

# fast end-to-end smoke of the unified serving API on both backends (<30 s)
smoke:
	$(PY) benchmarks/smoke.py

# 200k-request vectorpath-only run with an absolute events/s floor —
# CI-sized canary against a de-vectorized hot path (docs/performance.md)
perf-smoke:
	$(PY) -m benchmarks.throughput_bench --smoke

# the paper's headline study
fig4:
	$(PY) -m benchmarks.run --only fig4

# 10,000,000-request scenario at a 50 ms control cadence: fast-engine
# (>=10x bar) and vectorpath (>=100x bar) events/s vs the pre-refactor
# loop + memoized-solver hit rate; records BENCH_throughput.json
throughput:
	$(PY) -m benchmarks.throughput_bench

# 100k-request autoregressive continuous-batching benchmark + the
# real-kernel TokenJaxBackend slice (tokens/s, TTFT p99, TBT violations)
token-bench:
	$(PY) -m benchmarks.token_serving_bench

# 500k-request fleet benchmark: joint (n, c, b) scaling across >=8
# replicas vs a static fleet (asserts the >=20% core-seconds bar)
fleet-bench:
	$(PY) -m benchmarks.fleet_bench

# 100k+-request online-session benchmark: mid-flight SLO renegotiation
# + cancel storms through the session API (asserts the >=100k bar and
# the decision-stream delta vs the closed-world replay)
session-bench:
	$(PY) -m benchmarks.session_bench

# >=200k-request multi-tenant benchmark: the 128-core shared pool with
# marginal-value core swapping vs per-tenant static partitions (asserts
# the >=20% core-seconds bar at equal-or-lower per-tenant violations;
# appends the run to BENCH_tenant.json)
tenant-bench:
	$(PY) -m benchmarks.tenant_bench

# 100k+-request distribution-aware admission benchmark: quantile
# planning + cancel-on-overrun vs the deterministic-cost scaler on
# heavy-tailed decode lengths (asserts strictly fewer violations at
# equal-or-lower core-seconds; appends to BENCH_uncertainty.json)
uncertainty-bench:
	$(PY) -m benchmarks.uncertainty_bench

# degrade-under-pressure benchmark: the (m, n, c, b) planner vs every
# fixed ladder rung on the three degradation scenarios (asserts the
# planner beats the top rung on accuracy-weighted goodput at
# equal-or-lower core-seconds; appends to BENCH_degrade.json)
degrade-bench:
	$(PY) -m benchmarks.run --only degrade

# doc link integrity + serving-API docstring coverage
docs-check:
	$(PY) tools/docs_check.py

# benchmark regression gate over recorded BENCH_*.json trajectories
bench-gate:
	$(PY) tools/bench_gate.py

# full benchmark harness
bench:
	$(PY) -m benchmarks.run

help:
	@echo "make verify      - tier-1 test suite (pytest)"
	@echo "make test-fast   - fast tier-1 subset (control plane + solvers)"
	@echo "make smoke       - <30s end-to-end smoke, both backends"
	@echo "make perf-smoke  - 200k-request vectorpath canary (events/s floor)"
	@echo "make fig4        - the paper's headline study"
	@echo "make throughput  - 10M-request control-plane benchmark (>=10x/>=100x bars)"
	@echo "make token-bench - 100k-request autoregressive serving benchmark"
	@echo "make fleet-bench - 500k-request fleet benchmark (>=20% savings bar)"
	@echo "make session-bench - 100k+-request online-session benchmark"
	@echo "make tenant-bench - 200k+-request multi-tenant pool benchmark"
	@echo "make uncertainty-bench - 100k+-request distribution-aware admission benchmark"
	@echo "make degrade-bench - (m, n, c, b) planner vs fixed-model fleets"
	@echo "make lint        - spongelint (AST contracts) + ruff baseline"
	@echo "make docs-check  - doc links + serving-API docstring coverage"
	@echo "make bench-gate  - regression gate over BENCH_*.json trajectories"
	@echo "make bench       - full benchmark harness"
