"""Malformed / unknown directives are themselves findings."""
# spongelint: disable=not-a-rule
X = 1
# spongelint: frobnicate everything
Y = 2
