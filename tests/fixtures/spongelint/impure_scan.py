"""Seeded scan-purity violations: the step closes over and mutates
module state, and prints from inside the traced function."""
import jax
from jax import lax

log = []


def step(carry, x):
    log.append(x)
    print("tick")
    return carry + x, x


def run(xs):
    out, _ = lax.scan(step, 0.0, xs)
    return jax.jit(lambda y: y)(out)
