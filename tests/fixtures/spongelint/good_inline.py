"""A faithful alpha-renamed inline of fixpkg.canonical.window_rate."""


def fast_loop(n, s, p):
    # spongelint: inline-of fixpkg.canonical.window_rate stmts=3
    if n == 0:
        o = 0.0
    else:
        o = n / s
    if p <= 0:
        return o
    return 0.5 * o + 0.5 * p
