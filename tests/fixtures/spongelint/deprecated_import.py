"""Seeded deprecation-hygiene violations (fixture, not a test file)."""
import repro.core.multidim
from repro.serving import simulator
from repro.serving.engine import ServingEngine


def build():
    return ServingEngine, simulator, repro.core.multidim
