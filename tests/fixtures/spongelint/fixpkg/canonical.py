"""Canonical function for the inline-drift fixtures."""


def window_rate(count, span, prior):
    """Canonical observed-rate blend (fixture)."""
    if count == 0:
        obs = 0.0
    else:
        obs = count / span
    if prior <= 0:
        return obs
    return 0.5 * obs + 0.5 * prior
