"""Seeded determinism violations (fixture — under a serving/ path)."""
import random
import time

import numpy as np


def now_badly():
    return time.time()


def jitter():
    return random.random() + np.random.rand()


def rng():
    return np.random.default_rng()


def total(vals):
    acc = 0.0
    for v in {1.0, 2.0, 3.0}:
        acc += v
    return acc + sum(x for x in set(vals))
