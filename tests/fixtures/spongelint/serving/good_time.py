"""Determinism-clean twin of bad_time.py: telemetry clocks and seeded
generators are legal in hot paths."""
import time

import numpy as np


def timed():
    t0 = time.perf_counter()
    rng = np.random.default_rng(42)
    vals = sorted([3.0, 1.0])
    acc = 0.0
    for v in vals:
        acc += v
    return acc + float(rng.standard_normal()) + (time.perf_counter() - t0)
