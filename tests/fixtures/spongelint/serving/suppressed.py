"""A deliberate wall-clock read carrying an explicit suppression."""
import time


def stamp():
    return time.time()  # spongelint: disable=determinism -- label only, not scheduling
