"""PartitionSpec rule-fitting invariants (no multi-device needed: specs are
computed from shapes + a mesh description)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.models.api import init_cache, init_params
from repro.models.sharding import cache_specs, param_specs, _fit_spec


class FakeMesh:
    """Duck-typed mesh: only .shape (dict) and .axis_names are consulted."""
    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


MESH = FakeMesh(data=16, model=16)
MESH_MP = FakeMesh(pod=2, data=16, model=16)


def test_fit_spec_divisibility():
    assert _fit_spec(P("model", None), 2, (64, 10), MESH) == P("model", None)
    # 10 doesn't divide 16: dropped
    assert _fit_spec(P(None, "model"), 2, (64, 10), MESH) == P(None, None)
    # tuple axes: prefix that divides survives
    s = _fit_spec(P(("pod", "data"), None), 2, (4, 8), MESH_MP)
    assert s == P(("pod", "data"), None) or s == P("pod", None)


def test_fit_spec_right_alignment():
    # stacked-layer leading dim gets None
    s = _fit_spec(P("model", None), 3, (30, 64, 64), MESH)
    assert s == P(None, "model", None)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["1pod", "2pod"])
def test_param_specs_always_divide(arch, mesh):
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))
    specs = param_specs(shapes, mesh)
    flat_s, _ = jax.tree.flatten(shapes)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for leaf, spec in zip(flat_s, flat_p):
        for dim, ax in zip(leaf.shape, ([None] * (leaf.ndim - len(spec))
                                        + list(spec))):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % n == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "h2o-danube-1.8b",
                                  "rwkv6-1.6b", "whisper-large-v3"])
def test_cache_specs_divide(arch):
    cfg = get_config(arch)
    cache = jax.eval_shape(lambda: init_cache(cfg, 128, 32768))
    for seq_shard in (False, True):
        specs = cache_specs(cache, MESH, seq_shard=seq_shard)
        for leaf, spec in zip(jax.tree.leaves(cache),
                              jax.tree.leaves(specs,
                                              is_leaf=lambda x:
                                              isinstance(x, P))):
            pads = [None] * (leaf.ndim - len(spec)) + list(spec)
            for dim, ax in zip(leaf.shape, pads):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = int(np.prod([MESH.shape[a] for a in axes]))
                assert dim % n == 0, (arch, leaf.shape, spec, seq_shard)


def test_serving_specs_drop_fsdp():
    cfg = get_config("rwkv6-1.6b")
    shapes = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))
    specs = param_specs(shapes, MESH, fsdp=False)
    for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
        for ax in spec:
            axes = ax if isinstance(ax, tuple) else (ax,)
            assert "data" not in axes and "pod" not in axes


def test_moe_experts_keep_two_axis_sharding_when_serving():
    cfg = get_config("kimi-k2-1t-a32b")
    shapes = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))
    specs = param_specs(shapes, MESH, fsdp=False)
    # find an expert weight spec: groups[1] moe wg has rank 4 (L,E,d,f)
    moe_spec = specs["groups"][1]["moe"]["wg"]
    flat = [a for ax in moe_spec if ax is not None
            for a in (ax if isinstance(ax, tuple) else (ax,))]
    assert "model" in flat and "data" in flat
