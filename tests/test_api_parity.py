"""Unified serving API: protocol conformance, sim/live parity, c-rounding.

The parity contract (ISSUE 1): the same workload script + the same policy
pushed through ``SimBackend`` and ``JaxBackend`` must produce the same
decision sequence and the same bucket choices — latencies may differ.
``JaxBackend(clock="modeled")`` advances virtual time by the shared
PerfModel prediction, which makes the two event streams identical while
the jitted table still executes for real.
"""
import numpy as np

from repro.core.baselines import FA2Policy, SpongePolicy
from repro.core.perf_model import PerfModel
from repro.core.scaler import SpongeScaler
from repro.core.slo import Decision, Request
from repro.serving.api import (ExecutionBackend, JaxBackend, RunReport,
                               SchedulingPolicy, SimBackend, SpongeServer,
                               make_sim_server, pad_vectors, round_up_c,
                               toy_step_fns)

C_SET = B_SET = (1, 2, 4)
DIM = 16
PERF = PerfModel(gamma=0.030, eps=0.010, delta=0.002, eta=0.004)


def _script(n=60, rps=15.0, seed=0, dim=DIM, payloads=True):
    rng = np.random.default_rng(seed)          # comm-latency draws only —
    rng_pay = np.random.default_rng(seed + 1)  # payloads use their own rng
    out = []                                   # so both variants see the
    for i in range(n):                         # same arrival schedule
        ts = i / rps
        cl = float(rng.uniform(0.02, 0.25))
        req = Request.make(arrival=ts + cl, comm_latency=cl, slo=0.6)
        out.append((req, rng_pay.standard_normal(dim).astype(np.float32))
                   if payloads else req)
    return out


def _jax_server(policy, clock="modeled", prior_rps=15.0):
    fns = toy_step_fns(C_SET, B_SET, dim=DIM)
    backend = JaxBackend(fns, pad_vectors, PERF, clock=clock, c0=1)
    return SpongeServer(policy, backend, prior_rps=prior_rps)


def test_protocols_are_satisfied():
    assert isinstance(SpongePolicy(SpongeScaler(PERF)), SchedulingPolicy)
    assert isinstance(FA2Policy(PERF), SchedulingPolicy)
    assert isinstance(SpongeScaler(PERF), SchedulingPolicy)
    assert isinstance(SimBackend(PERF, C_SET, B_SET), ExecutionBackend)


def test_sim_jax_decision_and_bucket_parity():
    pol_sim = SpongePolicy(SpongeScaler(PERF, c_set=C_SET, b_set=B_SET))
    pol_jax = SpongePolicy(SpongeScaler(PERF, c_set=C_SET, b_set=B_SET))
    sim = make_sim_server(PERF, pol_sim, c_set=C_SET, b_set=B_SET, c0=1,
                          prior_rps=15.0, resize_penalty=0.0)
    jax_srv = _jax_server(pol_jax)
    jax_srv.backend.resize_penalty = 0.0
    r_sim = sim.run(_script(payloads=False), horizon=8.0)
    r_jax = jax_srv.run(_script(), horizon=8.0)

    d_sim = [(t, d.c, d.b, d.feasible) for t, d in r_sim.decisions]
    d_jax = [(t, d.c, d.b, d.feasible) for t, d in r_jax.decisions]
    assert d_sim == d_jax, "decision sequences diverged"
    assert r_sim.buckets == r_jax.buckets, "bucket choices diverged"
    assert r_sim.n_requests == r_jax.n_requests == 60
    # and the live path really executed: every request has a result
    assert all(it.result is not None for it in jax_srv.backend.results)


def test_jax_backend_measured_clock_serves_everything():
    pol = SpongePolicy(SpongeScaler(PERF, c_set=C_SET, b_set=B_SET,
                                    adaptation_interval=0.5))
    srv = _jax_server(pol, clock="measured")
    report = srv.run(_script(n=30), horizon=10.0)
    assert report.n_requests == 30
    assert report.backend == "jax"
    assert len(srv.backend.measured) > 0
    assert len(srv.monitor.perf_residuals) == len(srv.backend.measured)


def test_fa2_multi_instance_on_live_backend():
    """The live path models FA2-style horizontal baselines: one-core
    replicas over the same executable table, replica target via
    Decision.n."""
    pol = FA2Policy(PERF, slo=0.6, expected_rps=40.0, cold_start=0.5,
                    b_set=B_SET, reconfig_interval=1.0)
    srv = _jax_server(pol, prior_rps=40.0)
    report = srv.run(_script(n=80, rps=40.0), horizon=6.0)
    # scale-out engaged mid-run (it scales back down once traffic stops)
    assert max(cores for _, cores in report.core_timeline) > 1, \
        "horizontal scale-out never engaged"
    assert all(s.instance.c == 1 for s in srv.pool + srv.backend.dead)
    assert report.n_requests == 80


def test_decision_replica_fields_default_vertical():
    d = Decision(c=4, b=2)
    assert d.n == 1 and d.scale_up_delay == 0.0


def test_round_up_c_never_rounds_down():
    assert round_up_c((1, 2, 4, 8), 3) == 4
    assert round_up_c((1, 2, 4, 8), 8) == 8
    assert round_up_c((1, 2, 4, 8), 9) == 8       # fallback: max(c_set)
    # the old nearest-with-tiebreak rule picked 2 here — below the
    # solver's feasible c
    assert round_up_c((1, 2, 8), 3) == 8


def test_engine_apply_rounds_up():
    from repro.serving.engine import ServingEngine
    fns = toy_step_fns((1, 2, 8), (1, 2), dim=DIM)
    eng = ServingEngine(fns, SpongeScaler(PERF, c_set=(1, 2, 8),
                                          b_set=(1, 2)), pad_vectors)
    eng.apply(Decision(c=3, b=2), now=0.0)
    assert eng.c == 8, "Decision.c must never round below the feasible c"
    assert eng.b == 2


def test_run_report_is_dict_like():
    sim = make_sim_server(PERF, "sponge", c_set=C_SET, b_set=B_SET,
                          prior_rps=10.0)
    report = sim.run(_script(n=10, rps=10.0, payloads=False), horizon=4.0)
    assert isinstance(report, RunReport)
    assert report["p99"] == report.p99
    assert set(report.as_dict()) == set(report.keys())
    assert report.get("nope", 123) == 123
