"""``lax.scan``-jitted decode-stream prototype (ISSUE 8 satellite).

The scanpath contract is **backend parity**, not replay fidelity: the
pure integer-µs step function must produce bit-identical decision
streams, first-token / finish columns, per-request TBT-violation counts,
core-seconds and step counts whether it runs under ``jax.lax.scan`` +
``jax.jit`` or the NumPy fallback loop.  JAX-side tests skip with a
reason when JAX is not importable — the NumPy fallback is always
exercised.
"""
import numpy as np
import pytest

from repro.core.scaler import SpongeScaler
from repro.core.solver import DEFAULT_B, DEFAULT_C
from repro.serving.scanpath import (HAVE_JAX, ScanDecodeEngine,
                                    make_sponge_decide)
from repro.serving.scenarios import build_scenario

needs_jax = pytest.mark.skipif(
    not HAVE_JAX, reason="jax not importable: numpy fallback is the "
    "only backend here; parity needs both")


def _workload(duration=40, seed=3):
    batch, meta = build_scenario("llm-chat", duration=duration, seed=seed)
    return batch, meta["cost"]


def _run_pair(engine_kw, batch, cost, horizon=None):
    a = ScanDecodeEngine(cost, **engine_kw).run(batch, horizon=horizon,
                                                backend="jax")
    b = ScanDecodeEngine(cost, **engine_kw).run(batch, horizon=horizon,
                                                backend="numpy")
    return a, b


def _assert_parity(a, b):
    assert a["backend"] == "jax" and b["backend"] == "numpy"
    assert a["decisions"] == b["decisions"]
    assert np.array_equal(a["first_tok"], b["first_tok"], equal_nan=True)
    assert np.array_equal(a["finish"], b["finish"], equal_nan=True)
    assert np.array_equal(a["tbt_violations"], b["tbt_violations"])
    assert a["core_seconds"] == b["core_seconds"]
    assert a["steps"] == b["steps"]
    assert a["n_served"] == b["n_served"]


@needs_jax
@pytest.mark.parametrize("chunk", [16, 64])
def test_jax_numpy_parity_static(chunk):
    """Static (c0, b0) knobs, two chunk sizes."""
    batch, cost = _workload()
    a, b = _run_pair(dict(c0=8, b0=8, chunk_steps=chunk), batch, cost)
    _assert_parity(a, b)
    assert a["n_served"] > 0 and a["steps"] > 0


@needs_jax
def test_jax_numpy_parity_dynamic_decide():
    """Chunk-boundary (c, b) decisions via make_sponge_decide: the
    knobs change across chunks (0-d scalars, no retrace) and both
    backends still agree bit-for-bit."""
    batch, cost = _workload(duration=30, seed=7)
    sc = SpongeScaler(cost)
    kw = dict(c0=4, b0=4, chunk_steps=32,
              decide=make_sponge_decide(sc, cost, DEFAULT_C, DEFAULT_B))
    a, b = _run_pair(kw, batch, cost)
    _assert_parity(a, b)
    assert len({(c, bb) for _, c, bb in a["decisions"]}) > 1, \
        "decide hook never changed the knobs — test is vacuous"


@needs_jax
def test_jax_numpy_parity_prefill_allowance():
    """The break-at-first-overflow prefill-prefix semantics must match
    across backends when the allowance actually bites."""
    batch, cost = _workload(duration=25, seed=11)
    allow = int(np.asarray(batch.prompt_tokens).mean() * 2)
    a, b = _run_pair(dict(c0=8, b0=16, chunk_steps=32,
                          prefill_allowance=allow), batch, cost)
    _assert_parity(a, b)


def test_numpy_backend_standalone():
    """The fallback serves the workload end to end without JAX."""
    batch, cost = _workload(duration=30, seed=5)
    out = ScanDecodeEngine(cost, c0=8, b0=8).run(batch, backend="numpy")
    assert out["backend"] == "numpy"
    assert out["n_served"] == int(np.isfinite(out["finish"]).sum())
    assert out["n_served"] > 0
    served = np.isfinite(out["finish"])
    assert np.all(out["first_tok"][served] <= out["finish"][served])
    assert np.all(out["first_tok"][served]
                  >= np.asarray(batch.arrival)[served])
    assert out["core_seconds"] > 0.0


def test_numpy_two_runs_identical():
    batch, cost = _workload(duration=30, seed=9)
    r1 = ScanDecodeEngine(cost, c0=8, b0=8).run(batch, backend="numpy")
    r2 = ScanDecodeEngine(cost, c0=8, b0=8).run(batch, backend="numpy")
    _assert_parity({**r1, "backend": "jax"}, r2)


def test_auto_backend_resolves():
    batch, cost = _workload(duration=15, seed=2)
    out = ScanDecodeEngine(cost, c0=8, b0=8).run(batch, backend="auto")
    assert out["backend"] == ("jax" if HAVE_JAX else "numpy")


def test_horizon_overflow_rejected():
    """int32-µs time: horizons at/over 2^31 µs must refuse, not wrap."""
    batch, cost = _workload(duration=10, seed=1)
    eng = ScanDecodeEngine(cost, c0=8, b0=8)
    with pytest.raises(ValueError, match="2147"):
        eng.run(batch, horizon=2200.0)


def test_jax_backend_refused_when_absent():
    if HAVE_JAX:
        pytest.skip("jax importable here; refusal path needs it absent")
    batch, cost = _workload(duration=10, seed=1)
    with pytest.raises(RuntimeError, match="jax"):
        ScanDecodeEngine(cost, c0=8, b0=8).run(batch, backend="jax")


def test_scan_engine_adapter():
    """TokenFastSimRunner.scan_engine() hands its cost model and current
    allocation to a ScanDecodeEngine."""
    from repro.core.baselines import SpongePolicy
    from repro.serving.fastpath import TokenFastSimRunner

    batch, cost = _workload(duration=20, seed=4)
    runner = TokenFastSimRunner(SpongePolicy(SpongeScaler(cost)), cost,
                                DEFAULT_C, DEFAULT_B, c0=8)
    eng = runner.scan_engine(chunk_steps=32)
    assert eng.cost is cost
    assert eng.c0 == 8 and eng.chunk_steps == 32
    out = eng.run(batch, backend="numpy")
    assert out["n_served"] > 0
