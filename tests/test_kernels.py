"""Pallas kernel shape/dtype sweeps against pure-jnp oracles
(interpret=True on this CPU container; TPU is the target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("b,kv,g,d,s,block_s", [
    (1, 1, 1, 64, 128, 64),
    (2, 3, 4, 64, 256, 64),
    (2, 2, 2, 128, 512, 256),
    (4, 1, 8, 64, 128, 128),     # MQA-style
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, kv, g, d, s, block_s, dtype, rng):
    q = jnp.asarray(rng.standard_normal((b, kv, g, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    lens = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
    out = decode_attention(q, k, v, lens, block_s=block_s)
    ref = decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_decode_attention_length_masking(rng):
    """Tokens beyond the valid length must not influence the output."""
    b, kv, g, d, s = 2, 2, 2, 64, 128
    q = jnp.asarray(rng.standard_normal((b, kv, g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    lens = jnp.asarray([40, 80], jnp.int32)
    out1 = decode_attention(q, k, v, lens, block_s=64)
    k2 = k.at[:, 100:].set(999.0)
    v2 = v.at[:, 100:].set(-999.0)
    out2 = decode_attention(q, k2, v2, lens, block_s=64)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


@pytest.mark.parametrize("b,t,h,d,block_t", [
    (1, 16, 1, 16, 8),
    (2, 64, 3, 32, 16),
    (2, 32, 2, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_sweep(b, t, h, d, block_t, dtype, rng):
    mk = lambda: jnp.asarray(rng.standard_normal((b, t, h, d)) * 0.5, dtype)
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.8, 0.999, (b, t, h, d)), dtype)
    u = jnp.asarray(rng.standard_normal((h, d)) * 0.5, jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((b, h, d, d)) * 0.1, jnp.float32)
    y1, sf1 = rwkv6_scan(r, k, v, w, u, s0, block_t=block_t)
    y2, sf2 = rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(sf1), np.asarray(sf2),
                               **tol(dtype))


def test_rwkv6_state_continuation(rng):
    """Scanning [0:T] equals scanning [0:T/2] then [T/2:T] with the carried
    state (the prefill->decode handoff property)."""
    b, t, h, d = 1, 32, 2, 16
    mk = lambda: jnp.asarray(rng.standard_normal((b, t, h, d)) * 0.5,
                             jnp.float32)
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.9, 0.999, (b, t, h, d)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, d)), jnp.float32)
    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    y_all, s_all = rwkv6_scan(r, k, v, w, u, s0, block_t=8)
    half = t // 2
    y1, s1 = rwkv6_scan(r[:, :half], k[:, :half], v[:, :half], w[:, :half],
                        u, s0, block_t=8)
    y2, s2 = rwkv6_scan(r[:, half:], k[:, half:], v[:, half:], w[:, half:],
                        u, s1, block_t=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_all), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all), atol=1e-5)


@pytest.mark.parametrize("b,t,h,p,n,chunk", [
    (1, 16, 1, 16, 8, 8),
    (2, 64, 3, 32, 16, 16),
    (2, 128, 2, 64, 64, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, t, h, p, n, chunk, dtype, rng):
    x = jnp.asarray(rng.standard_normal((b, t, h, p)), dtype)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, t, h)),
                                     jnp.float32))
    alog = jnp.asarray(rng.standard_normal((h,)) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, t, n)), dtype)
    cm = jnp.asarray(rng.standard_normal((b, t, n)), dtype)
    h0 = jnp.asarray(rng.standard_normal((b, h, p, n)) * 0.1, jnp.float32)
    y1, h1 = ssd_scan(x, dt, alog, bm, cm, h0, chunk=chunk)
    y2, h2 = ssd_scan_ref(x, dt, alog, bm, cm, h0)
    t_ = dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), **t_)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), **t_)


def test_ssd_chunk_invariance(rng):
    b, t, h, p, n = 1, 48, 2, 16, 8
    x = jnp.asarray(rng.standard_normal((b, t, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, t, h)),
                                     jnp.float32))
    alog = jnp.asarray(rng.standard_normal((h,)) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, t, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, t, n)), jnp.float32)
    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    y1, hf1 = ssd_scan(x, dt, alog, bm, cm, h0, chunk=16)
    y2, hf2 = ssd_scan(x, dt, alog, bm, cm, h0, chunk=48)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf1), np.asarray(hf2), atol=1e-4)


def test_pallas_decode_integrated_in_model():
    """Model decode with use_pallas_decode=True (interpret mode on CPU)
    matches the pure-jnp decode path bit-for-bit within tolerance."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("smollm-135m", reduced=True)
    m0 = build_model(cfg)
    m1 = build_model(dataclasses.replace(cfg, use_pallas_decode=True))
    params = m0.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab_size)
    _, cache = jax.jit(lambda p, b: m0.prefill(p, b, cache_len=16))(
        params, {"tokens": toks[:, :-1]})
    d0, _ = jax.jit(lambda p, c, t: m0.decode_step(p, c, t))(
        params, cache, toks[:, -1:])
    d1, _ = jax.jit(lambda p, c, t: m1.decode_step(p, c, t))(
        params, cache, toks[:, -1:])
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), atol=1e-4)


def test_wkv6_chunked_matches_scan(rng):
    """Beyond-paper chunked-parallel WKV6 == per-step scan (incl. carried
    state and non-multiple sequence lengths)."""
    from repro.models.rwkv6 import wkv6_chunked, wkv6_scan
    b, t, h, d = 2, 77, 3, 16
    mk = lambda: jnp.asarray(rng.standard_normal((b, t, h, d)) * 0.5,
                             jnp.float32)
    r, k, v = mk(), mk(), mk()
    w = jnp.asarray(rng.uniform(0.7, 0.999, (b, t, h, d)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, d)) * 0.5, jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((b, h, d, d)) * 0.1, jnp.float32)
    y1, s1 = wkv6_scan(r, k, v, w, u, s0)
    for chunk in (16, 32):
        y2, s2 = wkv6_chunked(r, k, v, w, u, s0, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


@pytest.mark.parametrize("b,s,h,kv,d,w,blk", [
    (2, 256, 4, 2, 32, 64, 64),
    (1, 512, 2, 2, 64, 128, 128),
    (2, 128, 3, 1, 16, 1000, 64),    # window >= seq: full causal
    (1, 256, 2, 2, 32, 32, 64),      # window < block
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_swa_prefill_sweep(b, s, h, kv, d, w, blk, dtype, rng):
    from repro.kernels.swa_prefill.ops import swa_prefill_attention
    from repro.kernels.swa_prefill.ref import swa_prefill_ref
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), dtype)
    out = swa_prefill_attention(q, k, v, window=w, block=blk)
    kr = jnp.repeat(k, h // kv, 2)
    vr = jnp.repeat(v, h // kv, 2)
    ref = swa_prefill_ref(qr := q, kr, vr, window=w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


# --------------------------------------------------------------------------
# ragged / odd-shape parity (ISSUE 4 satellite): the sweeps above cover
# round power-of-two shapes only; serving hands the kernels ragged ones.
# swa_prefill requires s % block == 0 after clamping (block = min(block,
# s)), so odd lengths run either as a single odd-sized block or with a
# block that divides a non-power-of-two s.
# --------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,kv,d,w,blk", [
    (1, 77, 2, 2, 32, 32, 256),      # odd s, single odd block
    (1, 77, 2, 1, 32, 1000, 256),    # odd s, window >= s (full causal)
    (2, 96, 3, 3, 16, 40, 32),       # non-pow2 s, multi-block, ragged w
    (1, 160, 4, 2, 32, 33, 32),      # batch=1, window straddles blocks
    (1, 64, 2, 2, 32, 1, 32),        # window=1: pure self-attention
    (2, 33, 1, 1, 16, 17, 64),       # prime-ish s, single head
])
def test_swa_prefill_ragged_and_window_edges(b, s, h, kv, d, w, blk, rng):
    from repro.kernels.swa_prefill.ops import swa_prefill_attention
    from repro.kernels.swa_prefill.ref import swa_prefill_ref
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    out = swa_prefill_attention(q, k, v, window=w, block=blk)
    kr = jnp.repeat(k, h // kv, 2)
    vr = jnp.repeat(v, h // kv, 2)
    ref = swa_prefill_ref(q, kr, vr, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_swa_prefill_window_one_is_self_attention(rng):
    """window=1 must reduce to attending the own position only (softmax
    over one logit == V at that position)."""
    from repro.kernels.swa_prefill.ops import swa_prefill_attention
    b, s, h, d = 1, 96, 2, 32
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    out = swa_prefill_attention(q, k, v, window=1, block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v), atol=1e-5)


@pytest.mark.parametrize("b,kv,g,d,s,block_s,lens", [
    (1, 1, 1, 32, 77, 512, [1]),          # batch=1, odd s, minimal cache
    (1, 2, 4, 32, 77, 512, [77]),         # odd s, full-length cache
    (2, 2, 2, 32, 96, 32, [31, 33]),      # lens straddle block edges
    (3, 1, 2, 16, 96, 32, [32, 64, 96]),  # lens exactly on block edges
    (1, 3, 1, 64, 60, 20, [59]),          # non-pow2 everything, g=1
])
def test_decode_attention_ragged_lengths(b, kv, g, d, s, block_s, lens,
                                         rng):
    q = jnp.asarray(rng.standard_normal((b, kv, g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    ln = jnp.asarray(lens, jnp.int32)
    out = decode_attention(q, k, v, ln, block_s=block_s)
    ref = decode_attention_ref(q, k, v, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_length_one_reads_first_token(rng):
    """length=1 must return exactly V[:, 0] regardless of cache noise."""
    b, kv, g, d, s = 1, 2, 2, 32, 64
    q = jnp.asarray(rng.standard_normal((b, kv, g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    out = decode_attention(q, k, v, jnp.asarray([1], jnp.int32),
                           block_s=32)
    expect = np.broadcast_to(np.asarray(v)[:, 0][:, :, None, :],
                             (b, kv, g, d))
    np.testing.assert_allclose(np.asarray(out), expect, atol=1e-5)


def test_swa_prefill_matches_model_blocked_attention(rng):
    """The kernel agrees with the model's blocked_attention SWA path."""
    from repro.kernels.swa_prefill.ops import swa_prefill_attention
    from repro.models.attention import blocked_attention
    b, s, h, kv, d, w = 2, 256, 4, 2, 32, 96
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    ref = blocked_attention(q, k, v, pos, pos, causal=True, window=w,
                            scale=d ** -0.5, block_q=64, block_k=64)
    out = swa_prefill_attention(q, k, v, window=w, block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
