"""The benchmark regression gate (``tools/bench_gate.py``).

The gate is the CI tripwire over recorded ``BENCH_*.json``
trajectories: green on the repo's real history, red on an artificially
regressed record — both directions are pinned here so the gate itself
cannot silently rot.
"""
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import bench_gate  # noqa: E402


def _write(root: Path, name: str, entries) -> None:
    (root / f"BENCH_{name}.json").write_text(json.dumps(entries),
                                             encoding="utf-8")


def _savings_entry(key: str, value: float) -> dict:
    return {"timestamp": "t", "commit": "c", "metrics": {key: value}}


def test_gate_green_on_repo_history():
    """The repo's own recorded trajectories must pass the gate."""
    problems, _notices = bench_gate.run_gate(REPO)
    assert problems == [], problems


def test_gate_green_main_exit_code():
    assert bench_gate.main(["--root", str(REPO)]) == 0


def test_missing_files_pass_with_notice(tmp_path):
    problems, notices = bench_gate.run_gate(tmp_path)
    assert problems == []
    assert len(notices) == len(bench_gate.SAVINGS_KEYS)


def test_regressed_savings_blocks(tmp_path):
    """Latest savings >10% below the trajectory best must fail."""
    _write(tmp_path, "tenant", [_savings_entry("savings", 0.50),
                                _savings_entry("savings", 0.30)])
    problems, _ = bench_gate.run_gate(tmp_path)
    assert any("BENCH_tenant.json" in p and "regressed" in p
               for p in problems), problems
    assert bench_gate.main(["--root", str(tmp_path)]) == 1


def test_savings_within_tolerance_passes(tmp_path):
    _write(tmp_path, "tenant", [_savings_entry("savings", 0.50),
                                _savings_entry("savings", 0.46)])
    _write(tmp_path, "uncertainty",
           [_savings_entry("core_seconds_saved", 0.11)])
    problems, _ = bench_gate.run_gate(tmp_path)
    assert problems == [], problems


def test_uncertainty_regression_blocks(tmp_path):
    _write(tmp_path, "uncertainty",
           [_savings_entry("core_seconds_saved", 0.12),
            _savings_entry("core_seconds_saved", 0.01)])
    problems, _ = bench_gate.run_gate(tmp_path)
    assert any("BENCH_uncertainty.json" in p for p in problems), problems


def test_slow_speedup_row_blocks(tmp_path):
    """A harness row-list whose speedup falls below the 10x bar fails."""
    rows = [["scenario_fast", 12.3, "events_per_s=81000;speedup=8.4x"]]
    _write(tmp_path, "throughput",
           [{"timestamp": "t", "commit": "c", "metrics": rows}])
    problems, _ = bench_gate.run_gate(tmp_path)
    assert any("8.4x" in p for p in problems), problems


def test_fast_speedup_row_passes(tmp_path):
    rows = [["scenario_fast", 12.3, "events_per_s=81000;speedup=18.8x"]]
    _write(tmp_path, "throughput",
           [{"timestamp": "t", "commit": "c", "metrics": rows}])
    problems, _ = bench_gate.run_gate(tmp_path)
    assert problems == [], problems


def test_degrade_gain_below_break_even_blocks(tmp_path):
    """degrade* rows gate on acc_goodput_gain >= 1x: the planner must
    never lose accuracy-weighted goodput to the top fixed rung."""
    rows = [["degrade_flash-overload", 90.0,
             "acc_goodput_gain=0.92x;agp=11000;swaps=15"]]
    _write(tmp_path, "degrade",
           [{"timestamp": "t", "commit": "c", "metrics": rows}])
    problems, _ = bench_gate.run_gate(tmp_path)
    assert any("0.9x below the 1x bar" in p for p in problems), problems


def test_degrade_gain_uses_break_even_floor_not_speedup_bar(tmp_path):
    """A 1.2x gain passes: degrade rows use the 1x prefix floor, not
    the generic 10x speedup bar."""
    rows = [["degrade_total", 90.0, "acc_goodput_gain=1.22x;agp=54000"]]
    _write(tmp_path, "degrade",
           [{"timestamp": "t", "commit": "c", "metrics": rows}])
    problems, _ = bench_gate.run_gate(tmp_path)
    assert problems == [], problems


def test_short_trajectory_emits_named_notice(tmp_path):
    """A trajectory with a single record passes the gate but surfaces
    the named short-trajectory notice (it cannot regress *yet*)."""
    _write(tmp_path, "uncertainty",
           [_savings_entry("core_seconds_saved", 0.11)])
    rows = [["scenario_fast", 12.3, "speedup=18.8x"]]
    _write(tmp_path, "throughput",
           [{"timestamp": "t", "commit": "c", "metrics": rows}])
    problems, notices = bench_gate.run_gate(tmp_path)
    assert problems == [], problems
    short = [n for n in notices if "short-trajectory" in n]
    assert any("BENCH_uncertainty.json" in n for n in short), notices
    assert any("BENCH_throughput.json" in n for n in short), notices


def test_two_record_trajectory_has_no_short_notice(tmp_path):
    _write(tmp_path, "tenant", [_savings_entry("savings", 0.50),
                                _savings_entry("savings", 0.48)])
    _, notices = bench_gate.run_gate(tmp_path)
    assert not any("short-trajectory" in n and "tenant" in n
                   for n in notices), notices


def test_unreadable_file_blocks(tmp_path):
    (tmp_path / "BENCH_tenant.json").write_text("{not json",
                                                encoding="utf-8")
    problems, _ = bench_gate.run_gate(tmp_path)
    assert any("unreadable" in p for p in problems), problems
