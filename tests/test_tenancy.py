"""Multi-tenant pool tests (ISSUE 6).

Three layers:

* **Decision identity** — the interleaved struct-of-arrays
  :class:`~repro.serving.tenancy.TenantFastRunner` must be
  decision-identical to the pre-heaped
  :class:`~repro.serving.tenancy.TenantExactRunner` oracle on every
  ``mixed-zoo`` scenario × every pool policy.  The fast engine runs at
  solver quanta **zero** here: the production defaults
  (``budget_quantum=0.01, lam_quantum=0.5``) trade exactness for cache
  hits, and the exact engine always pins quanta to 0.
* **Reallocator properties** — driven directly through
  :meth:`TenantPool.reallocate` with synthetic snapshots: swaps never
  breach the pool budget or the per-tenant floor, ``fair-share``
  converges to the weight-proportional split from any skewed start,
  and ``priority`` starves the unimportant tenants down to a floor and
  then *stops proposing* (livelock-free by construction).
* **Starvation is reported, not deadlocked** — an engine-level run
  under ``priority`` completes, serves every request, and the starved
  tenant's violations land in its report.
"""
import numpy as np
import pytest

from repro.core.solver import JointSolverTable
from repro.serving.scenarios import build_scenario, run_scenario
from repro.serving.tenancy import POOL_POLICIES, TenantPool

SEED = 7


def _decision_sig(report):
    return [(t, d.c, d.b, d.n, d.feasible)
            for t, d in (report.decisions or [])]


def _sig(report):
    return (_decision_sig(report), report.buckets, report.n_requests,
            report.n_violations, round(report.core_seconds, 6))


# --------------------------------------------------------------------------
# decision identity: fast == exact oracle, every zoo x every policy
# --------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POOL_POLICIES)
@pytest.mark.parametrize("name", ["mixed-zoo", "mixed-zoo-rush"])
def test_fast_matches_exact_oracle(name, policy):
    kw = dict(duration=60, seed=SEED, tenant_policy=policy)
    r_fast, s_fast = run_scenario(name, engine="fast", budget_quantum=0.0,
                                  lam_quantum=0.0, **kw)
    r_ex, s_ex = run_scenario(name, engine="exact", **kw)
    assert _sig(r_fast) == _sig(r_ex)
    assert s_fast["pool"]["caps"] == s_ex["pool"]["caps"]
    assert s_fast["pool"]["swaps"] == s_ex["pool"]["swaps"]
    for tf, te in zip(s_fast["tenant_reports"], s_ex["tenant_reports"]):
        assert _sig(tf) == _sig(te)


# --------------------------------------------------------------------------
# reallocator properties (the pool driven directly)
# --------------------------------------------------------------------------
def _zoo_pool(policy, **kw):
    """A TenantPool over the real mixed-zoo specs with solver tables
    bound — the same frontier the engines price against."""
    _, meta = build_scenario("mixed-zoo", duration=5, seed=0)
    specs = list(meta["tenants"])
    pool = TenantPool(specs, budget=128, policy=policy, **kw)
    for k, s in enumerate(specs):
        pool.bind_table(k, JointSolverTable(s.cost, s.c_set, s.b_set,
                                            s.n_set))
    return pool


def _idle(k):
    return [(np.empty(0), 0.0, 0.0)] * k


@pytest.mark.parametrize("policy", POOL_POLICIES)
def test_swaps_never_violate_pool_budget(policy):
    """Property: under adversarial random snapshots, every round keeps
    ``sum(caps) <= budget`` and every cap at or above ``min_cores``."""
    pool = _zoo_pool(policy, swap_step=8, swap_patience=1, min_cores=4)
    rng = np.random.default_rng(0)
    for round_i in range(60):
        snaps = []
        for _ in pool.specs:
            rem = np.sort(rng.exponential(0.3, rng.integers(0, 25)))
            snaps.append((rem, float(rng.uniform(0.0, 400.0)),
                          float(rng.uniform(0.0, 0.2))))
        pool.reallocate(float(round_i), snaps)
        assert sum(pool.caps) <= pool.budget, (round_i, pool.caps)
        assert all(c >= pool.min_cores for c in pool.caps), pool.caps
    assert len(pool.cap_log) == 60
    for _, caps in pool.cap_log:
        assert sum(caps) <= pool.budget


def test_fair_share_converges_to_proportional():
    """From any skewed start, fair-share steers caps to the
    largest-remainder weight-proportional targets and then stops."""
    pool = _zoo_pool("fair-share", initial_caps=(88, 20, 20))
    assert pool.caps != pool._targets
    for i in range(20):
        pool.reallocate(float(i), _idle(len(pool.specs)))
    assert pool.caps == pool._targets
    assert sum(pool.caps) == pool.budget
    # converged means converged: further rounds propose nothing
    tail = pool.cap_log[-1][1]
    for i in range(20, 25):
        pool.reallocate(float(i), _idle(len(pool.specs)))
    assert all(caps == tail for _, caps in pool.cap_log[-5:])
    assert not any(t >= 20 for t, *_ in pool.swaps)


def test_priority_starves_to_floor_without_livelock():
    """A perpetually overloaded priority-0 tenant drains the others to
    the donation floor; once no donor remains the policy proposes
    nothing — starvation ends in a stable split, not a livelock."""
    pool = _zoo_pool("priority", swap_patience=1)
    prios = [s.priority for s in pool.specs]
    top = prios.index(min(prios))
    table = pool._tables[top]
    # λ far beyond anything the grid sustains: overflow pricing keeps
    # the starved-tenant gain alive with an empty queue
    lam = table.max_rate(pool.budget) + 200.0
    init = list(pool.caps)
    for i in range(40):
        snaps = [(np.empty(0), lam if k == top else 0.0, 0.0)
                 for k in range(len(pool.specs))]
        pool.reallocate(float(i), snaps)
        assert sum(pool.caps) <= pool.budget
    assert pool.caps[top] > init[top]
    for k in range(len(pool.specs)):
        if k != top:
            assert pool.caps[k] < init[k], (k, pool.caps)
            # drained until one more step would breach the floor
            assert pool.caps[k] - pool.swap_step < pool.min_cores
    # stable: the last rounds propose nothing further
    tail = pool.cap_log[-1][1]
    assert all(caps == tail for _, caps in pool.cap_log[-5:])


def test_overflow_pricing_signals_before_backlog_exists():
    """The λ-overflow term: a tenant whose arrival rate exceeds its
    capped ceiling prices a positive transfer gain *before* any request
    is queued — the early-warning property that lets cores move ahead
    of the queue melting down."""
    pool = _zoo_pool("greedy-marginal")
    table = pool._tables[0]
    cap = pool.caps[0]
    assert table.max_rate(cap + pool.swap_step) > table.max_rate(cap)
    lam = table.max_rate(cap) + 50.0
    prof = pool.marginal_profile(0, (np.empty(0), lam, 0.0))
    assert prof["v"] > 0.0
    assert prof["gain"] > 0.0
    # and an idle tenant prices zero everywhere
    idle = pool.marginal_profile(0, (np.empty(0), 0.0, 0.0))
    assert idle["v"] == idle["gain"] == 0.0


def test_pool_constructor_validation():
    _, meta = build_scenario("mixed-zoo", duration=5, seed=0)
    specs = list(meta["tenants"])
    with pytest.raises(KeyError):
        TenantPool(specs, policy="round-robin")
    with pytest.raises(ValueError):
        TenantPool(specs, budget=8, min_cores=4)      # cannot floor 3
    with pytest.raises(ValueError):
        TenantPool(specs, budget=128, initial_caps=(100, 100, 100))
    with pytest.raises(ValueError):
        TenantPool(specs, budget=128, initial_caps=(2, 2, 124))
    pool = TenantPool(specs, budget=128)
    assert sum(pool._targets) == 128
    assert pool.caps == pool._targets


# --------------------------------------------------------------------------
# starvation is reported, not deadlocked (engine level)
# --------------------------------------------------------------------------
def test_priority_starved_tenant_reports_violations():
    """Under ``priority`` the low-priority tenant is starved through a
    flash crowd it could otherwise absorb — the run still completes,
    every request of every tenant is accounted for, and the starved
    tenant's violations show up in its report instead of hanging the
    loop."""
    batch, _ = build_scenario("mixed-zoo", duration=60, seed=SEED)
    report, stats = run_scenario("mixed-zoo", engine="fast", duration=60,
                                 seed=SEED, tenant_policy="priority")
    assert report.n_requests == len(batch)
    assert sum(t["n_requests"] for t in stats["tenants"].values()) == \
        len(batch)
    specs = stats["meta"]["tenants"]
    starved = max(specs, key=lambda s: s.priority).name
    assert stats["tenants"][starved]["violation_rate"] > 0.0
    assert np.isfinite(report.core_seconds)
