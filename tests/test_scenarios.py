"""Scenario registry: every registered scenario runs end-to-end on both
engines, the launcher drives them, and the requests= sizing knob works."""
import numpy as np
import pytest

from repro.serving.scenarios import (SCENARIOS, build_scenario,
                                     get_scenario, list_scenarios,
                                     run_scenario)

REQUIRED = {"steady", "diurnal", "flash-crowd", "network-replay",
            "mixed-slo", "slo-renegotiation", "cancel-storm"}


def test_registry_contents():
    assert REQUIRED <= set(SCENARIOS), \
        f"missing scenarios: {REQUIRED - set(SCENARIOS)}"
    summaries = list_scenarios()
    for name in SCENARIOS:
        assert summaries[name], f"{name} has no summary"
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")


@pytest.mark.parametrize("name", sorted(REQUIRED))
def test_scenario_builds_sane_batches(name):
    batch, meta = build_scenario(name, duration=60, seed=7)
    assert len(batch) > 0
    assert np.all(np.diff(batch.arrival) >= 0), "not arrival-sorted"
    assert np.all(batch.comm_latency > 0)
    assert np.all(batch.deadline > batch.send)
    assert meta["slo"] > 0 and meta["expected_rps"] > 0
    assert meta["scenario"] == name


@pytest.mark.parametrize("name", sorted(REQUIRED))
def test_scenario_runs_end_to_end_fast(name):
    report, stats = run_scenario(name, engine="fast", duration=60, seed=7)
    assert report.n_requests > 0
    assert 0.0 <= report.violation_rate <= 1.0
    assert report.avg_cores > 0
    assert stats["engine"] == "fast" and stats["events"] > 0


@pytest.mark.parametrize("name", sorted(REQUIRED))
def test_scenario_runs_end_to_end_exact(name):
    report, stats = run_scenario(name, engine="exact", duration=45, seed=7)
    assert report.n_requests > 0
    assert stats["engine"] == "exact"


def test_fast_and_exact_agree_on_request_counts():
    for name in sorted(REQUIRED):
        fast, _ = run_scenario(name, engine="fast", duration=45, seed=2)
        exact, _ = run_scenario(name, engine="exact", duration=45, seed=2)
        assert fast.n_requests == exact.n_requests, name


def test_requests_knob_sizes_the_run():
    batch, meta = build_scenario("steady", requests=5000, seed=1)
    assert len(batch) == pytest.approx(5000, rel=0.05)
    batch, meta = build_scenario("diurnal", requests=3000, seed=1)
    assert len(batch) == pytest.approx(3000, rel=0.25)   # Poisson thinning


def test_scenarios_run_via_launcher():
    from repro.launch.serve import main
    for name in sorted(REQUIRED):
        main(["--scenario", name, "--duration", "30", "--seed", "4"])


def test_sponge_pred_requires_exact_engine():
    with pytest.raises(ValueError):
        run_scenario("steady", policy="sponge-pred", engine="fast",
                     duration=30)
    report, _ = run_scenario("steady", policy="sponge-pred",
                             engine="exact", duration=30)
    assert report.n_requests > 0


def test_flash_crowd_overload_is_localized():
    """The spike exceeds capacity by design; the base load around it must
    still be served cleanly (violations concentrate in/after spikes)."""
    batch, meta = build_scenario("flash-crowd", duration=300, seed=7)
    report, _ = run_scenario("flash-crowd", duration=300, seed=7)
    # first 35% of the run is pre-spike steady state at low utilization
    assert report.violation_rate < 0.6
    assert report.n_requests == len(batch)
