"""Public-API snapshot (ISSUE 5 satellite): surface changes must be
deliberate.

The exported-name sets below are the contract other code programs
against.  If a PR changes one of these sets, this test fails and the
snapshot must be updated *in the same PR* — which is the point: the
diff makes the surface change visible and reviewable, instead of a
re-export silently appearing or vanishing.
"""
import warnings

import pytest

SERVING_EXPORTS = {
    "ExactSession", "FastSession", "FleetSession", "JaxBackend",
    "RequestBatch", "RunReport", "ScenarioRunner", "SessionTranscript",
    "SimBackend", "SpongeServer", "SpongeSession", "TenantPool",
    "TenantSpec", "TokenFastSession", "WorkloadGenerator",
    "drive_session_events", "make_live_server", "make_policy",
    "make_sim_server", "replay_transcript", "round_up_c",
}

SOLVER_EXPORTS = {
    "DEFAULT_B", "DEFAULT_C", "DEFAULT_N", "JointMemoizedSolver",
    "JointSolverTable", "MemoizedSolver", "MultiModelMemoizedSolver",
    "MultiModelSolverTable", "SolverTable", "TokenMemoizedSolver",
    "TokenSolverTable", "solve_bruteforce", "solve_joint_bruteforce",
    "solve_multimodel_bruteforce", "solve_pruned",
    "solve_token_bruteforce",
}

DEGRADATION_EXPORTS = {
    "DEFAULT_LADDER_ARCHS", "FULL_LADDER_ARCHS", "ModelLadder",
    "ModelRung", "default_ladder", "fit_rung_cost", "resolve_ladder",
}

UNCERTAINTY_EXPORTS = {
    "EmpiricalLengths", "LengthDistribution", "LengthPredictor",
    "LognormalLengths", "MixtureLengths", "PointMass",
    "UncertaintyConfig",
}


def _public_names(mod) -> set:
    if hasattr(mod, "__all__"):
        return set(mod.__all__)
    return {n for n in vars(mod)
            if not n.startswith("_") and not _is_module(vars(mod)[n])}


def _is_module(obj) -> bool:
    import types
    return isinstance(obj, types.ModuleType)


def test_serving_public_surface():
    import repro.serving as serving
    assert _public_names(serving) == SERVING_EXPORTS


def test_uncertainty_public_surface():
    import repro.core.uncertainty as uncertainty
    assert _public_names(uncertainty) == UNCERTAINTY_EXPORTS


def test_solver_public_surface():
    import repro.core.solver as solver
    names = {n for n in _public_names(solver)
             if n == n.upper() or n[:1].isupper() or n.startswith("solve")}
    assert names >= SOLVER_EXPORTS, (
        f"missing from repro.core.solver: {SOLVER_EXPORTS - names}")


def test_degradation_public_surface():
    import repro.core.degradation as degradation
    names = {n for n in _public_names(degradation)
             if n == n.upper() or n[:1].isupper()
             or n in ("default_ladder", "fit_rung_cost",
                      "resolve_ladder")}
    assert names >= DEGRADATION_EXPORTS, (
        "missing from repro.core.degradation: "
        f"{DEGRADATION_EXPORTS - names}")


def test_serving_no_longer_reexports_shims():
    """The PR 1 deprecation, finished: the shim names are gone from the
    package surface and only reachable through their warning modules."""
    import repro.serving as serving
    for name in ("ClusterSimulator", "Server", "simulate",
                 "ServingEngine"):
        assert not hasattr(serving, name), name


def test_shim_modules_warn_on_import():
    import importlib
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        import repro.serving.simulator as sim_shim
        import repro.serving.engine as eng_shim
        import repro.core.multidim as multidim_shim
    for shim in (sim_shim, eng_shim, multidim_shim):
        with pytest.warns(DeprecationWarning):
            importlib.reload(shim)


def test_multidim_no_longer_patches_spongescaler():
    """The deprecated multidim module must not mutate ``SpongeScaler``
    at import time (the historical ``decide_shared`` monkey-patch)."""
    import importlib
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        import repro.core.multidim as multidim_shim
        importlib.reload(multidim_shim)
    from repro.core.scaler import SpongeScaler
    assert not hasattr(SpongeScaler, "decide_shared")


def test_shims_still_functional_behind_the_warning():
    """Deprecated != broken: the historical constructor signatures keep
    working for one more cycle."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.serving.simulator import ClusterSimulator, simulate  # noqa
    assert callable(simulate) and callable(ClusterSimulator)
